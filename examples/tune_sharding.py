"""Tune a production distribution config with the paper's BO engine.

The black-box objective is a 256-chip dry-run COMPILE (~30–120 s per
evaluation on this host): the tuner proposes (remat, q-chunking, logits
chunk, ZeRO-3 on/off, ...), a subprocess lowers+compiles the cell against
the production mesh, and the roofline step time comes back — or INVALID when
the config doesn't compile or doesn't fit HBM. This is the paper's problem
(expensive, discrete, constrained, invalid-laden) at datacenter scale.

  PYTHONPATH=src python examples/tune_sharding.py \
      --arch internlm2-1.8b --shape train_4k --budget 10
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.runner import run_strategy
from repro.core.strategies import make_strategy
from repro.core.strategies.bo import BOConfig, BOStrategy
from repro.core.tuning_targets import DryRunObjective


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--init", type=int, default=5)
    ap.add_argument("--strategy", default="advanced_multi")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1,
                    help="parallel compile evaluations (constant-liar batch)")
    args = ap.parse_args()

    obj = DryRunObjective(args.arch, args.shape, args.mesh)
    print(obj.space.describe())
    print(f"budget {args.budget} compiles (cached in results/tune_cache)\n")

    strat = BOStrategy(BOConfig(acquisition=args.strategy,
                                initial_samples=args.init))
    res = run_strategy(strat, obj, budget=args.budget, seed=args.seed,
                       workers=args.workers,
                       batch_size=max(args.workers, 1),
                       checkpoint_path="results/tune_cache/"
                       f"journal_{args.arch}_{args.shape}.json", resume=True)
    print(f"\nbest distribution config: {obj.space.config(res.best_idx)}")
    print(f"roofline step time: {res.best_value:.3f} s "
          f"({res.unique_evals} unique compiles)")


if __name__ == "__main__":
    main()
