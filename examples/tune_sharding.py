"""Tune a production distribution config with the paper's BO engine.

The black-box objective is a 256-chip dry-run COMPILE (~30–120 s per
evaluation on this host): the tuner proposes (remat, q-chunking, logits
chunk, ZeRO-3 on/off, ...), a subprocess lowers+compiles the cell against
the production mesh, and the roofline step time comes back — or INVALID when
the config doesn't compile or doesn't fit HBM. This is the paper's problem
(expensive, discrete, constrained, invalid-laden) at datacenter scale.

  PYTHONPATH=src python examples/tune_sharding.py \
      --arch internlm2-1.8b --shape train_4k --budget 10

``--wide`` opens the full chunk-size grids and BO automatically switches to
candidate-pool acquisition: each iteration scores a pool of incumbent
neighborhoods + stratified draws instead of the whole space. Past
``max_enumeration`` (the 10^9+ MoE grids of deepseek-v3-671b) the space
silently becomes the generative backend (DESIGN.md §15) — constructed in
milliseconds, nothing enumerated, feasible configs drawn straight from the
constraints.

  PYTHONPATH=src python examples/tune_sharding.py \
      --arch deepseek-v3-671b --shape train_4k --budget 10 --wide
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.runner import run_strategy
from repro.core.strategies.bo import BOConfig, BOStrategy
from repro.core.tuning_targets import DryRunObjective
from repro.store import SpaceFingerprint, TuningRecordStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--init", type=int, default=5)
    ap.add_argument("--strategy", default="advanced_multi")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1,
                    help="parallel compile evaluations (constant-liar batch)")
    ap.add_argument("--wide", action="store_true",
                    help="widened chunk-size grids (>2M cartesian for MoE "
                         "cells) with vectorized constraints; BO scores a "
                         "candidate pool instead of the full space")
    ap.add_argument("--store", default="results/tune_store",
                    help="shared tuning-record store: journals stream into "
                         "it, prior records (any size/shape of this cell) "
                         "warm-start the GP, and repro.launch.serve resolves "
                         "its config from it")
    ap.add_argument("--no-warm-start", action="store_true")
    args = ap.parse_args()

    obj = DryRunObjective(args.arch, args.shape, args.mesh, wide=args.wide)
    print(obj.space.describe())

    store = TuningRecordStore(args.store)
    fp = SpaceFingerprint.of(obj.space, objective=obj.name)
    prior = store.best_config(fp)
    if prior is not None:
        cfgp, tp = prior
        print(f"\nbest prior record for this cell: {tp:.3f}s {cfgp}")

    cfg = BOConfig(acquisition=args.strategy, initial_samples=args.init)
    strat = BOStrategy(cfg)
    if cfg.pool_active(obj.space.size) or obj.space.generative:
        # incumbent Hamming neighborhoods + stratified draws (+ LHS refresh)
        n_nbrs = sum(len(p.values) - 1 for p in obj.space.params)
        per_round = (cfg.pool_size + cfg.pool_incumbents * n_nbrs
                     + cfg.pool_lhs_points)
        backend = ("generative feasible draws" if obj.space.generative
                   else "the restricted space")
        print(f"\ncandidate-pool acquisition: ~{per_round:,} configs scored "
              f"per iteration via {backend} "
              f"(cartesian {obj.space.cartesian_size:,})")
    else:
        print(f"\nfull-space acquisition: all {obj.space.size:,} configs "
              f"scored per iteration (cartesian {obj.space.cartesian_size:,})")
    print(f"budget {args.budget} compiles (cached in results/tune_cache)\n")

    tag = f"{args.arch}_{args.shape}" + ("_wide" if args.wide else "")
    res = run_strategy(strat, obj, budget=args.budget, seed=args.seed,
                       workers=args.workers,
                       batch_size=max(args.workers, 1),
                       store=store, run_id=f"tune_{tag}-s{args.seed}",
                       warm_start=not args.no_warm_start,
                       resume=True)
    if res.best_idx is None:
        print(f"\nno valid config found in {res.unique_evals} compiles — "
              "raise --budget or inspect results/tune_cache for the errors")
        return
    print(f"\nbest distribution config: {obj.space.config(res.best_idx)}")
    print(f"roofline step time: {res.best_value:.3f} s "
          f"({res.unique_evals} unique compiles)")
    print(f"records in {args.store}: {len(store)} — serve resolves with\n"
          f"  python -m repro.launch.serve --arch {args.arch} --smoke "
          f"--store {args.store} --tuned-shape {args.shape}")


if __name__ == "__main__":
    main()
