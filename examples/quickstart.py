"""Quickstart: auto-tune a Pallas TPU GEMM kernel with the paper's BO.

The search space is the kernel's MXU tile configuration; invalid configs
(VMEM overflow) are discovered at evaluation time, exactly like the paper's
compile-/runtime-invalid GPU configs. On CPU the objective is the kernel's
analytic TPU cost model + measured interpret dispatch; on a real TPU the
same script times the real kernel.

  PYTHONPATH=src python examples/quickstart.py
"""
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.objectives import CallableObjective
from repro.core.runner import run_strategy
from repro.core.strategies import make_strategy
from repro.kernels import ops
from repro.kernels.gemm import gemm_vmem_bytes
from repro.launch.roofline import HBM_BW, PEAK_FLOPS, VMEM_BYTES

M = N = K = 2048


def tpu_cost_model(cfg) -> float:
    """Analytic v5e time (µs) for one tile config; None/raise = invalid."""
    bm, bn, bk = cfg["block_m"], cfg["block_n"], cfg["block_k"]
    if gemm_vmem_bytes(bm, bn, bk) > VMEM_BYTES:
        raise ValueError("VMEM overflow")         # invalid configuration
    if bm % 128 or bn % 128 or bk % 128:
        raise ValueError("MXU misalignment")      # invalid configuration
    flops = 2 * M * N * K
    # HBM traffic: A streamed N/bn times, B streamed M/bm times + C once
    bytes_moved = 2 * (M * K * (N // bn) + K * N * (M // bm) + M * N)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_moved / HBM_BW
    # small-tile launch overhead
    tiles = (M // bm) * (N // bn) * (K // bk)
    return (max(t_compute, t_memory) + tiles * 1e-7) * 1e6


def main():
    space = ops.gemm_config_space(M, N, K)
    print(space.describe())
    obj = CallableObjective(space, tpu_cost_model, name="pallas_gemm_2048")

    res = run_strategy(make_strategy("advanced_multi"), obj, budget=40, seed=0)
    best = space.config(res.best_idx)
    print(f"\nbest config after {res.unique_evals} evaluations: {best}"
          f"\npredicted time: {res.best_value:.1f} µs")

    n_invalid = sum(1 for o in res.journal if not math.isfinite(o.value))
    print(f"invalid configs encountered and handled: {n_invalid}")

    # correctness of the tuned kernel in interpret mode, small instance
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    small = {k: min(v, 256) for k, v in best.items()}
    out = ops.gemm(a, b, block_m=small["block_m"], block_n=small["block_n"],
                   block_k=small["block_k"])
    err = float(jnp.max(jnp.abs(out - a @ b)))
    print(f"tuned kernel validated in interpret mode, max err {err:.2e}")


if __name__ == "__main__":
    main()
