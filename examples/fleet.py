"""A tuning fleet in one process: N daemons + a racing compactor, one store.

Everything in this demo is the real control plane — ``TuningJobQueue``
submits durable ``kind="job"`` records, ``RetuneDaemon`` claims each one
under a fenced lease and services it with a journaled engine run, and
``compact_store`` races the daemons under the real single-compactor lock.
Only time (a step-advanced virtual clock) and the tuning objective (a
simulated latency surface per cell) are synthetic, so the run is
deterministic and finishes in seconds:

  PYTHONPATH=src python examples/fleet.py [--smoke]
  PYTHONPATH=src python examples/fleet.py --daemons 4 --jobs 32 --budget 5

The printout to watch: every job serviced by exactly ONE daemon (the
fencing tokens arbitrate every claim), the compactor folding segments
mid-drain without the daemons noticing, and all four job types flowing
through one fleet. On a real deployment the same daemons run as separate
processes on separate hosts (``python -m repro.launch.retune --store ...
--worker host-a``) — nothing here relies on sharing a process.
"""
import argparse
import os
import shutil
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.engine import RetuneRequest
from repro.core.objectives import SimulatedObjective
from repro.core.searchspace import Param, SearchSpace
from repro.core.strategies import make_strategy
from repro.launch.retune import RetuneDaemon
from repro.store import (JOB_TYPES, CompactionLocked, TuningJobQueue,
                         TuningRecordStore, compact_store)


class Clock:
    """Monotonic sim time, advanced by the loop — deterministic runs."""

    def __init__(self, t0: float = 1.0):
        self.t = t0

    def __call__(self) -> float:
        return self.t


def surface(space: SearchSpace, seed: int) -> np.ndarray:
    """A smooth per-config latency bowl — the simulated cell to tune."""
    rng = np.random.default_rng(seed)
    x = space.X_norm.astype(np.float64)
    c = rng.uniform(0.2, 0.8, size=x.shape[1])
    return 1.0 + np.sum((x - c) ** 2, axis=1) + 0.05 * rng.standard_normal(
        space.size)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--daemons", type=int, default=3)
    ap.add_argument("--jobs", type=int, default=12)
    ap.add_argument("--budget", type=int, default=3,
                    help="unique evals per serviced job")
    ap.add_argument("--compact-every", type=int, default=2,
                    help="race a compaction every N round-robin rounds")
    ap.add_argument("--store", default=None,
                    help="store directory (default: a temp dir)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI: assert the exactly-once outcome and exit")
    args = ap.parse_args()

    workdir = args.store or tempfile.mkdtemp(prefix="fleet-demo-")
    store_path = os.path.join(workdir, "store")
    clock = Clock()
    space = SearchSpace([Param("block", (64, 128, 256, 512)),
                         Param("warps", (2, 4, 8))], name="demo-cell")

    # ONE live appender for the whole process (compaction seals per pid);
    # every daemon and the submitter write through it
    store = TuningRecordStore(store_path, lazy=True)
    submitter = TuningJobQueue(store_path, worker="submitter",
                               clock=clock, appender=store)

    service_log = []

    def objective_for(worker):
        def _for(key):
            service_log.append((key, worker))
            return SimulatedObjective(space, surface(space, hash(key) % 997),
                                      name=key)
        return _for

    daemons = [RetuneDaemon(store_path, objective_for=objective_for(f"d{i}"),
                            strategy_factory=lambda: make_strategy("random"),
                            budget=args.budget, worker=f"d{i}",
                            claim_ttl=1000.0, clock=clock, store=store)
               for i in range(args.daemons)]

    for i in range(args.jobs):
        clock.t += 0.01
        ok = submitter.submit(
            RetuneRequest(key=f"cell-{i:03d}", objective=f"cell-{i:03d}",
                          reason="demo", t=clock()),
            job_type=JOB_TYPES[i % len(JOB_TYPES)])
        assert ok
    print(f"submitted {args.jobs} jobs "
          f"({', '.join(JOB_TYPES)}) to {store_path}")

    rounds = compactions = 0
    while len(submitter) > 0 and rounds < 200:
        rounds += 1
        for d in daemons:
            d.step()
            clock.t += 1.0
        if args.compact_every and rounds % args.compact_every == 0:
            store.close()                    # seal this pid's live segment
            try:
                stats = compact_store(store_path, retention_s=0.0,
                                      clock=clock)
                compactions += int(stats.folded)
                if stats.folded:
                    print(f"  round {rounds}: compactor folded "
                          f"{len(stats.sources)} segments "
                          f"({stats.dropped_retune} closed job records "
                          "dropped) while the daemons kept draining")
            except CompactionLocked as e:    # a peer got there first
                print(f"  round {rounds}: compactor yielded: {e}")

    per_key = {}
    for key, worker in service_log:
        per_key.setdefault(key, []).append(worker)
    print(f"\ndrained in {rounds} rounds, {compactions} compactions raced")
    for i, d in enumerate(daemons):
        print(f"  d{i}: serviced {d.serviced}, fenced out {d.fenced}")
    dupes = {k: w for k, w in per_key.items() if len(w) != 1}
    print(f"  exactly-once: {len(per_key)}/{args.jobs} jobs serviced once"
          + (f"  DUPLICATES: {dupes}" if dupes else ""))

    if args.smoke:
        assert len(submitter) == 0, "queue failed to drain"
        assert len(per_key) == args.jobs and not dupes, dupes
        assert sum(d.serviced for d in daemons) == args.jobs
        assert compactions >= 1, "the compactor never raced the fleet"
        print("smoke OK")
    if args.store is None:
        store.close()
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
