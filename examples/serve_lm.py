"""Example 4: batched serving (prefill + decode) across architectures.

Exercises the serving path for three different cache families:
GQA KV cache (gemma), MLA latent cache (deepseek), recurrent state (xlstm).

  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import subprocess
import sys

HERE = os.path.dirname(__file__)

for arch in ("gemma-2b", "deepseek-v3-671b", "xlstm-1.3b"):
    print(f"\n=== {arch} (smoke config) ===")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
         "--smoke", "--batch", "4", "--prompt-len", "32",
         "--decode-steps", "8"],
        env={**os.environ, "PYTHONPATH": os.path.join(HERE, "..", "src")},
        cwd=os.path.join(HERE, ".."))
    if r.returncode != 0:
        raise SystemExit(f"{arch} serving failed")
print("\nall serving paths OK")
