"""End-to-end driver: train an LM with the full substrate.

Demonstrates data pipeline → model → optimizer → fault-tolerant loop (async
checkpoints, straggler log, injected failure + automatic restart).

Two presets:
  * default — ~100M parameters (12L × d512, 50k vocab). A few hundred steps
    is a real-accelerator workload (~1.2 TFLOP/step); on this 1-core CPU
    container use --steps 20 to see it run end to end.
  * --small — ~25M parameters (8L × d256, 16k vocab), CPU-friendly: 300
    steps in ~10 min, loss visibly decreasing.

  PYTHONPATH=src python examples/train_lm.py --small --steps 300
  PYTHONPATH=src python examples/train_lm.py --steps 20   # 100M preset
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.arch import ArchConfig
from repro.data.pipeline import DataConfig
from repro.runtime.train import LoopConfig, TrainLoop, run_with_restarts

LM100M = ArchConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=50_304,
    dtype="float32",
)
LM25M = ArchConfig(
    name="lm-25m", family="dense", num_layers=8, d_model=256,
    num_heads=8, num_kv_heads=8, d_ff=1024, vocab_size=16_384,
    dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="~25M CPU-friendly preset (default: ~100M)")
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--peak-lr", type=float, default=6e-4)
    args = ap.parse_args()

    cfg = LM25M if args.small else LM100M
    seq = args.seq_len or (128 if args.small else 256)
    gb = args.global_batch or (8 if args.small else 8)
    ckpt_dir = args.ckpt_dir or f"/tmp/repro_{cfg.name}"
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params ({cfg.num_layers}L d{cfg.d_model}) "
          f"seq {seq} batch {gb}")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=gb, seed=0)

    def make_loop(attempt: int) -> TrainLoop:
        lc = LoopConfig(steps=args.steps, ckpt_every=max(args.steps // 3, 10),
                        ckpt_dir=ckpt_dir, log_every=20,
                        peak_lr=args.peak_lr, warmup=min(50, args.steps // 4),
                        fail_at_step=args.fail_at_step if attempt == 0 else None)
        return TrainLoop(cfg, data, lc)

    metrics = run_with_restarts(make_loop)
    losses = metrics.losses
    k = min(20, max(len(losses) // 5, 1))
    print(f"\nfirst-{k} mean loss {np.mean(losses[:k]):.3f} → "
          f"last-{k} mean loss {np.mean(losses[-k:]):.3f}")
    print(f"step time p50 {np.percentile(metrics.step_times, 50)*1e3:.0f} ms; "
          f"straggler events at {metrics.straggler_events}; "
          f"restored_from={metrics.restored_from}")
    if len(losses) >= 40:
        assert np.mean(losses[-k:]) < np.mean(losses[:k]), "no learning signal?"
        print("loss decreased — data pipeline, model, optimizer, checkpointing OK")


if __name__ == "__main__":
    main()
