"""Store-scaling benchmark: open time and bytes read vs record count,
indexed (lazy) vs full-load (DESIGN.md §13, ISSUE 5 acceptance).

The fleet-scale claim under measurement: opening a store and resolving ONE
serving cell must cost O(hot set), not O(store). For each record count the
bench builds a directory store of ``FLEET_CELLS`` fingerprints (one hot
cell with a fixed small record count, the rest cold bulk — the shape a
shared fleet store has), then measures, for full-load vs indexed open:

  * wall time to open + resolve the hot cell (``best`` + ``records``);
  * bytes of segment/index data read to do it (``store.bytes_read``);
  * and asserts the two paths return byte-identical results.

The committed curve lives in ``results/bench/store_scaling.json``; the
acceptance bar is >=10x less data read and >=5x faster open at the top of
the curve. ``--smoke`` (CI) runs a small count and checks the equivalence +
ratio machinery; the full curve (nightly) climbs to 10^6 records.

  PYTHONPATH=src python -m benchmarks.store_bench [--smoke] [--records N]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

from benchmarks.common import emit, save_json
from repro.core.searchspace import Param, SearchSpace
from repro.store import (SpaceFingerprint, TuningRecordStore, build_index,
                         write_index)

FLEET_CELLS = 64          # distinct fingerprints (serving cells) per store
HOT_RECORDS = 64          # records under the one cell a server resolves
SEGMENT_RECORDS = 200_000  # writer rollover cadence for the bulk
RUN_BLOCK = 512           # contiguous records per tuning run (how real
                          # journals land: one run streams one fingerprint)

SPACE = SearchSpace([Param("a", (0, 1, 2, 3)), Param("b", (0, 1, 2)),
                     Param("c", (0, 1))], name="bench")


def _fps(n: int):
    return [SpaceFingerprint.of(SPACE, objective=f"bench@cell{i}")
            for i in range(n)]


def build_store(path: str, n_records: int):
    """Write a fleet-shaped store of ``n_records`` observations: the hot
    cell's HOT_RECORDS plus cold bulk spread over the other cells, rolled
    into a new segment every SEGMENT_RECORDS. Lines are written through a
    buffered handle (the per-record-flush appender would make store
    CONSTRUCTION the bottleneck, and construction is not what's measured)
    in the exact on-disk format ``TuningRecordStore.append`` produces."""
    fps = _fps(FLEET_CELLS)
    hot = fps[0]
    os.makedirs(path, exist_ok=True)
    n_bulk = max(n_records - HOT_RECORDS, 0)
    written = 0
    seg_idx = 0
    f = None
    fp_written: set = set()
    try:
        for i in range(n_records):
            if f is None or written % SEGMENT_RECORDS == 0:
                if f is not None:
                    f.close()
                f = open(os.path.join(path, f"segment-1-{seg_idx}.jsonl"),
                         "w")
                seg_idx += 1
                fp_written = set()
            if i < n_bulk:
                fp = fps[1 + (i // RUN_BLOCK) % (FLEET_CELLS - 1)]
                seq, value = i, 1.0 + (i % 977) * 1e-3
            else:
                fp = hot
                seq = i - n_bulk
                value = 0.5 + ((seq * 7919) % HOT_RECORDS) * 1e-3
            if fp.digest not in fp_written:
                f.write(json.dumps(fp.to_json()) + "\n")
                fp_written.add(fp.digest)
            idx = seq % SPACE.size
            f.write(json.dumps({
                "kind": "obs", "fp": fp.digest, "run": f"w{seg_idx}",
                "seq": seq, "key": str(seq), "idx": idx, "value": value,
                "af": None, "config": SPACE.config(idx),
                "t": float(i)}) + "\n")
            written += 1
    finally:
        if f is not None:
            f.close()
    return hot


def _resolve(store, hot) -> tuple:
    best = store.best(hot.digest)
    recs = store.records(fp=hot.digest)
    return ([r.to_json() for r in recs],
            None if best is None else best.to_json())


def bench_one(n_records: int) -> dict:
    d = tempfile.mkdtemp(prefix=f"storebench-{n_records}-")
    path = os.path.join(d, "store")
    try:
        t0 = time.perf_counter()
        hot = build_store(path, n_records)
        t_build = time.perf_counter() - t0

        t0 = time.perf_counter()
        write_index(path, build_index(path))
        t_index = time.perf_counter() - t0

        t0 = time.perf_counter()
        full = TuningRecordStore(path)
        full_view = _resolve(full, hot)
        t_full = time.perf_counter() - t0

        t0 = time.perf_counter()
        lazy = TuningRecordStore(path, lazy=True)
        lazy_view = _resolve(lazy, hot)
        t_lazy = time.perf_counter() - t0

        assert lazy_view == full_view, \
            "lazy resolution must be byte-identical to full load"
        assert len(lazy) == len(full) == n_records
        seg_bytes = sum(os.path.getsize(os.path.join(path, f))
                        for f in os.listdir(path) if f.endswith(".jsonl"))
        return {"records": n_records, "segment_bytes": seg_bytes,
                "build_s": t_build, "index_build_s": t_index,
                "full": {"open_resolve_s": t_full,
                         "bytes_read": full.bytes_read},
                "indexed": {"open_resolve_s": t_lazy,
                            "bytes_read": lazy.bytes_read},
                "speedup": t_full / t_lazy,
                "read_reduction": full.bytes_read / max(lazy.bytes_read, 1)}
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: small store, equivalence + ratio sanity only")
    ap.add_argument("--records", type=int, default=None,
                    help="single run at this record count")
    args = ap.parse_args()
    if args.records is not None:
        counts = [args.records]
    elif args.smoke:
        counts = [20_000]
    else:
        counts = [10_000, 100_000, 1_000_000]

    rows = []
    for n in counts:
        row = bench_one(n)
        rows.append(row)
        emit(f"store_open_full_n{n}",
             row["full"]["open_resolve_s"] * 1e6,
             f"{row['full']['bytes_read']:,} B read")
        emit(f"store_open_indexed_n{n}",
             row["indexed"]["open_resolve_s"] * 1e6,
             f"{row['indexed']['bytes_read']:,} B read; "
             f"{row['speedup']:.1f}x faster, "
             f"{row['read_reduction']:.0f}x less data")
    top = rows[-1]
    if args.smoke:
        # the asymptotic bars are pinned at 10^6 nightly; the smoke run
        # only proves the machinery and a sane direction at small n
        assert top["read_reduction"] > 2 and top["speedup"] > 1, top
    else:
        assert top["read_reduction"] >= 10, \
            f"acceptance: >=10x less data read, got {top['read_reduction']:.1f}"
        assert top["speedup"] >= 5, \
            f"acceptance: >=5x faster open, got {top['speedup']:.1f}"
        save_json("store_scaling", {"cells": FLEET_CELLS,
                                    "hot_records": HOT_RECORDS,
                                    "rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    main()
