"""Figs. 6/7: unseen kernels (ExpDist 50.8% invalid, Adding) on the A100."""
from __future__ import annotations

from benchmarks.common import (emit, mdf_from_matrix, run_matrix, save_json,
                               strip_traces)

KERNELS = ("expdist", "adding")
STRATEGIES = ("advanced_multi", "multi", "ei",
              "genetic_algorithm", "mls", "simulated_annealing", "random")


def main(repeats: int = 7) -> dict:
    matrix = run_matrix(KERNELS, "a100", STRATEGIES, repeats,
                        random_repeats=max(repeats * 2, 10))
    mdf = mdf_from_matrix(matrix)
    for kernel, d in matrix.items():
        for strat, v in d.items():
            emit(f"fig6_7/{kernel}/{strat}", v["mean_wall_s"] * 1e6,
                 f"mae={v['mean_mae']:.4f}")
    for strat, v in mdf.items():
        emit(f"fig6_7/mdf/{strat}", 0.0, f"mdf={v['mdf']:.4f}")
    save_json("fig6_7", {"matrix": strip_traces(matrix), "mdf": mdf})
    return {"matrix": matrix, "mdf": mdf}


if __name__ == "__main__":
    main()
