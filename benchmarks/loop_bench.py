"""Online-loop microbenchmarks: the serve-side cost of staying live.

Every decode step in ``--online`` serving pays (a) a store poll when the
tail is quiet and (b) a swap decision when records land. Both sit on the
latency path between decode batches, so they must be cheap relative to a
decode step (~tens of ms):

  * ``poll_quiet``    — StoreWatcher.poll() on an unchanged store (stat-only
                        fast path), the per-step steady-state cost;
  * ``tail_follow``   — records/s a tail-following reader sustains against
                        a per-record-flushing writer (the full parse path);
  * ``hot_resolve``   — HotConfigSource.refresh() folding one freshly landed
                        record into the deployed-best decision.

  PYTHONPATH=src python -m benchmarks.loop_bench [--smoke]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

from benchmarks.common import emit, save_json
from repro.core.tuning_targets import sharding_space
from repro.store import (HotConfigSource, SpaceFingerprint, StoreWatcher,
                         TuningRecord, TuningRecordStore, cell_objective)

ARCH, SHAPE = "internlm2-1.8b", "decode_32k"


def _mk_store(path: str):
    space = sharding_space(ARCH, SHAPE)
    fp = SpaceFingerprint.of(space, objective=cell_objective(ARCH, SHAPE))
    store = TuningRecordStore(path)
    return space, fp, store


def _rec(space, fp, seq: int, value: float) -> TuningRecord:
    idx = seq % space.size
    return TuningRecord(fp=fp.digest, run="bench", seq=seq, key=str(idx),
                        idx=idx, value=value, config=space.config(idx))


def bench_poll_quiet(path: str, n: int) -> float:
    space, fp, store = _mk_store(os.path.join(path, "store"))
    store.append(_rec(space, fp, 0, 1.0), fingerprint=fp)
    store.close()
    # a store that has been quiet long enough for the watcher to trust its
    # segment-discovery cache — the steady state this bench measures
    aged = time.time() - 60
    os.utime(os.path.join(path, "store"), (aged, aged))
    watcher = StoreWatcher(os.path.join(path, "store"))
    watcher.poll()
    t0 = time.perf_counter()
    for _ in range(n):
        watcher.poll()
    return (time.perf_counter() - t0) / n * 1e6


def bench_tail_follow(path: str, n: int) -> float:
    space, fp, store = _mk_store(os.path.join(path, "store"))
    watcher = StoreWatcher(os.path.join(path, "store"))
    t0 = time.perf_counter()
    got = 0
    for seq in range(n):
        store.append(_rec(space, fp, seq, 1.0 + seq * 1e-6), fingerprint=fp)
        got += len(watcher.poll())
    dt = time.perf_counter() - t0
    store.close()
    assert got == n, f"tail lost records: {got}/{n}"
    return n / dt


def bench_hot_resolve(path: str, n: int) -> float:
    space, fp, store = _mk_store(os.path.join(path, "store"))
    source = HotConfigSource(os.path.join(path, "store"), ARCH, SHAPE)
    swaps = 0
    t0 = time.perf_counter()
    for seq in range(n):
        # each record strictly better: every refresh takes the swap path
        store.append(_rec(space, fp, seq, 1.0 - seq * 1e-4), fingerprint=fp)
        swaps += source.refresh() is not None
    dt = time.perf_counter() - t0
    store.close()
    assert swaps == n
    return (dt / n) * 1e6


def bench_decode_kernel_resolve(path: str, n: int) -> float:
    """HotConfigSource.refresh() over the DECODE kernel cell (ISSUE 8): the
    per-poll cost of keeping the per-token flash-decode blocks live while
    serving. Imports jax lazily — the rest of this bench stays jax-free."""
    from repro.kernels.tuning import decode_cell
    cell = decode_cell(1, 512, 4, 2, 16)
    fp = SpaceFingerprint.of(cell.space, objective=cell.objective_id())
    store = TuningRecordStore(os.path.join(path, "store"))
    source = HotConfigSource.for_kernel_cell(os.path.join(path, "store"),
                                             cell)
    swaps = 0
    t0 = time.perf_counter()
    for seq in range(n):
        idx = seq % cell.space.size
        store.append(TuningRecord(
            fp=fp.digest, run="bench", seq=seq, key=str(idx), idx=idx,
            value=1.0 - seq * 1e-4, config=cell.space.config(idx)),
            fingerprint=fp)
        swaps += source.refresh() is not None
    dt = time.perf_counter() - t0
    store.close()
    assert swaps == n
    return (dt / n) * 1e6


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    n = 200 if args.smoke else 2000

    rows = {}
    for name, fn, unit in (("poll_quiet", bench_poll_quiet, "us/poll"),
                           ("tail_follow", bench_tail_follow, "records/s"),
                           ("hot_resolve", bench_hot_resolve, "us/refresh"),
                           ("decode_kernel_resolve",
                            bench_decode_kernel_resolve, "us/refresh")):
        d = tempfile.mkdtemp(prefix=f"loopbench-{name}-")
        try:
            val = fn(d, n)
        finally:
            shutil.rmtree(d, ignore_errors=True)
        rows[name] = {"value": val, "unit": unit, "n": n}
        emit(f"loop_{name}", val if unit != "records/s" else 1e6 / val,
             f"{val:,.0f} {unit}")
    if not args.smoke:
        save_json("online_loop", rows)
    return rows


if __name__ == "__main__":
    main()
