"""Parallel evaluation engine throughput (the refactor's acceptance bar).

The objective is the toy simulated space wrapped in a per-eval sleep, which
models the real cost profile: compile-and-run dominates, the strategy math is
noise. At equal budget, ``--workers 8`` must cut tuning wall-clock by >= 4x
vs ``--workers 1`` for batchable strategies (BO constant-liar, random, GA).

  PYTHONPATH=src python -m benchmarks.run --only engine [--workers 8]
"""
from __future__ import annotations

import math
import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit, save_json
from repro.core.objectives import Objective, SimulatedObjective
from repro.core.runner import run_strategy
from repro.core.searchspace import Param, SearchSpace
from repro.core.strategies import make_strategy

DELAY_S = 0.01       # simulated compile-and-run latency per evaluation
BUDGET = 64


class SlowObjective(Objective):
    def __init__(self, inner: Objective, delay_s: float = DELAY_S):
        self.inner, self.delay_s = inner, delay_s
        self.space, self.name = inner.space, "slow_" + inner.name

    def __call__(self, idx: int) -> float:
        time.sleep(self.delay_s)
        return self.inner(idx)


def _toy(seed=0, n=400, invalid_frac=0.2):
    rng = np.random.default_rng(seed)
    space = SearchSpace([Param("a", tuple(range(20))),
                         Param("b", tuple(range(20)))], name="toy")
    x = space.X_norm
    times = 1.0 + 5 * ((x[:, 0] - 0.3) ** 2 + (x[:, 1] - 0.7) ** 2) \
        + 0.3 * np.sin(7 * x[:, 0]) * np.cos(5 * x[:, 1])
    inv = rng.choice(n, int(invalid_frac * n), replace=False)
    times = times.astype(np.float64)
    times[inv] = math.nan
    return SimulatedObjective(space, times, name="toy")


def main(repeats: int = 3, workers: int = 0) -> None:
    workers = workers or (common.WORKERS if common.WORKERS > 1 else 8)
    payload = {}
    for strat in ("random", "ei", "advanced_multi", "genetic_algorithm"):
        seq_s, par_s = [], []
        for seed in range(repeats):
            obj = SlowObjective(_toy())
            t0 = time.time()
            r1 = run_strategy(make_strategy(strat), obj, budget=BUDGET,
                              seed=seed)
            seq_s.append(time.time() - t0)
            t0 = time.time()
            rw = run_strategy(make_strategy(strat), obj, budget=BUDGET,
                              seed=seed, workers=workers, batch_size=workers)
            par_s.append(time.time() - t0)
            assert rw.unique_evals == r1.unique_evals
        seq_us = float(np.mean(seq_s)) * 1e6 / BUDGET
        par_us = float(np.mean(par_s)) * 1e6 / BUDGET
        speedup = seq_us / par_us
        emit(f"engine/{strat}_seq_per_eval", seq_us, f"budget={BUDGET}")
        emit(f"engine/{strat}_w{workers}_per_eval", par_us,
             f"speedup={speedup:.1f}x")
        payload[strat] = {"seq_s": seq_s, "par_s": par_s, "workers": workers,
                          "speedup": speedup}
    save_json("engine_throughput", payload)


if __name__ == "__main__":
    main()
