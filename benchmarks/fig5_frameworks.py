"""Fig. 5: other-framework BO analogues vs ours (RTX 2070 Super spaces)."""
from __future__ import annotations

from benchmarks.common import (emit, mdf_from_matrix, run_matrix, save_json,
                               strip_traces)

KERNELS = ("gemm", "convolution", "pnpoly")
STRATEGIES = ("advanced_multi", "multi", "ei",
              "bayesopt_ucb", "skopt_gphedge", "random")


def main(repeats: int = 5) -> dict:
    matrix = run_matrix(KERNELS, "rtx_2070_super", STRATEGIES, repeats,
                        random_repeats=max(repeats * 2, 10))
    mdf = mdf_from_matrix(matrix)
    for kernel, d in matrix.items():
        for strat, v in d.items():
            emit(f"fig5/{kernel}/{strat}", v["mean_wall_s"] * 1e6,
                 f"mae={v['mean_mae']:.4f}")
    for strat, v in mdf.items():
        emit(f"fig5/mdf/{strat}", 0.0, f"mdf={v['mdf']:.4f}")
    save_json("fig5", {"matrix": strip_traces(matrix), "mdf": mdf})
    return {"matrix": matrix, "mdf": mdf}


if __name__ == "__main__":
    main()
