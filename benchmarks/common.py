"""Shared benchmark machinery: strategy×kernel matrices, CSV emission."""
from __future__ import annotations

import json
import math
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.metrics import mae, mdf_table  # noqa: E402
from repro.core.runner import run_strategy  # noqa: E402
from repro.core.spaces import make_objective  # noqa: E402
from repro.core.strategies import make_strategy  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")

# Evaluation parallelism for every matrix run; set by ``benchmarks.run
# --workers N``. workers=1 keeps the bit-for-bit sequential path.
WORKERS = 1
BATCH_SIZE = 1

# Tuning-record store (repro.store) every matrix run journals into; set by
# ``benchmarks.run --store PATH``. None disables persistence. Benchmark runs
# never warm-start from it — paper-parity results must stay cold — they only
# PRODUCE records (fig1/fig4/fig6_7 journals share the engine schema).
STORE = None


def emit(name: str, us_per_call: float, derived) -> None:
    """The run.py contract: ``name,us_per_call,derived`` CSV rows."""
    print(f"{name},{us_per_call:.1f},{derived}")


def run_matrix(kernels: Sequence[str], gpu: str, strategies: Sequence[str],
               repeats: int, budget: int = 220,
               random_repeats: Optional[int] = None,
               workers: Optional[int] = None,
               batch_size: Optional[int] = None,
               store=None) -> Dict:
    """Per (kernel, strategy): traces + mean MAE (paper methodology)."""
    workers = WORKERS if workers is None else workers
    batch_size = BATCH_SIZE if batch_size is None else batch_size
    store = STORE if store is None else store
    if isinstance(store, str):
        # open once: a path per run would reload every segment per run
        from repro.store import TuningRecordStore
        store = TuningRecordStore(store)
    out: Dict[str, Dict[str, Dict]] = {}
    for kernel in kernels:
        obj = make_objective(kernel, gpu)
        out[kernel] = {}
        for strat in strategies:
            reps = (random_repeats or repeats) if strat == "random" else repeats
            traces, times = [], []
            for seed in range(reps):
                t0 = time.time()
                res = run_strategy(make_strategy(strat), obj, budget=budget,
                                   seed=seed, workers=workers,
                                   batch_size=batch_size,
                                   store=store, warm_start=False)
                times.append(time.time() - t0)
                traces.append(res.trace)
            maes = [mae(t, obj.optimum) for t in traces]
            out[kernel][strat] = {
                "mean_mae": float(np.mean(maes)),
                "std_mae": float(np.std(maes)),
                "mean_wall_s": float(np.mean(times)),
                "best_final": float(np.mean([t[min(len(t), budget) - 1]
                                             for t in traces])),
                "optimum": obj.optimum,
                "traces": [t.tolist() for t in traces],
            }
    return out


def mdf_from_matrix(matrix: Dict) -> Dict:
    per_kernel = {k: {s: v["mean_mae"] for s, v in d.items()}
                  for k, d in matrix.items()}
    return mdf_table(per_kernel)


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    slim = json.loads(json.dumps(payload, default=float))
    with open(path, "w") as f:
        json.dump(slim, f, indent=1)
    return path


def strip_traces(matrix: Dict) -> Dict:
    return {k: {s: {kk: vv for kk, vv in v.items() if kk != "traces"}
                for s, v in d.items()} for k, d in matrix.items()}
