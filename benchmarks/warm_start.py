"""Transfer-aware warm-start benchmark (the store layer's acceptance bar).

Fig6/7-style unseen scenario: BO has prior records for a kernel at one
problem size (the store holds journals from ``--source-runs`` tuning runs)
and is then pointed at the SAME kernel family at a DIFFERENT problem size —
a compatible-but-not-identical space (size-specific trim: different kept
configs, different indices) with a correlated-but-not-identical runtime
surface. Cross-size records are nearest-neighbor matched into the new space
with a discounted GP noise term (repro.store.transfer).

Metric: unique evaluations until the warm-started run reaches the cold
run's final best value, per seed, against the cold run's own
evaluations-to-best. Acceptance: >= 30% fewer (mean over seeds).

  PYTHONPATH=src python -m benchmarks.warm_start [--smoke]
  PYTHONPATH=src python -m benchmarks.run --only warm_start
"""
from __future__ import annotations

import argparse
import shutil
import sys
import tempfile

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.runner import run_strategy
from repro.core.spaces import make_scenario_objective
from repro.core.strategies import make_strategy
from repro.store import TuningRecordStore

KERNEL, GPU = "expdist", "a100"
SOURCE_SIZE, TARGET_SIZE = "seq512", "seq4096"
STRATEGY = "advanced_multi"
BUDGET = 220
SOURCE_RUNS = 3
TARGET_REDUCTION = 0.30


def evals_to_reach(trace: np.ndarray, value: float) -> int | None:
    """1-based unique-eval count at which best-so-far first reaches value."""
    hit = np.flatnonzero(trace <= value + 1e-12)
    return int(hit[0]) + 1 if hit.size else None


def main(repeats: int = 5, *, smoke: bool = False) -> dict:
    budget, source_runs = BUDGET, SOURCE_RUNS
    if smoke:
        repeats, budget, source_runs = max(min(repeats, 2), 1), 60, 1

    src = make_scenario_objective(KERNEL, GPU, SOURCE_SIZE)
    tgt = make_scenario_objective(KERNEL, GPU, TARGET_SIZE)
    store_path = tempfile.mkdtemp(prefix="warm_start_store_")
    for s in range(source_runs):
        res = run_strategy(make_strategy(STRATEGY), src, budget=budget,
                           seed=100 + s, store=store_path)
        emit(f"warm_start/source_run_{s}", res.wall_time_s * 1e6,
             f"best={res.best_value:.3f}")
    store = TuningRecordStore(store_path)   # read-only: record count below

    rows = []
    for seed in range(repeats):
        cold = run_strategy(make_strategy(STRATEGY), tgt, budget=budget,
                            seed=seed)
        # every warm seed gets a FRESH copy of the source-only store: a
        # shared one would leak earlier warm seeds' exact target-space
        # records, and the metric would measure record replay instead of
        # cross-size transfer
        seed_store = tempfile.mkdtemp(prefix="warm_start_seed_") + "/store"
        shutil.copytree(store_path, seed_store)
        warm = run_strategy(make_strategy(STRATEGY), tgt, budget=budget,
                            seed=seed, store=seed_store)
        c = evals_to_reach(cold.trace, cold.best_value)
        w = evals_to_reach(warm.trace, cold.best_value)
        rows.append({
            "seed": seed,
            "cold_best": float(cold.best_value),
            "warm_best": float(warm.best_value),
            "cold_evals_to_best": c,
            # a warm run that never reaches the cold best scores the full
            # budget — no silent optimism
            "warm_evals_to_cold_best": w,
            "warm_reached": w is not None,
        })
        emit(f"warm_start/seed{seed}", 0.0,
             f"cold={c} warm={w if w is not None else f'>{budget}'}")

    cold_mean = float(np.mean([r["cold_evals_to_best"] for r in rows]))
    warm_mean = float(np.mean([r["warm_evals_to_cold_best"]
                               if r["warm_evals_to_cold_best"] is not None
                               else budget for r in rows]))
    reduction = 1.0 - warm_mean / cold_mean
    payload = {
        "scenario": {"kernel": KERNEL, "gpu": GPU, "source": SOURCE_SIZE,
                     "target": TARGET_SIZE, "strategy": STRATEGY,
                     "budget": budget, "source_runs": source_runs,
                     "source_space": src.space.size,
                     "target_space": tgt.space.size,
                     "store_records": len(store)},
        "rows": rows,
        "cold_mean_evals_to_best": cold_mean,
        "warm_mean_evals_to_cold_best": warm_mean,
        "reduction": reduction,
        "acceptance": {"target_reduction": TARGET_REDUCTION,
                       "meets_target": reduction >= TARGET_REDUCTION},
    }
    emit("warm_start/reduction", 0.0, f"{reduction:.1%}")
    path = save_json("warm_start_smoke" if smoke else "warm_start", payload)
    print(f"# wrote {path}", file=sys.stderr)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 1 source run, budget 60, 2 seeds")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args()
    main(args.repeats, smoke=args.smoke)
