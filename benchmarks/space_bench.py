"""Search-space layer scaling: enumeration throughput + neighbor latency.

The vectorized constraint layer's acceptance bar (ISSUE 2): a constrained
space with a >=10^7 Cartesian product must enumerate in seconds. For each
space size this measures

  * chunked vectorized enumeration + VectorConstraint filtering (configs/s),
    against the seed's itertools.product + per-row Python loop where that
    is still affordable (reference capped at 10^6 cartesian);
  * Hamming neighbor queries: CSR-index build + per-query slice latency on
    spaces small enough for the precomputed index, per-query vectorized
    on-demand latency above that, against the seed's tuple-dict probes;
  * config lookup (index_of) via sorted mixed-radix codes.

The generative backend (DESIGN.md §15) gets its own rows: construction
time, time-to-first-feasible-sample, feasible-walk neighbor latency, and
resident bytes against the enumerated twin at 10^7 cartesian (acceptance:
>=100x lighter) plus construction-only rows at 10^9+ where enumeration is
impossible (acceptance: sub-second).

Tight-constraint rows (ISSUE 10) pit rejection against the propagating
sampler on a 32^6 ≈ 1.07e9 cartesian whose feasible fraction is driven to
~1e-2 / 1e-4 / 1e-6 by stacking pairwise modular constraints: each row
measures time-to-first-sample and pool-seed (stratified) latency for a
pure-rejection space (``PROPAGATE_BELOW = -1`` pin; raises where the draw
budget exhausts) and for the shipping auto-routed sampler. The nightly
acceptance assert (``--assert-propagating-win``) requires the propagating
path to complete AND be no slower than rejection on every row at <= 1e-4.

Results land in results/bench/space_scaling.json.

  PYTHONPATH=src python -m benchmarks.space_bench [--smoke]
      [--assert-propagating-win]
  PYTHONPATH=src python -m benchmarks.run --only space
"""
from __future__ import annotations

import argparse
import itertools
import math
import sys
import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.searchspace import (GenerativeSpace, Param, SearchSpace,
                                    VectorConstraint)

#: (values per param, params, constrained): cartesian grows from CI-smoke to
#: the 10^7 bar. The final unconstrained row keeps all 10^7 configs, which
#: crosses X_NORM_LAZY_MIN: X_norm stays lazy (memory-curve row — the eager
#: float32 matrix would be ~280 MB).
GRID_SMALL = [(10, 4, True), (18, 4, True)]              # 1.0e4, 1.05e5
GRID_FULL = GRID_SMALL + [(32, 4, True), (8, 8, True),   # + 1.05e6, 1.68e7
                          (10, 7, False)]                # + 1.0e7 kept (lazy)
#: generative-backend rows (DESIGN.md §15). The (10, 7, False) twin pairs
#: with the enumerated 1e7 row above for the resident-bytes comparison; the
#: 10^9 / 10^12 rows are construction+sampling only — enumeration there is
#: physically impossible, which is the point.
GEN_GRID_SMOKE = [(18, 4, True), (10, 7, False)]         # 1.05e5, 1.0e7
GEN_GRID_FULL = GEN_GRID_SMOKE + [(32, 6, True),         # + 1.07e9
                                  (100, 6, True)]        # + 1.0e12
REFERENCE_MAX = 1_050_000                        # python loop above: minutes
N_NEIGHBOR_QUERIES = 512
#: tight rows: stacked pairwise modular constraints on a 32^6 grid, each
#: pair keeping TIGHT_PAIR_K/1024 of its plane — n pairs ⇒ ~(K/1024)^n
#: feasible fraction: 1 ⇒ ~1e-2, 2 ⇒ ~1e-4, 3 ⇒ ~1e-6
TIGHT_PAIR_K = 10
TIGHT_GRID_SMOKE = [1]
TIGHT_GRID_FULL = [1, 2, 3]


def _params(k: int, d: int):
    return [Param(f"p{j}", tuple(range(1, k + 1))) for j in range(d)]


def _constraint_fns(k: int):
    """Two restrictions keeping roughly half the space, numpy-elementwise so
    the same lambdas serve the vectorized and the per-row reference path."""
    cap = (k * k) // 2
    return [lambda c: c["p0"] * c["p1"] <= cap,
            lambda c: (c["p2"] + c["p3"]) % 4 != 0]


def _tight_constraints(n_pairs: int):
    """``n_pairs`` stacked pairwise restrictions over disjoint param pairs;
    each keeps ~TIGHT_PAIR_K/1024 of its (32 x 32) plane, so fractions
    multiply. Pairwise-over-disjoint-pairs is the worst reasonable case for
    rejection (fractions compound) while staying exactly the shape the
    per-dimension pruner resolves at each pair's second level."""
    cons = []
    for p in range(n_pairs):
        a, b = f"p{2 * p}", f"p{2 * p + 1}"
        cons.append(VectorConstraint(
            (lambda a, b: lambda c: (c[a] * 33 + c[b]) % 1024
             < TIGHT_PAIR_K)(a, b),
            name=f"tight_{a}x{b}"))
    return cons


def _tight_rows(rng: np.random.Generator, *, small: bool):
    """Rejection vs propagating on ~1e9-cartesian spaces of sinking
    feasible fraction. Fresh spaces per path so adaptive state (EWMA,
    dead-prefix memo) never leaks between the contestants."""
    pool_n = 256 if small else 2048
    out = []
    for n_pairs in (TIGHT_GRID_SMOKE if small else TIGHT_GRID_FULL):
        params = _params(32, 6)
        fraction = (TIGHT_PAIR_K / 1024.0) ** n_pairs
        row = {"cartesian": 32 ** 6, "n_constraints": n_pairs,
               "feasible_fraction_nominal": fraction, "pool_n": pool_n}

        # -- pure rejection (the pre-ISSUE-10 behavior, pinned) -------------
        rej = GenerativeSpace(params, _tight_constraints(n_pairs),
                              name=f"tight_rej_{n_pairs}")
        rej.PROPAGATE_BELOW = -1.0          # instance pin: legacy sampler
        t0 = time.perf_counter()
        try:
            rej.sample_feasible(rng, 1)
            row["rejection_first_sample_s"] = time.perf_counter() - t0
            row["rejection_raised"] = False
        except ValueError:
            row["rejection_first_sample_s"] = time.perf_counter() - t0
            row["rejection_raised"] = True
        t0 = time.perf_counter()
        try:
            rej_pool = rej.stratified_feasible(rng, pool_n)
            row["rejection_pool_seed_s"] = time.perf_counter() - t0
            row["rejection_pool_raised"] = False
            # rejection pads a short draw batch with duplicates: the pool
            # it returns may hold orders of magnitude fewer UNIQUE configs
            row["rejection_pool_unique"] = int(np.unique(rej_pool).size)
        except ValueError:
            row["rejection_pool_seed_s"] = time.perf_counter() - t0
            row["rejection_pool_raised"] = True
            row["rejection_pool_unique"] = 0

        # -- propagating sampler (the auto-router's below-threshold path) ---
        prop = GenerativeSpace(params, _tight_constraints(n_pairs),
                               name=f"tight_prop_{n_pairs}")
        prop._accept_ewma = 0.0             # pin the propagating path: the
        # row compares the two samplers, not the router's warmup luck
        t0 = time.perf_counter()
        first = prop.sample_feasible(rng, 1)
        row["prop_first_sample_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        pool = prop.stratified_feasible(rng, pool_n)
        row["prop_pool_seed_s"] = time.perf_counter() - t0
        assert prop._feasible_mask(first).all()
        assert prop._feasible_mask(pool).all()
        row["prop_pool_unique"] = int(np.unique(pool).size)
        row["prop_draws"] = int(prop._prop_draws)
        row["dead_prefixes_memoized"] = len(prop._dead_prefixes)
        # the honest pool metric is cost per UNIQUE feasible config: a
        # rejection pool that exhausts its budget returns mostly duplicate
        # padding, which seeds an acquisition round with nothing new
        rej_per_unique = (math.inf if row["rejection_pool_unique"] == 0
                          else row["rejection_pool_seed_s"]
                          / row["rejection_pool_unique"])
        prop_per_unique = (row["prop_pool_seed_s"]
                           / max(row["prop_pool_unique"], 1))
        row["rejection_pool_per_unique_s"] = (
            None if rej_per_unique == math.inf else rej_per_unique)
        row["prop_pool_per_unique_s"] = prop_per_unique
        # first-sample leg: no slower than rejection, or inside the
        # milliseconds bound when a lucky early rejection batch hit
        # (rejection's first-sample time is a high-variance draw; the
        # propagating DFS is deterministic)
        row["propagating_wins"] = bool(
            (row["rejection_raised"]
             or row["prop_first_sample_s"]
             <= max(row["rejection_first_sample_s"], 0.05))
            and prop_per_unique <= rej_per_unique)
        out.append(row)
        emit(f"space/tight_first_sample_f{fraction:.0e}",
             row["prop_first_sample_s"] * 1e6,
             "rejection " + ("RAISED" if row["rejection_raised"] else
                             f"{row['rejection_first_sample_s'] * 1e6:.0f}us"))
        emit(f"space/tight_pool_seed_f{fraction:.0e}",
             row["prop_pool_seed_s"] * 1e6, f"pool={pool_n}")
    return out


def _reference_enumerate(params, cons):
    """The seed implementation, kept as the throughput baseline."""
    kept = []
    for idx_tuple in itertools.product(*[range(len(p.values)) for p in params]):
        cfg = {p.name: p.values[idx_tuple[j]] for j, p in enumerate(params)}
        if all(c(cfg) for c in cons):
            kept.append(idx_tuple)
    return np.asarray(kept, dtype=np.int32)


def _time_queries(space: SearchSpace, rng: np.random.Generator, n: int):
    ids = rng.integers(0, space.size, size=n)
    t0 = time.perf_counter()
    total = 0
    for i in ids:
        total += len(space.hamming_neighbors(int(i)))
    return (time.perf_counter() - t0) / n, total / n


def _time_dict_probes(space: SearchSpace, rng: np.random.Generator, n: int):
    """Seed-style neighbor queries: tuple dict + per-candidate probes."""
    lookup = {tuple(row): i for i, row in enumerate(space.value_indices)}
    ids = rng.integers(0, space.size, size=n)
    t0 = time.perf_counter()
    for i in ids:
        row = space.value_indices[int(i)]
        out = []
        for j, p in enumerate(space.params):
            for v in range(len(p.values)):
                if v == row[j]:
                    continue
                k = lookup.get(tuple(row[:j]) + (v,) + tuple(row[j + 1:]))
                if k is not None:
                    out.append(k)
    return (time.perf_counter() - t0) / n


def main(repeats: int = 0, *, small: bool = False,
         assert_propagating_win: bool = False) -> None:
    # `repeats` honors the benchmarks.run suite convention (fn(reps) for a
    # global --repeats override); enumeration timings are single-shot, so
    # extra repeats only re-run the grid and keep the last measurement.
    del repeats
    rng = np.random.default_rng(0)
    rows = []
    for k, d, constrained in (GRID_SMALL if small else GRID_FULL):
        params = _params(k, d)
        cons = ([VectorConstraint(fn) for fn in _constraint_fns(k)]
                if constrained else [])
        t0 = time.perf_counter()
        space = SearchSpace(params, cons, name=f"bench_{k}x{d}")
        t_enum = time.perf_counter() - t0
        row = {"cartesian": space.cartesian_size, "constrained": space.size,
               "params": d, "values_per_param": k,
               "enumerate_s": t_enum,
               "configs_per_s": space.cartesian_size / max(t_enum, 1e-9),
               # memory curve: eager X_norm is float32 (N, d); above
               # X_NORM_LAZY_MIN rows are chunk-computed on demand instead
               "x_norm_mode": "lazy" if space.x_norm_lazy else "eager",
               "x_norm_resident_bytes": (0 if space.x_norm_lazy
                                         else space.X_norm.nbytes),
               "x_norm_eager_equiv_bytes": space.size * space.dim * 4,
               "resident_bytes": space.resident_bytes}
        if space.x_norm_lazy:
            # the candidate-pool access pattern: gather a pool of rows +
            # snap LHS points, all without materializing (N, d)
            pool = rng.integers(0, space.size, size=2048)
            t0 = time.perf_counter()
            space.X_norm[pool]
            row["x_norm_pool_gather_s"] = time.perf_counter() - t0
            pts = rng.random((64, space.dim), dtype=np.float32)
            t0 = time.perf_counter()
            space.nearest_indices(pts)
            row["nearest_indices_64_s"] = time.perf_counter() - t0

        if space.cartesian_size <= REFERENCE_MAX:
            t0 = time.perf_counter()
            ref = _reference_enumerate(params, _constraint_fns(k))
            row["reference_python_s"] = time.perf_counter() - t0
            row["speedup_vs_python"] = row["reference_python_s"] / max(t_enum, 1e-9)
            assert len(ref) == space.size
            t0 = time.perf_counter()
            row["dict_probe_query_s"] = _time_dict_probes(
                space, rng, min(N_NEIGHBOR_QUERIES, 128))

        # neighbor queries: first call may build the CSR index — time it apart
        t0 = time.perf_counter()
        space.hamming_neighbors(0)
        row["neighbor_index_build_s"] = time.perf_counter() - t0
        row["neighbor_index"] = ("csr" if space._h_csr is not None
                                 else "on_demand")
        q_s, deg = _time_queries(space, rng, N_NEIGHBOR_QUERIES)
        row["neighbor_query_s"] = q_s
        row["mean_degree"] = deg
        if row["neighbor_index"] == "on_demand":
            # local searches re-query the incumbent neighborhood: the partial
            # CSR frontier over the visited region serves repeats from memo
            ids = rng.integers(0, space.size, size=N_NEIGHBOR_QUERIES)
            for i in ids:
                space.hamming_neighbors(int(i))      # populate frontier
            t0 = time.perf_counter()
            for i in ids:
                space.hamming_neighbors(int(i))      # repeat: cached
            row["neighbor_query_cached_s"] = ((time.perf_counter() - t0)
                                              / len(ids))

        ids = rng.integers(0, space.size, size=256)
        cfgs = [space.config(int(i)) for i in ids]
        t0 = time.perf_counter()
        for cfg, i in zip(cfgs, ids):
            assert space.index_of(cfg) == int(i)
        row["index_of_s"] = (time.perf_counter() - t0) / len(cfgs)

        rows.append(row)
        emit(f"space/enum_{space.cartesian_size}", t_enum * 1e6,
             f"{row['configs_per_s']:.0f}cfg/s")
        emit(f"space/neighbors_{space.cartesian_size}", q_s * 1e6,
             row["neighbor_index"])

    # -- generative backend (DESIGN.md §15): no enumeration at any size -----
    gen_rows = []
    for k, d, constrained in (GEN_GRID_SMOKE if small else GEN_GRID_FULL):
        params = _params(k, d)
        cons = ([VectorConstraint(fn) for fn in _constraint_fns(k)]
                if constrained else [])
        t0 = time.perf_counter()
        space = GenerativeSpace(params, cons, name=f"gen_{k}x{d}")
        t_construct = time.perf_counter() - t0
        t0 = time.perf_counter()
        first = int(space.sample_feasible(rng, 1)[0])
        t_first = time.perf_counter() - t0
        t0 = time.perf_counter()
        batch = space.sample_feasible(rng, 256)
        t_batch = time.perf_counter() - t0
        t0 = time.perf_counter()
        strata = space.stratified_feasible(rng, 256)
        t_strata = time.perf_counter() - t0
        # feasible-walk neighbor queries on sampled incumbents (cold, then
        # the memo-hit repeat a local search actually pays)
        probes = [int(g) for g in batch[:64]] or [first]
        t0 = time.perf_counter()
        for g in probes:
            space.hamming_neighbors(g)
        t_nbr = (time.perf_counter() - t0) / len(probes)
        t0 = time.perf_counter()
        for g in probes:
            space.hamming_neighbors(g)
        t_nbr_cached = (time.perf_counter() - t0) / len(probes)
        cfgs = [space.config(int(g)) for g in strata[:128]]
        t0 = time.perf_counter()
        for cfg, g in zip(cfgs, strata):
            assert space.index_of(cfg) == int(g)
        t_lookup = (time.perf_counter() - t0) / len(cfgs)
        row = {"cartesian": space.cartesian_size, "params": d,
               "values_per_param": k, "constrained_grid": constrained,
               "construct_s": t_construct,
               "first_feasible_sample_s": t_first,
               "sample_256_s": t_batch, "stratified_256_s": t_strata,
               "neighbor_walk_s": t_nbr,
               "neighbor_walk_cached_s": t_nbr_cached,
               "index_of_s": t_lookup,
               "resident_bytes": space.resident_bytes,
               "accept_rate_ewma": space._accept_ewma}
        gen_rows.append(row)
        emit(f"space/generative_construct_{space.cartesian_size}",
             t_construct * 1e6, f"{space.resident_bytes}B resident")
        emit(f"space/generative_first_sample_{space.cartesian_size}",
             t_first * 1e6, f"accept~{space._accept_ewma:.2f}")

    # -- tight-constraint rows: rejection vs propagating (ISSUE 10) ---------
    tight_rows = _tight_rows(rng, small=small)

    biggest = rows[-1]
    acceptance = {
        "cartesian": biggest["cartesian"],
        "enumerate_s": biggest["enumerate_s"],
        "meets_1e7_in_seconds": (biggest["cartesian"] >= 10_000_000
                                 and biggest["enumerate_s"] < 30.0)
        if not small else None}
    # §15 acceptance: >=100x lighter than the enumerated twin at 1e7, and
    # 10^9+ grids must construct in well under a second
    twins = {r["cartesian"]: r for r in rows}
    for g in gen_rows:
        twin = twins.get(g["cartesian"])
        if twin is not None:
            g["resident_ratio_vs_enumerated"] = (
                twin["resident_bytes"] / max(g["resident_bytes"], 1))
    at_1e7 = [g for g in gen_rows
              if g["cartesian"] >= 10_000_000
              and "resident_ratio_vs_enumerated" in g]
    huge = [g for g in gen_rows if g["cartesian"] >= 10 ** 9]
    acceptance["generative_resident_ratio_1e7"] = (
        min(g["resident_ratio_vs_enumerated"] for g in at_1e7)
        if at_1e7 else None)
    acceptance["generative_meets_100x_at_1e7"] = (
        acceptance["generative_resident_ratio_1e7"] is not None
        and acceptance["generative_resident_ratio_1e7"] >= 100.0)
    acceptance["generative_construct_1e9_s"] = (
        max(g["construct_s"] for g in huge) if huge else None)
    acceptance["generative_subsecond_at_1e9"] = (
        (acceptance["generative_construct_1e9_s"] is not None
         and acceptance["generative_construct_1e9_s"] < 1.0)
        if not small else None)
    # ISSUE 10 acceptance: at feasible fraction <= 1e-4 on the 1e9 grid,
    # the propagating path must complete in milliseconds AND be no slower
    # than rejection (which raises or stalls there)
    hard_tight = [t for t in tight_rows
                  if t["feasible_fraction_nominal"] <= 1e-4]
    acceptance["propagating_wins_at_1e-4_and_below"] = (
        all(t["propagating_wins"] for t in hard_tight)
        if hard_tight else None)
    acceptance["propagating_first_sample_worst_s"] = (
        max(t["prop_first_sample_s"] for t in tight_rows)
        if tight_rows else None)

    payload = {"rows": rows, "generative_rows": gen_rows,
               "tight_rows": tight_rows, "acceptance": acceptance}
    path = save_json("space_scaling", payload)
    print(f"# wrote {path}", file=sys.stderr)
    if assert_propagating_win:
        ok = acceptance["propagating_wins_at_1e-4_and_below"]
        if ok is None:
            print("# --assert-propagating-win needs the full grid "
                  "(no rows at <= 1e-4 in --smoke)", file=sys.stderr)
            sys.exit(2)
        if not ok:
            losers = [t for t in hard_tight if not t["propagating_wins"]]
            print(f"# ACCEPTANCE FAILED: propagating slower than rejection "
                  f"on {len(losers)} tight row(s): {losers}",
                  file=sys.stderr)
            sys.exit(1)
        print("# acceptance ok: propagating <= rejection at <= 1e-4",
              file=sys.stderr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--small", dest="smoke", action="store_true",
                    help="CI smoke grid (enumerated cartesian <= ~1e5, "
                         "generative <= 1e7, tight rows at ~1e-2 only)")
    ap.add_argument("--assert-propagating-win", action="store_true",
                    help="exit nonzero unless the propagating sampler "
                         "completes and is no slower than rejection on "
                         "every tight row at feasible fraction <= 1e-4 "
                         "(nightly acceptance)")
    args = ap.parse_args()
    main(small=args.smoke,
         assert_propagating_win=args.assert_propagating_win)
