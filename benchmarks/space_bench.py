"""Search-space layer scaling: enumeration throughput + neighbor latency.

The vectorized constraint layer's acceptance bar (ISSUE 2): a constrained
space with a >=10^7 Cartesian product must enumerate in seconds. For each
space size this measures

  * chunked vectorized enumeration + VectorConstraint filtering (configs/s),
    against the seed's itertools.product + per-row Python loop where that
    is still affordable (reference capped at 10^6 cartesian);
  * Hamming neighbor queries: CSR-index build + per-query slice latency on
    spaces small enough for the precomputed index, per-query vectorized
    on-demand latency above that, against the seed's tuple-dict probes;
  * config lookup (index_of) via sorted mixed-radix codes.

Results land in results/bench/space_scaling.json.

  PYTHONPATH=src python -m benchmarks.space_bench [--small]
  PYTHONPATH=src python -m benchmarks.run --only space
"""
from __future__ import annotations

import argparse
import itertools
import sys
import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.searchspace import Param, SearchSpace, VectorConstraint

#: (values per param, params, constrained): cartesian grows from CI-smoke to
#: the 10^7 bar. The final unconstrained row keeps all 10^7 configs, which
#: crosses X_NORM_LAZY_MIN: X_norm stays lazy (memory-curve row — the eager
#: float32 matrix would be ~280 MB).
GRID_SMALL = [(10, 4, True), (18, 4, True)]              # 1.0e4, 1.05e5
GRID_FULL = GRID_SMALL + [(32, 4, True), (8, 8, True),   # + 1.05e6, 1.68e7
                          (10, 7, False)]                # + 1.0e7 kept (lazy)
REFERENCE_MAX = 1_050_000                        # python loop above: minutes
N_NEIGHBOR_QUERIES = 512


def _params(k: int, d: int):
    return [Param(f"p{j}", tuple(range(1, k + 1))) for j in range(d)]


def _constraint_fns(k: int):
    """Two restrictions keeping roughly half the space, numpy-elementwise so
    the same lambdas serve the vectorized and the per-row reference path."""
    cap = (k * k) // 2
    return [lambda c: c["p0"] * c["p1"] <= cap,
            lambda c: (c["p2"] + c["p3"]) % 4 != 0]


def _reference_enumerate(params, cons):
    """The seed implementation, kept as the throughput baseline."""
    kept = []
    for idx_tuple in itertools.product(*[range(len(p.values)) for p in params]):
        cfg = {p.name: p.values[idx_tuple[j]] for j, p in enumerate(params)}
        if all(c(cfg) for c in cons):
            kept.append(idx_tuple)
    return np.asarray(kept, dtype=np.int32)


def _time_queries(space: SearchSpace, rng: np.random.Generator, n: int):
    ids = rng.integers(0, space.size, size=n)
    t0 = time.perf_counter()
    total = 0
    for i in ids:
        total += len(space.hamming_neighbors(int(i)))
    return (time.perf_counter() - t0) / n, total / n


def _time_dict_probes(space: SearchSpace, rng: np.random.Generator, n: int):
    """Seed-style neighbor queries: tuple dict + per-candidate probes."""
    lookup = {tuple(row): i for i, row in enumerate(space.value_indices)}
    ids = rng.integers(0, space.size, size=n)
    t0 = time.perf_counter()
    for i in ids:
        row = space.value_indices[int(i)]
        out = []
        for j, p in enumerate(space.params):
            for v in range(len(p.values)):
                if v == row[j]:
                    continue
                k = lookup.get(tuple(row[:j]) + (v,) + tuple(row[j + 1:]))
                if k is not None:
                    out.append(k)
    return (time.perf_counter() - t0) / n


def main(repeats: int = 0, *, small: bool = False) -> None:
    # `repeats` honors the benchmarks.run suite convention (fn(reps) for a
    # global --repeats override); enumeration timings are single-shot, so
    # extra repeats only re-run the grid and keep the last measurement.
    del repeats
    rng = np.random.default_rng(0)
    rows = []
    for k, d, constrained in (GRID_SMALL if small else GRID_FULL):
        params = _params(k, d)
        cons = ([VectorConstraint(fn) for fn in _constraint_fns(k)]
                if constrained else [])
        t0 = time.perf_counter()
        space = SearchSpace(params, cons, name=f"bench_{k}x{d}")
        t_enum = time.perf_counter() - t0
        row = {"cartesian": space.cartesian_size, "constrained": space.size,
               "params": d, "values_per_param": k,
               "enumerate_s": t_enum,
               "configs_per_s": space.cartesian_size / max(t_enum, 1e-9),
               # memory curve: eager X_norm is float32 (N, d); above
               # X_NORM_LAZY_MIN rows are chunk-computed on demand instead
               "x_norm_mode": "lazy" if space.x_norm_lazy else "eager",
               "x_norm_resident_bytes": (0 if space.x_norm_lazy
                                         else space.X_norm.nbytes),
               "x_norm_eager_equiv_bytes": space.size * space.dim * 4}
        if space.x_norm_lazy:
            # the candidate-pool access pattern: gather a pool of rows +
            # snap LHS points, all without materializing (N, d)
            pool = rng.integers(0, space.size, size=2048)
            t0 = time.perf_counter()
            space.X_norm[pool]
            row["x_norm_pool_gather_s"] = time.perf_counter() - t0
            pts = rng.random((64, space.dim), dtype=np.float32)
            t0 = time.perf_counter()
            space.nearest_indices(pts)
            row["nearest_indices_64_s"] = time.perf_counter() - t0

        if space.cartesian_size <= REFERENCE_MAX:
            t0 = time.perf_counter()
            ref = _reference_enumerate(params, _constraint_fns(k))
            row["reference_python_s"] = time.perf_counter() - t0
            row["speedup_vs_python"] = row["reference_python_s"] / max(t_enum, 1e-9)
            assert len(ref) == space.size
            t0 = time.perf_counter()
            row["dict_probe_query_s"] = _time_dict_probes(
                space, rng, min(N_NEIGHBOR_QUERIES, 128))

        # neighbor queries: first call may build the CSR index — time it apart
        t0 = time.perf_counter()
        space.hamming_neighbors(0)
        row["neighbor_index_build_s"] = time.perf_counter() - t0
        row["neighbor_index"] = ("csr" if space._h_csr is not None
                                 else "on_demand")
        q_s, deg = _time_queries(space, rng, N_NEIGHBOR_QUERIES)
        row["neighbor_query_s"] = q_s
        row["mean_degree"] = deg
        if row["neighbor_index"] == "on_demand":
            # local searches re-query the incumbent neighborhood: the partial
            # CSR frontier over the visited region serves repeats from memo
            ids = rng.integers(0, space.size, size=N_NEIGHBOR_QUERIES)
            for i in ids:
                space.hamming_neighbors(int(i))      # populate frontier
            t0 = time.perf_counter()
            for i in ids:
                space.hamming_neighbors(int(i))      # repeat: cached
            row["neighbor_query_cached_s"] = ((time.perf_counter() - t0)
                                              / len(ids))

        ids = rng.integers(0, space.size, size=256)
        cfgs = [space.config(int(i)) for i in ids]
        t0 = time.perf_counter()
        for cfg, i in zip(cfgs, ids):
            assert space.index_of(cfg) == int(i)
        row["index_of_s"] = (time.perf_counter() - t0) / len(cfgs)

        rows.append(row)
        emit(f"space/enum_{space.cartesian_size}", t_enum * 1e6,
             f"{row['configs_per_s']:.0f}cfg/s")
        emit(f"space/neighbors_{space.cartesian_size}", q_s * 1e6,
             row["neighbor_index"])

    biggest = rows[-1]
    payload = {"rows": rows,
               "acceptance": {
                   "cartesian": biggest["cartesian"],
                   "enumerate_s": biggest["enumerate_s"],
                   "meets_1e7_in_seconds": (biggest["cartesian"] >= 10_000_000
                                            and biggest["enumerate_s"] < 30.0)
                   if not small else None}}
    path = save_json("space_scaling", payload)
    print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="CI smoke grid (cartesian <= ~1e5)")
    args = ap.parse_args()
    main(small=args.small)
