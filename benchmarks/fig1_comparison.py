"""Fig. 1: our BO strategies vs Kernel Tuner baselines, GTX Titan X spaces."""
from __future__ import annotations

from benchmarks.common import (emit, mdf_from_matrix, run_matrix, save_json,
                               strip_traces)

KERNELS = ("gemm", "convolution", "pnpoly")
STRATEGIES = ("advanced_multi", "multi", "ei",
              "genetic_algorithm", "mls", "simulated_annealing", "random")


def main(repeats: int = 7) -> dict:
    matrix = run_matrix(KERNELS, "gtx_titan_x", STRATEGIES, repeats,
                        random_repeats=max(repeats * 2, 10))
    mdf = mdf_from_matrix(matrix)
    for kernel, d in matrix.items():
        for strat, v in d.items():
            emit(f"fig1/{kernel}/{strat}", v["mean_wall_s"] * 1e6,
                 f"mae={v['mean_mae']:.4f}")
    for strat, v in mdf.items():
        emit(f"fig1/mdf/{strat}", 0.0, f"mdf={v['mdf']:.4f}±{v['std']:.3f}")
    save_json("fig1", {"matrix": strip_traces(matrix), "mdf": mdf,
                       "repeats": repeats})
    return {"matrix": matrix, "mdf": mdf}


if __name__ == "__main__":
    main()
