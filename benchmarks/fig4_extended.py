"""Fig. 4: evaluations other strategies need to match EI's best at 220
(GEMM, GTX Titan X; cap 1020)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.metrics import evals_to_match
from repro.core.runner import run_strategy
from repro.core.spaces import make_objective
from repro.core.strategies import make_strategy

OTHERS = ("genetic_algorithm", "mls", "simulated_annealing", "random")
CAP = 1020


def main(repeats: int = 7) -> dict:
    obj = make_objective("gemm", "gtx_titan_x")
    ei_best = []
    for seed in range(repeats):
        res = run_strategy(make_strategy("ei"), obj, budget=220, seed=seed)
        ei_best.append(res.best_value)
    target = float(np.mean(ei_best))
    emit("fig4/ei_target", 0.0, f"best_at_220={target:.4f}")

    out = {"target": target, "others": {}}
    for strat in OTHERS:
        evals = []
        for seed in range(repeats):
            res = run_strategy(make_strategy(strat), obj, budget=CAP, seed=seed)
            evals.append(evals_to_match(res.trace, target, CAP))
        mean_evals = float(np.mean(evals))
        frac_matched = float(np.mean([e <= CAP for e in evals]))
        out["others"][strat] = {"mean_evals": mean_evals,
                                "frac_matched": frac_matched}
        emit(f"fig4/{strat}", 0.0,
             f"evals_to_match={mean_evals:.0f} matched={frac_matched:.0%}")
    save_json("fig4", out)
    return out


if __name__ == "__main__":
    main()
