"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Default repeats are reduced for a
single-core container; pass ``--repeats 35`` to reproduce the paper's
statistics exactly (EXPERIMENTS.md quotes a full run).

  PYTHONPATH=src python -m benchmarks.run [--only fig1,...] [--repeats N]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig4,fig5,fig6_7,"
                         "table1,kernels,roofline,perf,engine,space,"
                         "warm_start")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--workers", type=int, default=1,
                    help="parallel evaluation workers for every tuning run "
                         "(1 = the bit-for-bit sequential path)")
    ap.add_argument("--store", default=None,
                    help="tuning-record store (dir) every matrix run "
                         "journals into — fig1/fig4/fig6_7 results land in "
                         "the same schema as engine checkpoints and golden "
                         "traces (runs stay cold: no warm start)")
    args = ap.parse_args()

    from benchmarks import (common, engine_bench, fig1_comparison,
                            fig4_extended, fig5_frameworks, fig6_7_unseen,
                            kernel_bench, perf_hillclimb, roofline_table,
                            space_bench, table1_hyperparams, warm_start)

    common.WORKERS = max(args.workers, 1)
    common.BATCH_SIZE = max(args.workers, 1)
    common.STORE = args.store

    suite = {
        "fig1": (fig1_comparison.main, 7),
        "fig4": (fig4_extended.main, 5),
        "fig5": (fig5_frameworks.main, 3),
        "fig6_7": (fig6_7_unseen.main, 7),
        "table1": (table1_hyperparams.main, 5),
        "kernels": (kernel_bench.main, 3),
        "roofline": (roofline_table.main, 0),
        "perf": (perf_hillclimb.main, 0),
        "engine": (engine_bench.main, 3),
        "space": (space_bench.main, 0),
        "warm_start": (warm_start.main, 5),
    }
    only = args.only.split(",") if args.only else list(suite)
    for name in only:
        fn, default_reps = suite[name]
        reps = args.repeats if args.repeats is not None else default_reps
        t0 = time.time()
        print(f"# === {name} (repeats={reps}) ===", file=sys.stderr)
        fn(reps) if reps else fn()
        print(f"# === {name} done in {time.time() - t0:.1f}s ===",
              file=sys.stderr)


if __name__ == "__main__":
    main()
