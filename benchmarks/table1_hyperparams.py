"""Table I: hyperparameter re-tune of the BO defaults on our spaces.

A reduced grid over the axes the paper tuned: covariance (kernel,
lengthscale), exploration factor (CV vs constants), acquisition mode,
discount, improvement factor, initial sampling. Reported as mean MDF over
the three Titan X kernels (lower better).
"""
from __future__ import annotations

import itertools
import math
from typing import Dict

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.metrics import mae, mdf_table
from repro.core.runner import run_strategy
from repro.core.spaces import make_objective
from repro.core.strategies.bo import BOConfig, BOStrategy

KERNELS = ("gemm", "convolution", "pnpoly")

VARIANTS: Dict[str, BOConfig] = {
    # Table I winner
    "m32_l2.0_cv_advmulti": BOConfig(acquisition="advanced_multi",
                                     kernel="matern32", lengthscale_cv=1.5),
    "m32_l2.0_cv_multi": BOConfig(acquisition="multi", kernel="matern32"),
    "m32_l2.0_cv_ei": BOConfig(acquisition="ei", kernel="matern32"),
    # covariance alternatives
    "m52_l0.5_cv_advmulti": BOConfig(acquisition="advanced_multi",
                                     kernel="matern52", lengthscale_cv=0.5),
    "rbf_l1.0_cv_advmulti": BOConfig(acquisition="advanced_multi",
                                     kernel="rbf", lengthscale_cv=1.0),
    # constant exploration instead of CV
    "m32_l2.0_x0.01_advmulti": BOConfig(acquisition="advanced_multi",
                                        exploration=0.01, lengthscale=2.0),
    "m32_l2.0_x0.1_advmulti": BOConfig(acquisition="advanced_multi",
                                       exploration=0.1, lengthscale=2.0),
    # discount / improvement factor
    "advmulti_disc0.9": BOConfig(acquisition="advanced_multi", discount=0.9),
    "advmulti_if0.05": BOConfig(acquisition="advanced_multi",
                                improvement_factor=0.05),
    # initial sampling: random instead of maximin LHS
    "advmulti_random_init": BOConfig(acquisition="advanced_multi",
                                     maximin=False),
}


def main(repeats: int = 5) -> dict:
    per_kernel: Dict[str, Dict[str, float]] = {k: {} for k in KERNELS}
    for kernel in KERNELS:
        obj = make_objective(kernel, "gtx_titan_x")
        for name, cfg in VARIANTS.items():
            maes = []
            for seed in range(repeats):
                res = run_strategy(BOStrategy(cfg, name=name), obj,
                                   budget=220, seed=seed)
                maes.append(mae(res.trace, obj.optimum))
            per_kernel[kernel][name] = float(np.mean(maes))
    mdf = mdf_table(per_kernel)
    ranked = sorted(mdf.items(), key=lambda kv: kv[1]["mdf"])
    for name, v in ranked:
        emit(f"table1/{name}", 0.0, f"mdf={v['mdf']:.4f}")
    save_json("table1", {"per_kernel": per_kernel, "mdf": mdf})
    return {"per_kernel": per_kernel, "mdf": mdf, "ranked": ranked}


if __name__ == "__main__":
    main()
