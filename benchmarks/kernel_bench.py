"""Pallas-kernel and GP-engine microbenchmarks.

Wall-times here are CPU/interpret numbers (the TPU is the target; interpret
mode validates semantics). The informative derived columns are the
allclose-vs-oracle error and the incremental-GP speedup, which are
machine-meaningful on any host.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.core.gp import GP
from repro.core.gp_fast import IncrementalGP, forward_substitute
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6, out


def bench_gemm():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    us, out = _time(lambda: ops.gemm(a, b, block_m=128, block_n=128, block_k=128))
    err = float(jnp.max(jnp.abs(out - ref.gemm(a, b))))
    emit("kernels/gemm_interp_512", us, f"maxerr={err:.2e}")


def bench_flash():
    rng = np.random.default_rng(1)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
               for _ in range(3))
    us, out = _time(lambda: ops.flash_attention(q, k, v, block_q=128,
                                                block_kv=128))
    err = float(jnp.max(jnp.abs(out - ref.attention(q, k, v))))
    emit("kernels/flash_interp_512", us, f"maxerr={err:.2e}")


def _tuned_block_n(store, N: int, default: int = 512) -> int:
    """Tuned matern_gp block size from the kernel-tuning store, when one is
    present — the nightly bench then exercises the tuned path instead of a
    hardcoded default."""
    if store is None:
        return default
    from repro.kernels.tuning import tuned_gp_block_n
    return tuned_gp_block_n(store, N=N, default=default)


def bench_gp_engines(store=None):
    """The paper's per-iteration cost: exhaustive posterior over ~18k configs."""
    rng = np.random.default_rng(2)
    N, d, T = 17956, 15, 220
    Xc = rng.random((N, d)).astype(np.float32)

    g_fast = IncrementalGP(Xc, max_obs=T, ell=2.0)
    t0 = time.time()
    for i in range(60):
        g_fast.add(Xc[rng.integers(N)], float(rng.normal(10, 2)))
        g_fast.predict()
    fast_us = (time.time() - t0) / 60 * 1e6

    g_jax = GP(d, max_obs=T, ell=2.0)
    for i in range(60):
        g_jax.add(Xc[rng.integers(N)], float(rng.normal(10, 2)))
    t0 = time.time()
    g_jax.fit()
    mu, _ = g_jax.predict(Xc)
    jax.block_until_ready(mu)
    t_once = time.time() - t0
    for _ in range(2):
        g_jax.add(Xc[rng.integers(N)], 10.0)
        t0 = time.time()
        g_jax.fit()
        mu, _ = g_jax.predict(Xc)
        jax.block_until_ready(mu)
        t_once = time.time() - t0
    jax_us = t_once * 1e6

    emit("gp/incremental_per_iter", fast_us, f"N={N} T={T}")
    emit("gp/padded_jax_per_iter", jax_us, f"speedup={jax_us / fast_us:.1f}x")
    out = {"fast_us": fast_us, "jax_us": jax_us,
           "speedup": jax_us / fast_us}

    if store is not None:
        # self-hosted row: the same loop scored through the Pallas kernel
        # with the store-tuned block_n (DESIGN.md §14)
        bn = _tuned_block_n(store, N)
        g_pl = IncrementalGP(Xc, max_obs=T, ell=2.0, backend="pallas",
                             block_n=bn)
        for i in range(20):
            g_pl.add(Xc[rng.integers(N)], float(rng.normal(10, 2)))
        t0 = time.time()
        g_pl.predict()
        pallas_us = (time.time() - t0) * 1e6
        emit("gp/pallas_backend_per_iter", pallas_us, f"block_n={bn}")
        out.update({"pallas_us": pallas_us, "pallas_block_n": bn})
    save_json("gp_engines", out)


def bench_matern_kernel(store=None):
    rng = np.random.default_rng(3)
    N, d, t = 4096, 15, 37
    Xc = rng.random((N, d)).astype(np.float32)
    g = IncrementalGP(Xc, max_obs=64, ell=2.0)
    for _ in range(t):
        g.add(Xc[rng.integers(N)], float(rng.normal(10, 2)))
    x_obs, vinv, w, mask, y_mean, y_std = ops.gp_inputs_from_incremental(g)
    args = (jnp.asarray(Xc), jnp.asarray(x_obs), jnp.asarray(vinv),
            jnp.asarray(w), jnp.asarray(mask))
    bn = _tuned_block_n(store, N)
    us, (mean_k, _) = _time(lambda: ops.gp_posterior(*args, ell=2.0,
                                                     block_n=bn))
    mu_i, _ = g.predict()
    err = float(np.max(np.abs(y_mean + y_std * np.asarray(mean_k) - mu_i)))
    emit("kernels/matern_gp_interp_4k", us,
         f"block_n={bn} vs_engine_err={err:.2e}")


def bench_triangular_solve():
    """IncrementalGP's forward substitution: generic np.linalg.solve is
    O(t^3) per add; scipy solve_triangular exploits the factor in O(t^2)."""
    rng = np.random.default_rng(4)
    t = 220   # paper budget = worst-case factor size
    L = np.tril(rng.random((t, t))) + t * np.eye(t)
    b = rng.random(t)

    reps = 200
    t0 = time.time()
    for _ in range(reps):
        x_gen = np.linalg.solve(L, b)
    gen_us = (time.time() - t0) / reps * 1e6
    t0 = time.time()
    for _ in range(reps):
        x_tri = forward_substitute(L, b)
    tri_us = (time.time() - t0) / reps * 1e6
    err = float(np.max(np.abs(x_gen - x_tri)))
    emit("gp/solve_generic_t220", gen_us, f"maxerr={err:.2e}")
    emit("gp/solve_triangular_t220", tri_us,
         f"speedup={gen_us / tri_us:.1f}x")
    save_json("triangular_solve", {"generic_us": gen_us, "triangular_us": tri_us,
                                   "speedup": gen_us / tri_us})


def main(repeats: int = 3, store=None) -> None:
    bench_gemm()
    bench_flash()
    bench_matern_kernel(store=store)
    bench_gp_engines(store=store)
    bench_triangular_solve()


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default=None,
                    help="kernel-tuning record store; block configs are "
                         "sourced from it when present")
    main(store=ap.parse_args().store)
