"""§Perf hillclimb reporter: emits every hillclimb variant's roofline terms.

The actual experiments are driven by `repro.launch.dryrun` (tags A*/B*/C*)
and by `examples/tune_sharding.py` (the BO-driven C cell); this module
re-reads the cached records so `python -m benchmarks.run` reproduces the
§Perf tables from EXPERIMENTS.md without recompiling.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

HILL_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "hillclimb")


def main(repeats: int = 0) -> None:
    recs = []
    for f in sorted(glob.glob(os.path.join(HILL_DIR, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        tag = os.path.basename(f).split("__")[0]
        recs.append((tag, r))
    if not recs:
        emit("perf/none", 0.0, "no hillclimb records (run scripts/rerun_all.sh)")
        return
    for tag, r in recs:
        if r.get("status") != "ok":
            emit(f"perf/{tag}", 0.0, f"status={r.get('status')}")
            continue
        rf = r["roofline"]
        emit(f"perf/{tag}/{r['arch']}/{r['shape']}/{r['mesh']}",
             r.get("t_compile_s", 0.0) * 1e6,
             f"t=({rf['t_compute']:.2f};{rf['t_memory']:.2f};"
             f"{rf['t_collective']:.2f})s dom={rf['dominant']} "
             f"frac={100 * (rf.get('roofline_fraction') or 0):.3f}%")


if __name__ == "__main__":
    main()
