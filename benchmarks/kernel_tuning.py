"""Tuned-vs-default Pallas kernel block configs (DESIGN.md §14).

The source paper's headline measurement, run over this repo's own kernels:
BO tunes each kernel cell's block configuration against measured step time,
and the table reports tuned vs the kernel's built-in default, plus a
budget-sensitivity row in the style of Schoonhoven et al. (arxiv
2210.01465) — best-so-far at fractions of the full budget, so the "how much
tuning is enough" question is answered honestly rather than only at the
final budget.

Numbers are interpret-mode on CPU (semantics-validation path; the TPU is
the target) or real device timings on TPU — the cells key their store
fingerprints by device, so the two never mix.

  PYTHONPATH=src python -m benchmarks.kernel_tuning [--smoke] [--store PATH]

Writes results/bench/kernel_tuning.json.
"""
from __future__ import annotations

import argparse
import math
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import emit, save_json
from repro.kernels.tuning import (KernelObjective, default_cells, device_kind,
                                  run_kernel_tuning)

#: budget-sensitivity checkpoints (fractions of the full budget)
BUDGET_FRACTIONS = (0.25, 0.5, 1.0)


def tune_cell(cell, store, *, budget: int, reps: int, seed: int = 0) -> Dict:
    obj = KernelObjective(cell, reps=reps)
    default_s = obj.eval_config(cell.default)
    res = run_kernel_tuning(cell, store, budget=budget,
                            init=max(2, budget // 3), seed=seed, reps=reps)
    best_cfg = cell.space.config(res.best_idx)
    trace = np.asarray(res.trace, float)
    curve = {}
    for frac in BUDGET_FRACTIONS:
        k = max(1, int(math.ceil(frac * len(trace))))
        v = float(np.nanmin(trace[:k]))
        curve[f"best_at_{int(frac * 100)}pct"] = v
    tuned_s = float(res.best_value)
    if best_cfg == cell.default:
        # tuning converged on the built-in default: report parity, not the
        # re-measurement jitter between two timings of the same config
        tuned_s = default_s
    speedup = default_s / tuned_s if tuned_s > 0 else float("nan")
    emit(f"kernel_tuning/{cell.kernel}_{cell.shape_sig}", tuned_s * 1e6,
         f"default={default_s * 1e6:.1f}us speedup={speedup:.2f}x "
         f"cfg={best_cfg}")
    row = {
        "kernel": cell.kernel, "shape": cell.shape_sig,
        "space_size": cell.space.size,
        "default_config": cell.default, "default_s": default_s,
        "tuned_config": best_cfg, "tuned_s": tuned_s,
        "speedup": speedup, "budget": budget, "reps": reps,
        "unique_evals": res.unique_evals, "budget_curve": curve,
    }
    if cell.kernel == "decode":
        # the decode cell is one token per batch row per step: step time IS
        # the serving rate, so report it in the unit serving dashboards use
        B = int(cell.meta["B"])
        row["tokens_per_s_default"] = (B / default_s if default_s > 0
                                       else float("nan"))
        row["tokens_per_s_tuned"] = (B / tuned_s if tuned_s > 0
                                     else float("nan"))
    return row


def main(*, smoke: bool = False, budget: Optional[int] = None,
         reps: Optional[int] = None, store_path: Optional[str] = None,
         seed: int = 0, assert_decode_win: bool = False) -> Dict:
    budget = budget or (6 if smoke else 14)
    reps = reps or (1 if smoke else 3)
    store = None
    if store_path is not None:
        from repro.store import TuningRecordStore
        store = TuningRecordStore(store_path)
    rows: List[Dict] = []
    for cell in default_cells(smoke=smoke):
        rows.append(tune_cell(cell, store, budget=budget, reps=reps,
                              seed=seed))
    wins = sum(1 for r in rows if r["tuned_s"] <= r["default_s"])
    payload = {
        "device": device_kind(), "smoke": smoke, "budget": budget,
        "reps": reps, "budget_fractions": list(BUDGET_FRACTIONS),
        "cells": rows,
        "tuned_beats_or_matches_default": wins, "n_cells": len(rows),
    }
    path = save_json("kernel_tuning_smoke" if smoke else "kernel_tuning",
                     payload)
    print(f"[kernel_tuning] {wins}/{len(rows)} cells tuned <= default "
          f"-> {path}")
    if assert_decode_win:
        # nightly acceptance gate (ISSUE 8): the serve-hot-path cell must
        # never regress past its built-in default
        decode_rows = [r for r in rows if r["kernel"] == "decode"]
        assert decode_rows, "no decode cell in the matrix"
        for r in decode_rows:
            assert r["tuned_s"] <= r["default_s"], (
                f"decode cell {r['shape']}: tuned {r['tuned_s']:.6f}s > "
                f"default {r['default_s']:.6f}s")
        print(f"[kernel_tuning] decode gate OK: tuned <= default on "
              f"{len(decode_rows)} decode cell(s)")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small shapes, budget 6, 1 timing rep")
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--store", default=None,
                    help="persist tuning records to this store (the serve "
                         "layer and kernel_bench then resolve tuned blocks "
                         "from it)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--assert-decode-win", action="store_true",
                    help="fail (exit nonzero) unless tuned <= default for "
                         "the decode cell — the nightly serve-hot-path gate")
    args = ap.parse_args()
    main(smoke=args.smoke, budget=args.budget, reps=args.reps,
         store_path=args.store, seed=args.seed,
         assert_decode_win=args.assert_decode_win)
