"""§Roofline table generator: reads the dry-run JSON cache, emits the
per-(arch × shape × mesh) three-term table (markdown + CSV rows)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import emit, save_json

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["deepseek-v3-671b", "qwen3-moe-30b-a3b", "recurrentgemma-9b",
              "gemma-2b", "mistral-large-123b", "internlm2-1.8b",
              "stablelm-3b", "musicgen-large", "chameleon-34b", "xlstm-1.3b"]


def load(tag: str = "baseline") -> List[Dict]:
    recs = []
    for f in glob.glob(os.path.join(DRYRUN_DIR, f"{tag}__*.json")):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def markdown_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = {(r["arch"], r["shape"]): r for r in recs if r.get("mesh") == mesh}
    lines = [
        f"| arch | shape | status | t_compute (s) | t_memory (s) | t_collective (s) "
        f"| dominant | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = rows.get((a, s))
            if r is None:
                continue
            if r["status"] == "skip":
                lines.append(f"| {a} | {s} | SKIP | — | — | — | — | — | — |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {a} | {s} | {r['status']} | — | — | — | — | — | — |")
                continue
            rf = r["roofline"]
            u = rf.get("useful_flops_ratio") or 0.0
            frac = rf.get("roofline_fraction") or 0.0
            lines.append(
                f"| {a} | {s} | ok | {rf['t_compute']:.3f} | {rf['t_memory']:.3f} "
                f"| {rf['t_collective']:.3f} | {rf['dominant']} | {u:.3f} "
                f"| {100 * frac:.3f}% |")
    return "\n".join(lines)


def main(repeats: int = 0, tag: str = "baseline") -> dict:
    recs = load(tag)
    ok = [r for r in recs if r.get("status") == "ok"]
    for r in sorted(ok, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        rf = r["roofline"]
        emit(f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}",
             r.get("t_compile_s", 0.0) * 1e6,
             f"dom={rf['dominant']} t=({rf['t_compute']:.3f};"
             f"{rf['t_memory']:.3f};{rf['t_collective']:.3f})s "
             f"frac={100 * (rf.get('roofline_fraction') or 0):.3f}%")
    md_single = markdown_table(recs, "single")
    md_multi = markdown_table(recs, "multi")
    save_json("roofline", {"n_ok": len(ok), "n_total": len(recs)})
    out_md = os.path.join(DRYRUN_DIR, f"{tag}_roofline.md")
    with open(out_md, "w") as f:
        f.write("## single-pod (16×16 = 256 chips)\n\n" + md_single +
                "\n\n## multi-pod (2×16×16 = 512 chips)\n\n" + md_multi + "\n")
    emit("roofline/summary", 0.0,
         f"ok={len(ok)} skip={sum(1 for r in recs if r.get('status') == 'skip')} "
         f"md={out_md}")
    return {"md_single": md_single, "md_multi": md_multi}


if __name__ == "__main__":
    main()
