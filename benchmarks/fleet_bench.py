"""Fleet control-plane benchmark: tuning-job throughput and duplicate
services at 1/2/4 racing daemons (DESIGN.md §13, ISSUE 9 acceptance).

The claim under measurement: the fenced ``TuningJobQueue`` scales a fleet
WITHOUT duplicating work — N daemons draining one store-backed queue
service every job exactly once (fencing tokens arbitrate every claim race),
and the arbitration overhead (issue token + claim append + re-read + done
append per job) stays cheap enough that queue throughput is not the
bottleneck of a tuning fleet (real services run seconds to minutes; the
control plane must sit orders of magnitude below that).

Per daemon count the bench submits a mixed-type job batch into a fresh
directory store, round-robins the daemons claim→done over it (service
itself is a no-op: this isolates the CONTROL-PLANE cost, not the tuning
run), and reports:

  * jobs/sec drained across the fleet (claim + fenced done, per job);
  * duplicate-service count — MUST be zero at every fleet width;
  * fenced/rejected writes observed (zero in an uncontended round-robin).

The committed numbers live in ``results/bench/fleet.json`` (full run,
nightly); ``--smoke`` (CI) runs a small batch and asserts the exactly-once
and sanity bars without writing.

  PYTHONPATH=src python -m benchmarks.fleet_bench [--smoke] [--jobs N]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

from benchmarks.common import emit, save_json
from repro.store import JOB_TYPES, TuningJobQueue, TuningRecordStore

DAEMON_COUNTS = (1, 2, 4)


class _Req:
    def __init__(self, key: str, t: float):
        self.key = key
        self.objective = key
        self.observed = 2.0
        self.predicted = 1.0
        self.reason = "bench"
        self.t = t


def bench_one(n_daemons: int, n_jobs: int) -> dict:
    d = tempfile.mkdtemp(prefix=f"fleetbench-{n_daemons}-")
    path = os.path.join(d, "store")
    try:
        store = TuningRecordStore(path, load=False)
        submitter = TuningJobQueue(path, worker="submitter", appender=store)
        t0 = time.perf_counter()
        for i in range(n_jobs):
            ok = submitter.submit(_Req(f"cell-{i:05d}", t=float(i + 1)),
                                  job_type=JOB_TYPES[i % len(JOB_TYPES)])
            assert ok
        t_submit = time.perf_counter() - t0

        daemons = [TuningJobQueue(path, worker=f"daemon-{i}",
                                  appender=store)
                   for i in range(n_daemons)]
        serviced: dict = {}
        duplicates = 0
        t0 = time.perf_counter()
        drained = 0
        while drained < n_jobs:
            progress = False
            for q in daemons:
                ticket = q.claim()
                if ticket is None:
                    continue
                if ticket.key in serviced:
                    duplicates += 1
                serviced[ticket.key] = serviced.get(ticket.key, 0) + 1
                q.done(ticket)          # no-op service: control-plane cost
                drained += 1
                progress = True
            if not progress:
                break
        t_drain = time.perf_counter() - t0
        fenced = sum(q.rejected_writes for q in daemons)
        store.close()
        return {"daemons": n_daemons, "jobs": n_jobs,
                "drained": drained, "duplicate_services": duplicates,
                "rejected_writes": fenced,
                "submit_s": t_submit, "drain_s": t_drain,
                "submits_per_s": n_jobs / max(t_submit, 1e-9),
                "jobs_per_s": drained / max(t_drain, 1e-9)}
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: small batch, exactly-once + sanity bars only")
    ap.add_argument("--jobs", type=int, default=None,
                    help="jobs per fleet width (default: 24 smoke, 200 full)")
    args = ap.parse_args()
    n_jobs = args.jobs or (24 if args.smoke else 200)

    rows = []
    for n in DAEMON_COUNTS:
        row = bench_one(n, n_jobs)
        rows.append(row)
        emit(f"fleet_drain_d{n}", row["drain_s"] * 1e6 / max(row["drained"], 1),
             f"{row['jobs_per_s']:.0f} jobs/s, "
             f"{row['duplicate_services']} duplicates, "
             f"{row['rejected_writes']} fenced writes")
        assert row["drained"] == n_jobs, \
            f"{n} daemons drained {row['drained']}/{n_jobs} jobs"
        assert row["duplicate_services"] == 0, \
            f"{n} daemons produced {row['duplicate_services']} duplicate " \
            "services — the fencing arbitration leaked a job"
    if args.smoke:
        assert all(r["jobs_per_s"] > 5 for r in rows), rows
    else:
        save_json("fleet", {"job_types": list(JOB_TYPES),
                            "jobs_per_width": n_jobs, "rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    main()
