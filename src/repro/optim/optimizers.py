"""Sharded optimizers: AdamW and Adafactor, plus LR schedules.

State trees mirror the parameter tree (same structure, same shardings), so
GSPMD shards optimizer state exactly like ZeRO-3. ``abstract_state`` builds
ShapeDtypeStructs for the dry-run without allocating anything.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * (step + 1) / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return schedule


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


@dataclass(frozen=True)
class AdamW:
    schedule: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"
    clip_norm: Optional[float] = 1.0

    def init(self, params):
        md = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, md)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def abstract_state(self, param_structs):
        md = jnp.dtype(self.moment_dtype)

        def like(p):
            sh = getattr(p, "sharding", None)
            if sh is not None:
                return jax.ShapeDtypeStruct(p.shape, md, sharding=sh)
            return jax.ShapeDtypeStruct(p.shape, md)

        return {"mu": jax.tree.map(like, param_structs),
                "nu": jax.tree.map(like, param_structs),
                "count": jax.ShapeDtypeStruct((), jnp.int32)}

    def update(self, grads, state, params):
        if self.clip_norm:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        else:
            gnorm = global_norm(grads)
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        lr = self.schedule(state["count"])
        bc1 = 1 - self.b1 ** cf
        bc2 = 1 - self.b2 ** cf
        md = jnp.dtype(self.moment_dtype)

        def upd(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu32 = self.b1 * mu.astype(jnp.float32) + (1 - self.b1) * g32
            nu32 = self.b2 * nu.astype(jnp.float32) + (1 - self.b2) * jnp.square(g32)
            step = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return new_p, mu32.astype(md), nu32.astype(md)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_state = {"mu": treedef.unflatten([o[1] for o in out]),
                     "nu": treedef.unflatten([o[2] for o in out]),
                     "count": count}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


@dataclass(frozen=True)
class Adafactor:
    """Factored second-moment optimizer (memory: ~1 fp32 scalar per row+col)."""

    schedule: Callable[[jax.Array], jax.Array]
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _factored(self, shape) -> bool:
        return len(shape) >= 2

    def init(self, params):
        def one(p):
            if self._factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(one, params), "count": jnp.zeros((), jnp.int32)}

    def abstract_state(self, param_structs):
        def one(p):
            if self._factored(p.shape):
                return {"vr": jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
                        "vc": jax.ShapeDtypeStruct(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}
        return {"v": jax.tree.map(one, param_structs,
                                  is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
                "count": jax.ShapeDtypeStruct((), jnp.int32)}

    def update(self, grads, state, params):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        lr = self.schedule(state["count"])
        beta = 1.0 - cf ** (-self.decay)

        def upd(p, g, v):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            if self._factored(p.shape):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], self.eps))
                upd_ = g32 * jax.lax.rsqrt(denom + self.eps)
                new_v = {"vr": vr, "vc": vc}
            else:
                nv = beta * v["v"] + (1 - beta) * g2
                upd_ = g32 * jax.lax.rsqrt(nv + self.eps)
                new_v = {"v": nv}
            rms = jnp.sqrt(jnp.mean(jnp.square(upd_)) + 1e-12)
            upd_ = upd_ / jnp.maximum(1.0, rms / self.clip_threshold)
            new_p = p.astype(jnp.float32) - lr * (upd_ + self.weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), new_v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_state = {"v": treedef.unflatten([o[1] for o in out]), "count": count}
        return new_params, new_state, {"lr": lr}


def make_optimizer(name: str, schedule, moment_dtype: str = "float32"):
    if name == "adamw":
        return AdamW(schedule=schedule, moment_dtype=moment_dtype)
    if name == "adafactor":
        return Adafactor(schedule=schedule)
    raise ValueError(name)
