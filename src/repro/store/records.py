"""Persistent tuning-record store (DESIGN.md §11).

One schema for every observation the system produces — engine journals,
benchmark runs, golden traces, dry-run compile tunings. Records are
append-only JSONL, keyed by a ``SpaceFingerprint``: the identity of a tuning
problem (parameter grid, restriction signature, objective id, device
context). The store is the substrate for checkpoint/resume (a run's journal
is the ordered record stream of its ``run`` id) and for transfer-aware
warm starts (``repro.store.transfer`` matches prior records — exact
fingerprint or compatible-dims cross-size — into a new run).

Layout:
  * directory mode — ``<path>/segment-*.jsonl``, one segment per writer;
    shared store across runs/benchmarks;
  * single-file mode — ``<path>`` ends in ``.json``/``.jsonl``: the whole
    store is one segment. This is what a per-run checkpoint path becomes
    (the legacy whole-journal-rewrite JSON format is migrated in place by
    ``repro.store.migrate``).

Each line is either a fingerprint descriptor (``kind: fp`` — written once
per digest per segment, making segments self-contained) or an observation
(``kind: obs``). Appends are flushed per record, so a killed run leaves a
valid record-stream prefix; a torn final line is tolerated on load. Two
further kinds are control plane, not observations: ``kind: compact``
(compaction headers, ``repro.store.compact``) and ``kind: job`` /
``kind: retune`` (the durable tuning-job queue, ``repro.store.queue``;
``retune`` is the queue's legacy single-daemon spelling) — the loader
skips all of them.

Open modes:
  * ``load=True`` (default) — parse every segment into memory; right for
    small stores and for whole-store consumers;
  * ``load=False`` — write-only appender, O(1) startup;
  * ``lazy=True`` — read only the sidecar segment index
    (``repro.store.index``, rebuilt on demand when stale or missing) plus
    any bytes appended past it, and materialize a fingerprint's records
    only when a caller touches that digest: O(hot set) opens on
    fleet-scale stores. Queries answer from the open-time snapshot, the
    same visibility ``load=True`` gives.
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import re
from dataclasses import dataclass, field
from functools import cached_property
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.searchspace import SearchSpace

SCHEMA_VERSION = 1


@dataclass(frozen=True)
class SpaceFingerprint:
    """Identity of a tuning problem: dims + restrictions + objective + device.

    ``params`` stores each parameter's ordered value grid as strings, so a
    fingerprint is JSON-stable and can renormalize configs from *its own*
    grid without reconstructing a SearchSpace — which is what makes
    cross-size transfer possible from records alone.
    """

    params: Tuple[Tuple[str, Tuple[str, ...]], ...]
    size: int                    # kept configs (captures the filter effect)
    cartesian: int
    restrictions: Tuple[str, ...]
    objective: str               # objective id, e.g. "expdist@a100"
    context: str = ""            # device/deployment context

    @cached_property
    def digest(self) -> str:
        blob = json.dumps([list(map(list, self.params)), self.size,
                           self.cartesian, list(self.restrictions),
                           self.objective, self.context])
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    @classmethod
    def of(cls, space: SearchSpace, objective: str = "",
           context: str = "") -> "SpaceFingerprint":
        return cls(
            params=tuple((p.name, tuple(str(v) for v in p.values))
                         for p in space.params),
            size=int(space.size), cartesian=int(space.cartesian_size),
            restrictions=tuple(
                getattr(c, "name", getattr(c, "__name__", "<restriction>"))
                for c in space.constraints),
            objective=str(objective), context=str(context))

    def compatible(self, other: "SpaceFingerprint") -> bool:
        """Cross-size transferable: same parameter names in the same order
        (the value grids — and so the space sizes — may differ)."""
        return (self.param_names == other.param_names
                and len(self.params) > 0)

    @property
    def param_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.params)

    def x_norm(self, config: Dict[str, Any]) -> Optional[np.ndarray]:
        """Ordinal-normalized position of ``config`` under THIS fingerprint's
        grids (value j of n -> j/(n-1), n==1 -> 0.5); None when a value is
        not on the grid."""
        out = np.empty(len(self.params), np.float32)
        for j, (name, values) in enumerate(self.params):
            if name not in config:
                return None
            try:
                k = values.index(str(config[name]))
            except ValueError:
                return None
            out[j] = 0.5 if len(values) == 1 else k / (len(values) - 1)
        return out

    def to_json(self) -> Dict[str, Any]:
        return {"kind": "fp", "v": SCHEMA_VERSION, "digest": self.digest,
                "params": [[n, list(vs)] for n, vs in self.params],
                "size": self.size, "cartesian": self.cartesian,
                "restrictions": list(self.restrictions),
                "objective": self.objective, "context": self.context}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "SpaceFingerprint":
        return cls(params=tuple((n, tuple(vs)) for n, vs in d["params"]),
                   size=int(d["size"]), cartesian=int(d["cartesian"]),
                   restrictions=tuple(d["restrictions"]),
                   objective=d["objective"], context=d.get("context", ""))


@dataclass
class TuningRecord:
    """One observation: what was evaluated, under which problem identity."""

    fp: str                      # SpaceFingerprint digest
    run: str                     # journal stream id (strategy/seed/run tag)
    seq: int                     # acceptance-order position within the run
    key: str                     # unique evaluation key (space idx or cfg:)
    idx: Optional[int]           # config index (None outside the space)
    value: float                 # objective value, NaN = invalid
    af: Optional[str] = None
    config: Optional[Dict[str, Any]] = None
    worker: str = "main"
    dur: float = 0.0
    t: float = 0.0
    meta: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "kind": "obs", "fp": self.fp, "run": self.run, "seq": self.seq,
            "key": self.key, "idx": self.idx,
            "value": None if not math.isfinite(self.value) else self.value,
            "af": self.af}
        if self.config is not None:
            d["config"] = self.config
        if self.worker != "main":
            d["worker"] = self.worker
        if self.dur:
            d["dur"] = self.dur
        if self.t:
            d["t"] = self.t
        if self.meta:
            d["meta"] = self.meta
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "TuningRecord":
        v = d.get("value")
        return cls(fp=d["fp"], run=d["run"], seq=int(d.get("seq", 0)),
                   key=d["key"],
                   idx=None if d.get("idx") is None else int(d["idx"]),
                   value=math.nan if v is None else float(v),
                   af=d.get("af"), config=d.get("config"),
                   worker=d.get("worker", "main"),
                   dur=float(d.get("dur", 0.0)), t=float(d.get("t", 0.0)),
                   meta=d.get("meta", {}))


def _is_single_file(path: str) -> bool:
    return path.endswith((".json", ".jsonl"))


def natural_key(name: str) -> Tuple:
    """Digit-aware sort key: ``segment-<pid>-10`` after ``segment-<pid>-2``
    (plain lexicographic order breaks past ten rollovers of one writer)."""
    return tuple(int(tok) if tok.isdigit() else tok
                 for tok in re.split(r"(\d+)", name))


def list_segments(path: str, single_file: bool) -> List[str]:
    """A store's segment files in rollover order — the one definition both
    the loader and the live watcher must agree on."""
    if single_file:
        return [path] if os.path.exists(path) else []
    if not os.path.isdir(path):
        return []
    names = sorted((f for f in os.listdir(path) if f.endswith(".jsonl")),
                   key=natural_key)
    return [os.path.join(path, f) for f in names]


def _segment_high_water(path: str) -> Dict[int, int]:
    """Highest segment number ever FOLDED per writer pid, read from the
    compaction headers of ``segment-0-*.jsonl`` outputs. Compaction deletes
    its source files; a writer that restarted its numbering below the high
    water would reuse a deleted name and corrupt concurrent watcher tails,
    so ``_handle`` starts new segments past it. Headers carry the merged
    high water of everything they transitively folded, so one header level
    is enough."""
    hw: Dict[int, int] = {}
    if not os.path.isdir(path):
        return hw
    for name in os.listdir(path):
        if not re.match(r"segment-0-\d+\.jsonl$", name):
            continue
        try:
            with open(os.path.join(path, name)) as f:
                d = json.loads(f.readline())
        except (OSError, json.JSONDecodeError):
            continue
        if not isinstance(d, dict) or d.get("kind") != "compact":
            continue
        for pid, k in d.get("high_water", {}).items():
            try:
                pid = int(pid)
            except ValueError:
                continue
            hw[pid] = max(hw.get(pid, -1), int(k))
    return hw


class TuningRecordStore:
    """Append-only JSONL segments + in-memory index by fingerprint digest."""

    def __init__(self, path: str, *, load: bool = True, lazy: bool = False):
        """``load=False`` opens a write-only appender: no segment parse, no
        in-memory index — O(1) startup however large the store has grown.
        For producers that only ever ``append`` (serving telemetry); queries
        on such an instance see only its own appends. ``lazy=True`` opens
        through the sidecar segment index instead (``repro.store.index``):
        O(index + un-indexed tail) startup, per-digest materialization on
        first touch, identical query results on an unchanged store."""
        self.path = path
        self.single_file = _is_single_file(path)
        self.lazy = bool(lazy)
        self.bytes_read = 0                # data-plane bytes this instance read
        self._records: List[TuningRecord] = []
        self._by_fp: Dict[str, List[int]] = {}
        self._fps: Dict[str, SpaceFingerprint] = {}
        self._fh = None                    # lazy append handle
        self._written_fps: set = set()     # descriptors this handle has written
        # lazy-mode state: sidecar index, open-time tail scan, per-digest
        # materialization cache, and this instance's own appends
        self._index = None
        self._tail: Dict[str, Dict[str, List[TuningRecord]]] = {}
        self._tail_total = 0
        self._mat: Dict[str, List[TuningRecord]] = {}
        self._appended_by_fp: Dict[str, List[TuningRecord]] = {}
        self._appended_total = 0
        if self.lazy:
            self._open_lazy()
        elif load:
            self._load()

    # -- loading ------------------------------------------------------------
    def _segments(self) -> List[str]:
        return list_segments(self.path, self.single_file)

    def _load(self) -> None:
        for seg in self._segments():
            with open(seg) as f:
                data = f.read()
            self.bytes_read += len(data)
            lines = data.splitlines()
            for k, line in enumerate(lines):
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                except json.JSONDecodeError:
                    if k == len(lines) - 1:
                        break   # torn final line from a killed writer
                    raise ValueError(
                        f"{seg}:{k + 1}: corrupt record line — if this is a "
                        "legacy engine checkpoint, migrate it with "
                        "repro.store.migrate.migrate_checkpoint")
                self._ingest(d, seg, k)

    def _ingest(self, d: Dict[str, Any], seg: str, lineno: int) -> None:
        kind = d.get("kind")
        if kind == "fp":
            fp = SpaceFingerprint.from_json(d)
            self._fps.setdefault(fp.digest, fp)
        elif kind == "obs":
            rec = TuningRecord.from_json(d)
            self._by_fp.setdefault(rec.fp, []).append(len(self._records))
            self._records.append(rec)
        elif kind in ("compact", "retune", "job"):
            pass    # control plane: compaction headers / durable job queue
        else:
            raise ValueError(
                f"{seg}:{lineno + 1}: unknown record kind {kind!r} — if this "
                "is a legacy engine checkpoint, migrate it with "
                "repro.store.migrate.migrate_checkpoint")

    # -- lazy (indexed) loading ---------------------------------------------
    def _open_lazy(self) -> None:
        """Load the sidecar index (rebuilding it when stale/missing), then
        scan only the bytes appended past each segment's indexed frontier.
        A freshly indexed store opens by reading the index alone."""
        from repro.store import index as sidx
        idx = sidx.load_index(self.path)
        if idx is not None:
            try:
                self.bytes_read += os.path.getsize(sidx.index_path(self.path))
            except OSError:
                pass
        if idx is None or sidx.index_is_stale(self.path, idx):
            idx = sidx.build_index(self.path)
            for seg in self._segments():
                self.bytes_read += idx.segments.get(os.path.basename(seg), 0)
            sidx.write_index(self.path, idx)    # best-effort sidecar refresh
            self._index = idx
            self._fps = {**idx.fps, **self._fps}
            return
        self._index = idx
        self._fps = {**idx.fps, **self._fps}
        for seg in self._segments():
            name = os.path.basename(seg)
            start = idx.segments.get(name, 0)
            if os.path.getsize(seg) <= start:
                continue
            per_fp = self._tail.setdefault(name, {})
            for offset, nbytes, raw in sidx.iter_complete_lines(seg, start):
                self.bytes_read += nbytes
                text = raw.decode("utf-8").strip()
                if not text:
                    continue
                d = json.loads(text)
                kind = d.get("kind")
                if kind == "fp":
                    fp = SpaceFingerprint.from_json(d)
                    self._fps.setdefault(fp.digest, fp)
                elif kind == "obs":
                    rec = TuningRecord.from_json(d)
                    per_fp.setdefault(rec.fp, []).append(rec)
                    self._tail_total += 1

    def _segment_path(self, name: str) -> str:
        return self.path if self.single_file else os.path.join(self.path,
                                                               name)

    def _read_extent(self, extent, digest: str) -> List[TuningRecord]:
        seg = self._segment_path(extent.segment)
        with open(seg, "rb") as f:
            f.seek(extent.offset)
            data = f.read(extent.length)
        self.bytes_read += len(data)
        out: List[TuningRecord] = []
        for raw in data.split(b"\n"):
            text = raw.decode("utf-8").strip()
            if not text:
                continue
            d = json.loads(text)
            if d.get("kind") == "obs" and d.get("fp") == digest:
                out.append(TuningRecord.from_json(d))
        return out

    def _materialize(self, digest: str) -> List[TuningRecord]:
        """This digest's records from disk (indexed extents + open-time tail),
        in global append order; cached. Own appends are tracked separately
        (``_appended_by_fp``) so they are never double-counted. If a
        compaction swapped segments out from under this snapshot, the open
        is redone against the rewritten store and the read retried —
        compaction preserves every non-GC'd record, so the answer is the
        same."""
        if digest in self._mat:
            return self._mat[digest]
        try:
            return self._materialize_uncached(digest)
        except FileNotFoundError:
            self._reopen_lazy()
            return self._materialize_uncached(digest)

    def _reopen_lazy(self) -> None:
        """Drop the open-time snapshot and re-open against the rewritten
        store. Own appends were flushed, so the fresh snapshot covers them
        from disk — the append-side bookkeeping must reset with the rest or
        they would be counted twice."""
        self._tail, self._tail_total, self._mat = {}, 0, {}
        self._appended_by_fp, self._appended_total = {}, 0
        self._open_lazy()

    def refresh(self) -> None:
        """Re-snapshot a lazy store: appends landed by other processes
        since open become visible and a concurrent compaction is absorbed.
        Long-lived lazy consumers (the retune daemon) call this between
        units of work; no-op in the other modes."""
        if self.lazy:
            self._reopen_lazy()

    def _materialize_uncached(self, digest: str) -> List[TuningRecord]:
        ext_by_seg: Dict[str, list] = {}
        for e in self._index.extents.get(digest, ()):
            ext_by_seg.setdefault(e.segment, []).append(e)
        names = sorted(set(ext_by_seg) | set(self._tail), key=natural_key)
        rows: List[TuningRecord] = []
        for name in names:
            for e in ext_by_seg.get(name, ()):
                rows.extend(self._read_extent(e, digest))
            rows.extend(self._tail.get(name, {}).get(digest, ()))
        self._mat[digest] = rows
        return rows

    def _scan_all(self) -> List[TuningRecord]:
        """Every observation on disk right now, in full-load order — the
        lazy store's fallback for whole-store queries (``records()`` with no
        digest). Own appends were flushed, so they are on disk too."""
        from repro.store import index as sidx
        rows: List[TuningRecord] = []
        for seg in self._segments():
            for offset, nbytes, raw in sidx.iter_complete_lines(seg):
                self.bytes_read += nbytes
                text = raw.decode("utf-8").strip()
                if not text:
                    continue
                d = json.loads(text)
                if d.get("kind") == "obs":
                    rows.append(TuningRecord.from_json(d))
        return rows

    # -- appending ----------------------------------------------------------
    def _handle(self):
        if self._fh is None:
            if self.single_file:
                parent = os.path.dirname(self.path)
                if parent:
                    os.makedirs(parent, exist_ok=True)
                self._fh = open(self.path, "a")
            else:
                os.makedirs(self.path, exist_ok=True)
                # start past both the segments on disk AND any compaction
                # high water: reusing a folded (deleted) segment name would
                # corrupt concurrent watcher tails
                k = _segment_high_water(self.path).get(os.getpid(), -1) + 1
                while True:
                    seg = os.path.join(self.path,
                                       f"segment-{os.getpid()}-{k}.jsonl")
                    if not os.path.exists(seg):
                        break
                    k += 1
                self._fh = open(seg, "a")
        return self._fh

    def register(self, fp: SpaceFingerprint) -> str:
        """Record a fingerprint descriptor (idempotent). Returns the digest."""
        if fp.digest not in self._written_fps:
            self._handle().write(json.dumps(fp.to_json()) + "\n")
            self._handle().flush()
            self._written_fps.add(fp.digest)
        self._fps.setdefault(fp.digest, fp)
        return fp.digest

    def append(self, rec: TuningRecord,
               fingerprint: Optional[SpaceFingerprint] = None) -> None:
        """Append one observation; flushes so crashes leave a valid prefix."""
        if fingerprint is not None:
            if rec.fp and rec.fp != fingerprint.digest:
                raise ValueError(f"record fp {rec.fp} != fingerprint "
                                 f"{fingerprint.digest}")
            rec.fp = fingerprint.digest
            self.register(fingerprint)
        if rec.fp not in self._fps:
            raise ValueError(f"unknown fingerprint {rec.fp!r}: register the "
                             "descriptor first (append(rec, fingerprint=...))")
        if rec.fp not in self._written_fps:
            self.register(self._fps[rec.fp])
        fh = self._handle()
        fh.write(json.dumps(rec.to_json()) + "\n")
        fh.flush()
        if self.lazy:
            self._appended_by_fp.setdefault(rec.fp, []).append(rec)
            self._appended_total += 1
        else:
            self._by_fp.setdefault(rec.fp, []).append(len(self._records))
            self._records.append(rec)

    def append_control(self, d: Dict[str, Any]) -> None:
        """Append one raw control record (``kind`` other than fp/obs) —
        the durable queue's write path. Flushed like observations."""
        fh = self._handle()
        fh.write(json.dumps(d) + "\n")
        fh.flush()

    def extend(self, recs: Iterable[TuningRecord],
               fingerprint: Optional[SpaceFingerprint] = None) -> None:
        for rec in recs:
            self.append(rec, fingerprint=fingerprint)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
            self._written_fps = set()

    # -- queries ------------------------------------------------------------
    def __len__(self) -> int:
        if self.lazy:
            return self._index.total + self._tail_total + self._appended_total
        return len(self._records)

    def fingerprints(self) -> Dict[str, SpaceFingerprint]:
        return dict(self._fps)

    def fingerprint_info(self, digest: str) -> Optional[SpaceFingerprint]:
        return self._fps.get(digest)

    def records(self, fp: Optional[str] = None,
                run: Optional[str] = None) -> List[TuningRecord]:
        """Records in append order, optionally filtered by digest and/or run.
        On a lazy store, passing a digest reads only that digest's extents;
        ``fp=None`` falls back to a full segment scan (preserving the same
        global order a ``load=True`` open returns) — whole-store consumers
        should open with ``load=True`` instead."""
        if fp is not None:
            if self.lazy:
                rows: Sequence[TuningRecord] = (
                    self._materialize(fp) + self._appended_by_fp.get(fp, []))
            else:
                rows = [self._records[i] for i in self._by_fp.get(fp, ())]
        elif self.lazy:
            rows = self._scan_all()
        else:
            rows = self._records
        if run is not None:
            rows = [r for r in rows if r.run == run]
        return list(rows)

    def runs(self, fp: Optional[str] = None) -> List[str]:
        seen: Dict[str, None] = {}
        for r in self.records(fp=fp):
            seen.setdefault(r.run, None)
        return list(seen)

    def best(self, fp: str) -> Optional[TuningRecord]:
        """Best (lowest finite value) record for an exact fingerprint; the
        first record achieving the minimum wins, matching full-load order.
        On a lazy store whose digest has no un-indexed tail or own appends,
        this reads ONE extent: the first whose cached best equals the
        digest's minimum — earlier extents all have strictly worse bests,
        so their records cannot be the first achiever."""
        if self.lazy:
            return self._lazy_best(fp)
        best: Optional[TuningRecord] = None
        for i in self._by_fp.get(fp, ()):
            r = self._records[i]
            if math.isfinite(r.value) and (best is None
                                           or r.value < best.value):
                best = r
        return best

    @staticmethod
    def _first_min(rows: Sequence[TuningRecord]) -> Optional[TuningRecord]:
        best: Optional[TuningRecord] = None
        for r in rows:
            if math.isfinite(r.value) and (best is None
                                           or r.value < best.value):
                best = r
        return best

    def _lazy_best(self, fp: str) -> Optional[TuningRecord]:
        tail_or_appended = (fp in self._appended_by_fp or any(
            fp in per_fp for per_fp in self._tail.values()))
        if fp in self._mat or tail_or_appended:
            return self._first_min(self.records(fp=fp))
        exts = self._index.extents.get(fp, ())
        bests = [e.best for e in exts if e.best is not None]
        if not bests:
            return None
        m = min(bests)
        for e in exts:
            if e.best == m:
                try:
                    rows = self._read_extent(e, fp)
                except FileNotFoundError:
                    # compaction swapped the snapshot: reopen and fall back
                    self._reopen_lazy()
                    return self._lazy_best(fp)
                return self._first_min(rows)
        return None

    def best_config(self, fp) -> Optional[Tuple[Dict[str, Any], float]]:
        """(config, value) of the best prior evaluation for this problem.
        ``fp`` may be a SpaceFingerprint or a digest string. The serve/launch
        layer calls this before falling back to built-in defaults."""
        digest = fp.digest if isinstance(fp, SpaceFingerprint) else fp
        rec = self.best(digest)
        if rec is None or rec.config is None:
            return None
        return dict(rec.config), rec.value
