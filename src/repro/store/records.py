"""Persistent tuning-record store (DESIGN.md §11).

One schema for every observation the system produces — engine journals,
benchmark runs, golden traces, dry-run compile tunings. Records are
append-only JSONL, keyed by a ``SpaceFingerprint``: the identity of a tuning
problem (parameter grid, restriction signature, objective id, device
context). The store is the substrate for checkpoint/resume (a run's journal
is the ordered record stream of its ``run`` id) and for transfer-aware
warm starts (``repro.store.transfer`` matches prior records — exact
fingerprint or compatible-dims cross-size — into a new run).

Layout:
  * directory mode — ``<path>/segment-*.jsonl``, one segment per writer;
    shared store across runs/benchmarks;
  * single-file mode — ``<path>`` ends in ``.json``/``.jsonl``: the whole
    store is one segment. This is what a per-run checkpoint path becomes
    (the legacy whole-journal-rewrite JSON format is migrated in place by
    ``repro.store.migrate``).

Each line is either a fingerprint descriptor (``kind: fp`` — written once
per digest per segment, making segments self-contained) or an observation
(``kind: obs``). Appends are flushed per record, so a killed run leaves a
valid record-stream prefix; a torn final line is tolerated on load.
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import re
from dataclasses import dataclass, field
from functools import cached_property
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.searchspace import SearchSpace

SCHEMA_VERSION = 1


@dataclass(frozen=True)
class SpaceFingerprint:
    """Identity of a tuning problem: dims + restrictions + objective + device.

    ``params`` stores each parameter's ordered value grid as strings, so a
    fingerprint is JSON-stable and can renormalize configs from *its own*
    grid without reconstructing a SearchSpace — which is what makes
    cross-size transfer possible from records alone.
    """

    params: Tuple[Tuple[str, Tuple[str, ...]], ...]
    size: int                    # kept configs (captures the filter effect)
    cartesian: int
    restrictions: Tuple[str, ...]
    objective: str               # objective id, e.g. "expdist@a100"
    context: str = ""            # device/deployment context

    @cached_property
    def digest(self) -> str:
        blob = json.dumps([list(map(list, self.params)), self.size,
                           self.cartesian, list(self.restrictions),
                           self.objective, self.context])
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    @classmethod
    def of(cls, space: SearchSpace, objective: str = "",
           context: str = "") -> "SpaceFingerprint":
        return cls(
            params=tuple((p.name, tuple(str(v) for v in p.values))
                         for p in space.params),
            size=int(space.size), cartesian=int(space.cartesian_size),
            restrictions=tuple(
                getattr(c, "name", getattr(c, "__name__", "<restriction>"))
                for c in space.constraints),
            objective=str(objective), context=str(context))

    def compatible(self, other: "SpaceFingerprint") -> bool:
        """Cross-size transferable: same parameter names in the same order
        (the value grids — and so the space sizes — may differ)."""
        return (self.param_names == other.param_names
                and len(self.params) > 0)

    @property
    def param_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.params)

    def x_norm(self, config: Dict[str, Any]) -> Optional[np.ndarray]:
        """Ordinal-normalized position of ``config`` under THIS fingerprint's
        grids (value j of n -> j/(n-1), n==1 -> 0.5); None when a value is
        not on the grid."""
        out = np.empty(len(self.params), np.float32)
        for j, (name, values) in enumerate(self.params):
            if name not in config:
                return None
            try:
                k = values.index(str(config[name]))
            except ValueError:
                return None
            out[j] = 0.5 if len(values) == 1 else k / (len(values) - 1)
        return out

    def to_json(self) -> Dict[str, Any]:
        return {"kind": "fp", "v": SCHEMA_VERSION, "digest": self.digest,
                "params": [[n, list(vs)] for n, vs in self.params],
                "size": self.size, "cartesian": self.cartesian,
                "restrictions": list(self.restrictions),
                "objective": self.objective, "context": self.context}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "SpaceFingerprint":
        return cls(params=tuple((n, tuple(vs)) for n, vs in d["params"]),
                   size=int(d["size"]), cartesian=int(d["cartesian"]),
                   restrictions=tuple(d["restrictions"]),
                   objective=d["objective"], context=d.get("context", ""))


@dataclass
class TuningRecord:
    """One observation: what was evaluated, under which problem identity."""

    fp: str                      # SpaceFingerprint digest
    run: str                     # journal stream id (strategy/seed/run tag)
    seq: int                     # acceptance-order position within the run
    key: str                     # unique evaluation key (space idx or cfg:)
    idx: Optional[int]           # config index (None outside the space)
    value: float                 # objective value, NaN = invalid
    af: Optional[str] = None
    config: Optional[Dict[str, Any]] = None
    worker: str = "main"
    dur: float = 0.0
    t: float = 0.0
    meta: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "kind": "obs", "fp": self.fp, "run": self.run, "seq": self.seq,
            "key": self.key, "idx": self.idx,
            "value": None if not math.isfinite(self.value) else self.value,
            "af": self.af}
        if self.config is not None:
            d["config"] = self.config
        if self.worker != "main":
            d["worker"] = self.worker
        if self.dur:
            d["dur"] = self.dur
        if self.t:
            d["t"] = self.t
        if self.meta:
            d["meta"] = self.meta
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "TuningRecord":
        v = d.get("value")
        return cls(fp=d["fp"], run=d["run"], seq=int(d.get("seq", 0)),
                   key=d["key"],
                   idx=None if d.get("idx") is None else int(d["idx"]),
                   value=math.nan if v is None else float(v),
                   af=d.get("af"), config=d.get("config"),
                   worker=d.get("worker", "main"),
                   dur=float(d.get("dur", 0.0)), t=float(d.get("t", 0.0)),
                   meta=d.get("meta", {}))


def _is_single_file(path: str) -> bool:
    return path.endswith((".json", ".jsonl"))


def natural_key(name: str) -> Tuple:
    """Digit-aware sort key: ``segment-<pid>-10`` after ``segment-<pid>-2``
    (plain lexicographic order breaks past ten rollovers of one writer)."""
    return tuple(int(tok) if tok.isdigit() else tok
                 for tok in re.split(r"(\d+)", name))


def list_segments(path: str, single_file: bool) -> List[str]:
    """A store's segment files in rollover order — the one definition both
    the loader and the live watcher must agree on."""
    if single_file:
        return [path] if os.path.exists(path) else []
    if not os.path.isdir(path):
        return []
    names = sorted((f for f in os.listdir(path) if f.endswith(".jsonl")),
                   key=natural_key)
    return [os.path.join(path, f) for f in names]


class TuningRecordStore:
    """Append-only JSONL segments + in-memory index by fingerprint digest."""

    def __init__(self, path: str, *, load: bool = True):
        """``load=False`` opens a write-only appender: no segment parse, no
        in-memory index — O(1) startup however large the store has grown.
        For producers that only ever ``append`` (serving telemetry); queries
        on such an instance see only its own appends."""
        self.path = path
        self.single_file = _is_single_file(path)
        self._records: List[TuningRecord] = []
        self._by_fp: Dict[str, List[int]] = {}
        self._fps: Dict[str, SpaceFingerprint] = {}
        self._fh = None                    # lazy append handle
        self._written_fps: set = set()     # descriptors this handle has written
        if load:
            self._load()

    # -- loading ------------------------------------------------------------
    def _segments(self) -> List[str]:
        return list_segments(self.path, self.single_file)

    def _load(self) -> None:
        for seg in self._segments():
            with open(seg) as f:
                lines = f.read().splitlines()
            for k, line in enumerate(lines):
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                except json.JSONDecodeError:
                    if k == len(lines) - 1:
                        break   # torn final line from a killed writer
                    raise ValueError(
                        f"{seg}:{k + 1}: corrupt record line — if this is a "
                        "legacy engine checkpoint, migrate it with "
                        "repro.store.migrate.migrate_checkpoint")
                self._ingest(d, seg, k)

    def _ingest(self, d: Dict[str, Any], seg: str, lineno: int) -> None:
        kind = d.get("kind")
        if kind == "fp":
            fp = SpaceFingerprint.from_json(d)
            self._fps.setdefault(fp.digest, fp)
        elif kind == "obs":
            rec = TuningRecord.from_json(d)
            self._by_fp.setdefault(rec.fp, []).append(len(self._records))
            self._records.append(rec)
        else:
            raise ValueError(
                f"{seg}:{lineno + 1}: unknown record kind {kind!r} — if this "
                "is a legacy engine checkpoint, migrate it with "
                "repro.store.migrate.migrate_checkpoint")

    # -- appending ----------------------------------------------------------
    def _handle(self):
        if self._fh is None:
            if self.single_file:
                parent = os.path.dirname(self.path)
                if parent:
                    os.makedirs(parent, exist_ok=True)
                self._fh = open(self.path, "a")
            else:
                os.makedirs(self.path, exist_ok=True)
                k = 0
                while True:
                    seg = os.path.join(self.path,
                                       f"segment-{os.getpid()}-{k}.jsonl")
                    if not os.path.exists(seg):
                        break
                    k += 1
                self._fh = open(seg, "a")
        return self._fh

    def register(self, fp: SpaceFingerprint) -> str:
        """Record a fingerprint descriptor (idempotent). Returns the digest."""
        if fp.digest not in self._written_fps:
            self._handle().write(json.dumps(fp.to_json()) + "\n")
            self._handle().flush()
            self._written_fps.add(fp.digest)
        self._fps.setdefault(fp.digest, fp)
        return fp.digest

    def append(self, rec: TuningRecord,
               fingerprint: Optional[SpaceFingerprint] = None) -> None:
        """Append one observation; flushes so crashes leave a valid prefix."""
        if fingerprint is not None:
            if rec.fp and rec.fp != fingerprint.digest:
                raise ValueError(f"record fp {rec.fp} != fingerprint "
                                 f"{fingerprint.digest}")
            rec.fp = fingerprint.digest
            self.register(fingerprint)
        if rec.fp not in self._fps:
            raise ValueError(f"unknown fingerprint {rec.fp!r}: register the "
                             "descriptor first (append(rec, fingerprint=...))")
        if rec.fp not in self._written_fps:
            self.register(self._fps[rec.fp])
        fh = self._handle()
        fh.write(json.dumps(rec.to_json()) + "\n")
        fh.flush()
        self._by_fp.setdefault(rec.fp, []).append(len(self._records))
        self._records.append(rec)

    def extend(self, recs: Iterable[TuningRecord],
               fingerprint: Optional[SpaceFingerprint] = None) -> None:
        for rec in recs:
            self.append(rec, fingerprint=fingerprint)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
            self._written_fps = set()

    # -- queries ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._records)

    def fingerprints(self) -> Dict[str, SpaceFingerprint]:
        return dict(self._fps)

    def fingerprint_info(self, digest: str) -> Optional[SpaceFingerprint]:
        return self._fps.get(digest)

    def records(self, fp: Optional[str] = None,
                run: Optional[str] = None) -> List[TuningRecord]:
        """Records in append order, optionally filtered by digest and/or run."""
        if fp is not None:
            rows: Sequence[TuningRecord] = [self._records[i]
                                            for i in self._by_fp.get(fp, ())]
        else:
            rows = self._records
        if run is not None:
            rows = [r for r in rows if r.run == run]
        return list(rows)

    def runs(self, fp: Optional[str] = None) -> List[str]:
        seen: Dict[str, None] = {}
        for r in (self.records(fp=fp) if fp is not None else self._records):
            seen.setdefault(r.run, None)
        return list(seen)

    def best(self, fp: str) -> Optional[TuningRecord]:
        """Best (lowest finite value) record for an exact fingerprint."""
        best: Optional[TuningRecord] = None
        for i in self._by_fp.get(fp, ()):
            r = self._records[i]
            if math.isfinite(r.value) and (best is None
                                           or r.value < best.value):
                best = r
        return best

    def best_config(self, fp) -> Optional[Tuple[Dict[str, Any], float]]:
        """(config, value) of the best prior evaluation for this problem.
        ``fp`` may be a SpaceFingerprint or a digest string. The serve/launch
        layer calls this before falling back to built-in defaults."""
        digest = fp.digest if isinstance(fp, SpaceFingerprint) else fp
        rec = self.best(digest)
        if rec is None or rec.config is None:
            return None
        return dict(rec.config), rec.value
