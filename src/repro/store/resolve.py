"""Serve/launch-side config resolution from the record store.

The launchers ask the store for the best prior tuning result of their exact
problem — ``(arch, shape, mesh)`` distribution tuning fingerprint — before
falling back to built-in defaults, so a production deployment never re-pays
tuning cost for a scenario any earlier run (tuner, benchmark, or another
host writing to the same store) has already explored.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

from repro.store.records import SpaceFingerprint, TuningRecordStore

#: sharding-space parameters that map 1:1 onto ParallelConfig fields
_PCFG_FIELDS = ("remat", "attn_q_chunks", "logits_chunk", "attn_block_kv",
                "microbatches", "capacity_factor", "opt_moment_dtype",
                "mlstm_chunk", "attn_block_q", "moe_combine",
                "grad_compression", "grad_compression_topk")


def cell_objective(arch: str, shape: str, mesh: str = "single") -> str:
    """Tuning-objective id of one serving cell — the string every layer
    (dry-run tuner, store resolution, hot reload) keys the cell's
    fingerprints on."""
    return f"dryrun[{arch}×{shape}×{mesh}]"


def best_sharding_config(store, arch: str, shape: str, mesh: str = "single",
                         wide: bool = False
                         ) -> Optional[Tuple[Dict[str, Any], float]]:
    """(config, roofline step time) of the best prior tuning record for this
    (arch, shape, mesh) cell, or None when the store has never seen it."""
    if isinstance(store, str):
        if not os.path.exists(store):
            return None
        # indexed open: resolution touches one cell's fingerprints, so a
        # fleet-scale store must not be parsed wholesale per lookup
        store = TuningRecordStore(store, lazy=True)
    from repro.core.tuning_targets import sharding_space
    space = sharding_space(arch, shape, wide=wide)
    fp = SpaceFingerprint.of(space, objective=cell_objective(arch, shape, mesh))
    hit = store.best_config(fp)
    if hit is not None:
        return hit
    # a narrow-space record also serves a wide lookup (and vice versa): any
    # same-named sharding fingerprint for this cell beats the defaults —
    # minimum over ALL compatible fingerprints, not the first one seen
    best: Optional[Tuple[Dict[str, Any], float]] = None
    for digest, desc in store.fingerprints().items():
        if desc.objective == fp.objective and digest != fp.digest:
            alt = store.best_config(digest)
            if alt is not None and (best is None or alt[1] < best[1]):
                best = alt
    return best


def apply_sharding_config(pcfg, cfg: Dict[str, Any]):
    """Overlay a stored tuning config onto a ParallelConfig (dataclass
    ``replace``): only the knobs ParallelConfig owns; mesh rules
    (experts/embed) are applied by the launch layer, not here."""
    kw = {k: cfg[k] for k in _PCFG_FIELDS if k in cfg}
    if "flash" in cfg:
        # flash=1: blockwise attention always on; flash=0: never
        kw["flash_threshold"] = 0 if cfg["flash"] else 1 << 30
    return pcfg.replace(**kw)


def apply_kernel_config(pcfg, cfg: Dict[str, Any]):
    """Overlay a stored *kernel-cell* block config (DESIGN.md §14/§16) onto
    a ParallelConfig as a ``KernelConfig``. Decode-cell keys
    (``num_splits``/``combine``) enable Pallas flash-decode dispatch;
    flash-cell keys (``block_q``/``block_kv`` without split keys) enable
    Pallas flash; a config carrying neither shape of key (e.g. a gemm
    cell's) leaves the kernel field untouched. Overlays compose: applying a
    decode config on top of a flash-enabled KernelConfig keeps the flash
    blocks (and vice versa), so one server carries both tuned paths."""
    from repro.parallel.sharding import KernelConfig
    base = pcfg.kernel or KernelConfig()
    if "num_splits" in cfg or "combine" in cfg:
        return pcfg.replace(kernel=base.replace(
            use_decode=True,
            decode_block_kv=int(cfg.get("block_kv", base.decode_block_kv)),
            decode_num_splits=int(cfg.get("num_splits",
                                          base.decode_num_splits)),
            decode_combine=str(cfg.get("combine", base.decode_combine))))
    if "block_q" not in cfg and "block_kv" not in cfg:
        return pcfg
    return pcfg.replace(kernel=base.replace(
        use_flash=True,
        flash_block_q=int(cfg.get("block_q", base.flash_block_q)),
        flash_block_kv=int(cfg.get("block_kv", base.flash_block_kv))))
