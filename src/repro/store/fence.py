"""Fencing tokens for the store control plane (DESIGN.md §13).

The append-only log gives durability but not mutual exclusion: two daemons
can both append a claim for one job and each read a view in which it won.
This module supplies the one atomic primitive the filesystem actually
guarantees — ``open(..., O_CREAT | O_EXCL)`` creates a file exactly once —
and builds per-key **monotonically increasing fencing tokens** on it:

    <store>/fence/<key-id>.<N>             token marker (holder JSON inside)
    <store>/fence/<key-id>.<N>.released    holder gave the token up

``issue(key)`` computes the next token above everything on disk (and above
an explicit ``floor`` the caller folded from claim records) and tries to
create its marker; exactly one contender can succeed per token value, so a
successful ``issue`` is a unique, totally ordered grant. Tokens are never
reused or deleted-and-recreated — takeover of a stale holder is "issue the
next token", never "remove the old marker", which closes the classic
unlink/recreate race where a second taker deletes a *fresh* lock.

Consumers enforce the fence: any record written while servicing a claim
carries the claim's token, and folds/readers reject a record whose token is
below the highest token they have seen for that key (``repro.store.queue``
for ``done`` records, ``repro.store.watch.HotConfigSource`` for journaled
observations, ``repro.store.compact`` for the compactor lock). A paused
claimant that wakes after losing its lease therefore cannot corrupt state —
its writes are fenced out by token comparison, no matter how late they land.

Markers are tiny and GC'd opportunistically: a successful ``issue`` removes
markers more than ``_KEEP_BEHIND`` tokens below the one it just granted
(the highest marker must survive — it IS the monotonicity floor)."""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Any, Dict, Optional

from repro.store.records import _is_single_file

#: how many superseded markers to keep behind the newest (debuggability —
#: the crash matrix is easier to read with the last few holders on disk)
_KEEP_BEHIND = 4


class FencedClaimError(RuntimeError):
    """A write was attempted under a token another claimant superseded."""


def fence_dir(store_path: str) -> str:
    """Where a store's fence markers live: a ``fence/`` subdir of a
    directory store (``list_segments`` only matches ``*.jsonl`` files, so
    the subdir is invisible to every reader), or ``<file>.fence`` beside a
    single-file store."""
    if _is_single_file(store_path):
        return store_path + ".fence"
    return os.path.join(store_path, "fence")


def _key_id(key: str) -> str:
    """Filesystem-safe stable id for an arbitrary key (cell keys contain
    ``×``, ``[``, ``/``...)."""
    return hashlib.sha1(key.encode("utf-8")).hexdigest()[:16]


class FenceRegistry:
    """Token issuance + holder metadata for one store's keys."""

    def __init__(self, store_path: str, *, clock=time.time):
        self.dir = fence_dir(store_path)
        self.clock = clock

    # -- reads --------------------------------------------------------------
    def _tokens(self, key: str) -> Dict[int, str]:
        """token -> marker filename, for every marker of ``key`` on disk."""
        kid = _key_id(key)
        out: Dict[int, str] = {}
        try:
            names = os.listdir(self.dir)
        except FileNotFoundError:
            return out
        prefix = kid + "."
        for name in names:
            if not name.startswith(prefix) or name.endswith(".released"):
                continue
            try:
                out[int(name[len(prefix):])] = name
            except ValueError:
                continue
        return out

    def highest(self, key: str) -> int:
        """Highest token ever issued for ``key`` (0 = none)."""
        toks = self._tokens(key)
        return max(toks) if toks else 0

    def released(self, key: str, token: int) -> bool:
        return os.path.exists(os.path.join(
            self.dir, f"{_key_id(key)}.{int(token)}.released"))

    def holder(self, key: str, token: int) -> Optional[Dict[str, Any]]:
        """The marker's holder JSON (``{"key", "by", "t"}``), or None if the
        marker is missing/torn."""
        path = os.path.join(self.dir, f"{_key_id(key)}.{int(token)}")
        try:
            with open(path) as f:
                d = json.load(f)
            return d if isinstance(d, dict) else None
        except (OSError, json.JSONDecodeError):
            return None

    # -- writes -------------------------------------------------------------
    def issue(self, key: str, *, floor: int = 0,
              by: str = "") -> Optional[int]:
        """Atomically grant the next token above both the on-disk markers
        and ``floor`` (the highest token the caller has *folded* — markers
        alone are not enough once old ones are GC'd). Returns the token, or
        None if another contender created the same marker first (the caller
        lost this round; re-read and retry if still appropriate)."""
        os.makedirs(self.dir, exist_ok=True)
        token = max(self.highest(key), int(floor)) + 1
        path = os.path.join(self.dir, f"{_key_id(key)}.{token}")
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return None
        with os.fdopen(fd, "w") as f:
            f.write(json.dumps({"key": key, "by": by,
                                "t": float(self.clock())}))
            f.flush()
        self._gc(key, token)
        return token

    def release(self, key: str, token: int) -> None:
        """Voluntarily give the token up (claim aborted, compactor done):
        the marker stays — monotonicity — but a ``.released`` flag tells
        arbitration not to wait out the holder's TTL."""
        os.makedirs(self.dir, exist_ok=True)
        path = os.path.join(self.dir,
                            f"{_key_id(key)}.{int(token)}.released")
        try:
            with open(path, "w") as f:
                f.write("")
        except OSError:
            pass

    def _gc(self, key: str, newest: int) -> None:
        for token, name in self._tokens(key).items():
            if token < newest - _KEEP_BEHIND:
                for victim in (name, name + ".released"):
                    try:
                        os.unlink(os.path.join(self.dir, victim))
                    except OSError:
                        pass
