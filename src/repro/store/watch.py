"""Online store layer: live tail, prod-latency writeback, drift (DESIGN.md §12).

The record store made tuning knowledge persistent; this module closes the
loop at serve time:

  * ``StoreWatcher`` tail-follows a store's segments by (mtime, byte offset)
    and yields records appended since the last poll — every record exactly
    once, in write order, tolerating a torn (partially flushed) final line
    and segment rollover, without ever re-reading consumed bytes;
  * ``HotConfigSource`` folds the watched stream into "best tuning config
    for one serving cell" and tells the server when a strictly better record
    has landed, so a fleet re-resolves mid-flight instead of at startup only;
  * ``ProdRecorder`` writes measured per-step serving latencies back into
    the store as ``context="prod"`` records under the cell's parameter
    family, so subsequent tuning runs warm-start from real telemetry via the
    existing ``repro.store.transfer.warm_matches`` cross-fingerprint path;
  * ``DriftMonitor`` flags when observed prod latency diverges from the
    stored roofline prediction by a configurable factor, and
    ``OnlineServeLoop`` turns that into a ``RetuneRequest`` on the intake
    queue (the in-process ``repro.core.engine.RetuneQueue`` or the durable
    fleet-wide ``repro.store.queue.TuningJobQueue``).

Everything here is control plane: no jax, no threads, no wall-clock sleeps.
Time enters only through an injectable ``clock`` and latencies measured by
the caller, which is what makes the full store → serve → store cycle
drivable by the deterministic simulation harness (tests/loop_sim.py).
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.store.records import (SpaceFingerprint, TuningRecord,
                                 TuningRecordStore, _is_single_file,
                                 list_segments, natural_key)
from repro.store.resolve import cell_objective


def prod_objective(arch: str, shape: str, mesh: str = "single") -> str:
    """Objective id for serving-telemetry records of a cell. Distinct from
    the tuning id (``cell_objective``) so measured latencies never win a
    ``best_sharding_config`` resolution — they transfer only through the
    warm-start cross-fingerprint path, discounted by the GP."""
    return f"prod[{arch}×{shape}×{mesh}]"


#: How long a directory mtime must have been stable before the watcher
#: trusts its segment-discovery cache: filesystems with coarse timestamp
#: granularity (1-2 s) can create a segment without advancing the mtime.
_DIR_SETTLE_NS = 2_000_000_000


@dataclass
class _Tail:
    """Read position in one segment: only COMPLETE lines are consumed, so a
    torn final line (killed or mid-flush writer) is left for the next poll.
    ``offset`` doubles as the consumed frontier compaction provenance is
    checked against: a record stamped with a source byte offset below it
    was already consumed under that incarnation (delivered, or skipped as
    pre-open history by a ``from_start=False`` tail)."""
    offset: int = 0
    mtime: float = -1.0


class StoreWatcher:
    """Incremental reader over a live store's segments.

    ``poll()`` returns the observations appended since the last call (and
    absorbs fingerprint descriptors into ``fingerprints()``). With
    ``from_start=True`` the first poll replays the whole store — that is how
    a serving process does its initial resolution and its hot reloads
    through one code path.

    Compaction-safe: a ``kind="compact"`` header retires the folded source
    segments before this poll could touch them again (the compacted segment
    sorts first), and each copied record's ``src=[[segment, byte_offset],
    ...]`` provenance chain is checked against the consumed byte frontier
    of every prior incarnation — so a rewrite-and-swap mid-tail re-delivers
    nothing and loses nothing.

    ``collect_controls=True`` additionally retains ``kind="job"`` /
    ``kind="retune"`` control records for ``drain_controls()`` (the durable
    job queue's read path); otherwise they are skipped.

    ``start_offsets`` (basename -> byte offset) seeds per-segment read
    positions: a caller that already consumed a segment's prefix through a
    side channel — the durable queue folding the sidecar index's control
    extents — starts each named segment at its indexed frontier instead of
    replaying it. Unnamed segments keep the ``from_start`` behavior, and the
    pre-frontier bytes count as consumed for compaction provenance (their
    content was delivered, just not through ``poll``).
    """

    def __init__(self, path: str, *, from_start: bool = True,
                 collect_controls: bool = False,
                 start_offsets: Optional[Dict[str, int]] = None):
        self.path = path
        self.single_file = _is_single_file(path)
        self.collect_controls = bool(collect_controls)
        self._tails: Dict[str, _Tail] = {}
        self._fps: Dict[str, SpaceFingerprint] = {}
        self._dead: set = set()       # folded source segments (full paths)
        self._folded: Dict[str, float] = {}   # basename -> consumed lines
        self._controls: List[Dict[str, Any]] = []
        self._dir_mtime_ns = -1       # segment-discovery cache (dir mode)
        if not from_start:
            for seg in self._segments():
                try:
                    st = os.stat(seg)
                except FileNotFoundError:
                    continue
                self._tails[seg] = _Tail(offset=st.st_size, mtime=st.st_mtime)
        elif start_offsets:
            for name, off in start_offsets.items():
                seg = (self.path if self.single_file
                       else os.path.join(self.path, name))
                try:
                    size = os.path.getsize(seg)
                except FileNotFoundError:
                    continue
                # clamp: an offset past the current size (segment rewritten
                # shorter than the index claims) must not wedge the tail
                self._tails[seg] = _Tail(offset=min(int(off), size),
                                         mtime=-1.0)

    def _segments(self) -> List[str]:
        return list_segments(self.path, self.single_file)

    def fingerprints(self) -> Dict[str, SpaceFingerprint]:
        return dict(self._fps)

    def drain_controls(self) -> List[Dict[str, Any]]:
        out, self._controls = self._controls, []
        return out

    def _retire(self, basename: str) -> None:
        """A compaction header folded this source: never read it again, and
        remember its consumed byte frontier — records resurfacing from the
        compacted copy below that offset are already consumed."""
        path = (self.path if self.single_file
                else os.path.join(self.path, basename))
        consumed = self._consumed_bytes(basename)
        prior = self._folded.get(basename)
        self._folded[basename] = (consumed if prior is None
                                  else max(prior, consumed))
        self._dead.add(path)

    def _consumed_bytes(self, basename: str) -> float:
        """Consumed byte frontier of a segment under any incarnation:
        retired frontier if folded, live tail offset otherwise (which for a
        ``from_start=False`` tail starts at the open-time size — pre-open
        history counts consumed, post-open appends do not)."""
        if basename in self._folded:
            return self._folded[basename]
        path = (self.path if self.single_file
                else os.path.join(self.path, basename))
        tail = self._tails.get(path)
        return float(tail.offset) if tail is not None else 0.0

    def _already_delivered(self, chain) -> bool:
        """True if any hop of a compacted record's provenance chain lies
        below the consumed frontier of that incarnation."""
        return any(int(offset) < self._consumed_bytes(name)
                   for name, offset in chain)

    def poll(self) -> List[TuningRecord]:
        """New complete observations, in write order (per segment; segments
        in rollover order — the same natural-numeric order the loader uses,
        which also puts a fresh compacted segment, holding the oldest
        records, ahead of every live one)."""
        out: List[TuningRecord] = []
        known = list(self._tails)
        fresh: List[str] = []
        if self.single_file:
            fresh = [s for s in self._segments() if s not in self._tails]
        else:
            # appends don't touch the directory mtime, segment creation
            # does: skip the listdir on the quiet path (the per-decode-step
            # poll tax is a handful of stats, not a directory scan). An
            # mtime still inside the filesystem's granularity window is
            # never trusted — a segment created in the same timestamp tick
            # as the cached value would otherwise be missed forever.
            try:
                dir_mtime_ns = os.stat(self.path).st_mtime_ns
            except FileNotFoundError:
                dir_mtime_ns = -1
            if (dir_mtime_ns != self._dir_mtime_ns
                    or time.time_ns() - dir_mtime_ns < _DIR_SETTLE_NS):
                fresh = [s for s in self._segments()
                         if s not in self._tails]
                self._dir_mtime_ns = dir_mtime_ns
        order = sorted(set(known) | set(fresh),
                       key=lambda p: natural_key(os.path.basename(p)))
        for seg in order:
            if seg in self._dead:
                continue
            tail = self._tails.setdefault(seg, _Tail())
            try:
                st = os.stat(seg)
            except FileNotFoundError:
                continue
            if st.st_size <= tail.offset and st.st_mtime == tail.mtime:
                continue
            tail.mtime = st.st_mtime
            if st.st_size <= tail.offset:
                continue
            with open(seg, "rb") as f:
                f.seek(tail.offset)
                data = f.read()
            lines = data.split(b"\n")
            partial = lines.pop()          # b"" when data ends in a newline
            for line in lines:
                tail.offset += len(line) + 1
                text = line.decode("utf-8").strip()
                if not text:
                    continue
                d = json.loads(text)
                kind = d.get("kind")
                if kind == "fp":
                    fp = SpaceFingerprint.from_json(d)
                    self._fps.setdefault(fp.digest, fp)
                elif kind == "obs":
                    src = d.get("src")
                    if src is not None and self._already_delivered(src):
                        continue    # delivered under a prior incarnation
                    out.append(TuningRecord.from_json(d))
                elif kind == "compact":
                    for name in d.get("sources", ()):
                        self._retire(name)
                elif kind in ("retune", "job"):
                    src = d.get("src")
                    if self.collect_controls and (
                            src is None
                            or not self._already_delivered(src)):
                        self._controls.append(d)
                else:
                    raise ValueError(f"{seg}:@{tail.offset}: unknown record "
                                     f"kind {kind!r}")
            del partial  # torn tail stays unconsumed until its newline lands
        return out


class HotConfigSource:
    """Best stored tuning config for one serving cell, live.

    Resolution mirrors ``repro.store.resolve.best_sharding_config``: the
    cell's exact fingerprint wins; any compatible fingerprint with the same
    tuning objective id is the cross-digest fallback (minimum over all of
    them). ``refresh()`` folds newly landed records in and returns the
    ``(config, value)`` to deploy when it is strictly better than what is
    currently deployed — the atomic-swap decision point for the serve loop.
    """

    def __init__(self, path: str, arch: str, shape: str,
                 mesh: str = "single", *, wide: bool = False,
                 swap_margin: float = 0.0, space=None,
                 objective_id: Optional[str] = None):
        if space is None:
            from repro.core.tuning_targets import sharding_space
            space = sharding_space(arch, shape, wide=wide)
        self.objective_id = objective_id or cell_objective(arch, shape, mesh)
        self.fp = SpaceFingerprint.of(space, objective=self.objective_id)
        # controls are collected too: job-claim records carry the fencing
        # tokens observation fencing is judged against (see _fold)
        self.watcher = StoreWatcher(path, from_start=True,
                                    collect_controls=True)
        #: highest job-claim fencing token seen per key: an observation
        #: journaled under a LOWER token is a fenced-out (superseded)
        #: claimant's late write and must not steer the hot path
        self._fence_top: Dict[str, int] = {}
        self.fenced_obs_rejected = 0
        #: swap hysteresis (seconds of roofline step time): a same-tier
        #: improvement must beat the deployed value by MORE than this to be
        #: worth the re-jit a swap costs. 0.0 = historical always-swap.
        self.swap_margin = float(swap_margin)
        self._best_exact: Optional[Tuple[Dict[str, Any], float]] = None
        self._best_cross: Optional[Tuple[Dict[str, Any], float]] = None
        self.current: Optional[Tuple[Dict[str, Any], float]] = None
        self._current_tier = 1        # 0 = exact fingerprint, 1 = fallback

    @classmethod
    def for_kernel_cell(cls, path: str, cell, *,
                        device: Optional[str] = None,
                        swap_margin: float = 0.0) -> "HotConfigSource":
        """A live source over a kernel-tuning cell (DESIGN.md §14): same
        tier/hysteresis semantics as sharding cells, keyed under the cell's
        ``kernel[name×shape×device]`` objective id. ``cell`` is a
        ``repro.kernels.tuning.KernelCell``."""
        return cls(path, "", "", space=cell.space,
                   objective_id=cell.objective_id(device),
                   swap_margin=swap_margin)

    @property
    def stale(self) -> bool:
        """No exact-fingerprint record has ever landed: the cell serves a
        cross-digest fallback (or built-in defaults) — its own measured
        problem was never tuned, which makes it a retune candidate."""
        return self._best_exact is None

    def _fold(self, rec: TuningRecord) -> None:
        fence = (rec.meta or {}).get("fence")
        if fence and int(fence.get("token") or 0) < \
                self._fence_top.get(str(fence.get("key", "")), 0):
            # the key's lease moved past this record's token: the writer
            # was fenced out mid-service; the new claimant's run re-journals
            # the cell under the current token
            self.fenced_obs_rejected += 1
            return
        if rec.config is None or not math.isfinite(rec.value):
            return
        if rec.fp == self.fp.digest:
            if self._best_exact is None or rec.value < self._best_exact[1]:
                self._best_exact = (dict(rec.config), rec.value)
            return
        desc = self.watcher.fingerprints().get(rec.fp)
        if desc is not None and desc.objective == self.objective_id:
            if self._best_cross is None or rec.value < self._best_cross[1]:
                self._best_cross = (dict(rec.config), rec.value)

    def refresh(self) -> Optional[Tuple[Dict[str, Any], float]]:
        """Poll the store; return the new (config, value) iff the server
        should swap. Precedence matches a restarting server's resolution,
        so a fleet converges on one config regardless of restart history:
        an exact-fingerprint record outranks any cross-digest fallback
        (even a lower-valued one — exact is the cell's own measured
        problem); within a tier, only a strictly lower roofline value
        swaps, and only by more than ``swap_margin`` — a sub-margin delta
        never pays back the re-jit. A tier upgrade always swaps (it is what
        a restarting server would deploy; the fleet must converge on it).
        Returns None when nothing should change."""
        recs = self.watcher.poll()
        # fold this batch's claim tokens FIRST: a fenced-out claimant's
        # late observations sort after the superseding claim in append
        # order, so token state must lead the observation fold
        for d in self.watcher.drain_controls():
            if d.get("state") == "claim":
                key, tok = str(d.get("key", "")), int(d.get("token") or 0)
                if tok > self._fence_top.get(key, 0):
                    self._fence_top[key] = tok
        for rec in recs:
            self._fold(rec)
        if self._best_exact is not None:
            cand, tier = self._best_exact, 0
        elif self._best_cross is not None:
            cand, tier = self._best_cross, 1
        else:
            return None
        if self.current is not None:
            if (tier, cand[1]) >= (self._current_tier, self.current[1]):
                return None
            if cand[0] == self.current[0]:
                # same config, re-ranked (better value or exact record for
                # the deployed fallback): no swap, no re-jit
                self.current, self._current_tier = cand, tier
                return None
            if tier == self._current_tier \
                    and self.current[1] - cand[1] <= self.swap_margin:
                return None     # better, but not worth a re-jit
        self.current, self._current_tier = cand, tier
        return cand


def _quantile(sorted_vals: List[float], q: float) -> float:
    """Linear-interpolated quantile of an ascending list (numpy 'linear')."""
    n = len(sorted_vals)
    if n == 1:
        return sorted_vals[0]
    pos = q * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    return sorted_vals[lo] + (pos - lo) * (sorted_vals[hi] - sorted_vals[lo])


def latency_summary(window: List[float]) -> Dict[str, float]:
    """Windowed distribution summary journaled alongside each prod record:
    the mean plus the p50/p99 tail — drift policies can key off the tail a
    user actually feels instead of the median. Schema-additive (lives in
    ``meta``); records without it still parse."""
    s = sorted(window)
    return {"p50": _quantile(s, 0.50), "p99": _quantile(s, 0.99),
            "mean": sum(s) / len(s), "n": len(s)}


class ProdRecorder:
    """Serving telemetry → store: measured latencies as ``context="prod"``
    records under the cell's parameter family (same grids as the tuning
    space, ``prod_objective`` id), so ``warm_matches`` transfers them into
    future tuning runs as discounted cross-fingerprint priors. Each decode
    record additionally journals a windowed p50/p99/mean summary of the
    last ``summary_window`` measurements (``meta``, schema-additive)."""

    def __init__(self, store, arch: str, shape: str, mesh: str = "single", *,
                 wide: bool = False, run_id: Optional[str] = None,
                 clock=time.time, summary_window: int = 16):
        from repro.core.tuning_targets import sharding_space
        # a path opens write-only: the recorder only ever appends, and a
        # fleet-scale store must not be parsed into memory per server
        self.store = (TuningRecordStore(store, load=False)
                      if isinstance(store, str) else store)
        self.space = sharding_space(arch, shape, wide=wide)
        self.fp = SpaceFingerprint.of(
            self.space, objective=prod_objective(arch, shape, mesh),
            context="prod")
        self.run_id = run_id or f"serve-{os.getpid()}"
        self.clock = clock
        self.summary_window = max(int(summary_window), 1)
        self._window: List[float] = []
        self._seq = 0

    @property
    def count(self) -> int:
        """Records journaled by this recorder."""
        return self._seq

    def record(self, config: Optional[Dict[str, Any]], latency_s: float, *,
               phase: str = "decode") -> TuningRecord:
        """One measured step. ``config=None`` (built-in defaults, nothing
        resolved) is still journaled — telemetry — but carries no config and
        so never transfers."""
        idx = (self.space.index_of(config) if config is not None else None)
        key = (str(int(idx)) if idx is not None else
               "cfg:" + json.dumps(config, sort_keys=True, default=str)
               if config is not None else f"default:{self._seq}")
        meta: Dict[str, Any] = {"phase": phase}
        if phase == "decode":
            # prefill is in different units and would poison the window
            self._window = (self._window
                            + [float(latency_s)])[-self.summary_window:]
            meta.update(latency_summary(self._window))
        rec = TuningRecord(
            fp=self.fp.digest, run=self.run_id, seq=self._seq, key=key,
            idx=None if idx is None else int(idx), value=float(latency_s),
            config=None if config is None else dict(config),
            dur=float(latency_s), t=float(self.clock()),
            meta=meta)
        self._seq += 1
        self.store.append(rec, fingerprint=self.fp)
        return rec


class DriftMonitor:
    """Windowed divergence of observed latency from the stored prediction.

    Triggers when the chosen window statistic (``stat``: the median by
    default; ``"p99"`` keys the alarm off the tail users actually feel,
    ``"mean"`` off throughput) of the last ``window`` observations is off
    the roofline prediction by more than ``factor`` in either direction
    (slower: the stored config is stale for this hardware/load; faster: the
    roofline itself is stale and tuning is mis-ranking). Every ``observe``
    surfaces the full windowed summary (``last_p50``/``last_p99``/
    ``last_mean``) regardless of which statistic triggers. Re-arms by
    clearing the window, so one drifted regime yields one trigger, not one
    per step."""

    STATS = ("median", "p50", "p99", "mean")

    def __init__(self, predicted: Optional[float] = None, *,
                 factor: float = 1.5, window: int = 8,
                 stat: str = "median"):
        if factor <= 1.0:
            raise ValueError(f"drift factor must be > 1, got {factor}")
        if stat not in self.STATS:
            raise ValueError(f"drift stat must be one of {self.STATS}, "
                             f"got {stat!r}")
        self.predicted = predicted
        self.factor = factor
        self.window = max(int(window), 1)
        self.stat = stat
        self._obs: List[float] = []
        self.last_median: float = math.nan
        self.last_p50: float = math.nan
        self.last_p99: float = math.nan
        self.last_mean: float = math.nan

    @property
    def last_stat(self) -> float:
        """The triggering statistic's latest windowed value."""
        return {"median": self.last_median, "p50": self.last_p50,
                "p99": self.last_p99, "mean": self.last_mean}[self.stat]

    def rebase(self, predicted: Optional[float]) -> None:
        """New config deployed: new prediction, fresh window."""
        self.predicted = predicted
        self._obs = []

    def observe(self, latency_s: float) -> bool:
        if self.predicted is None or self.predicted <= 0:
            return False
        self._obs.append(float(latency_s))
        if len(self._obs) < self.window:
            return False
        self._obs = self._obs[-self.window:]
        summary = latency_summary(self._obs)
        self.last_median = self.last_p50 = summary["p50"]
        self.last_p99 = summary["p99"]
        self.last_mean = summary["mean"]
        ratio = self.last_stat / self.predicted
        if ratio > self.factor or ratio < 1.0 / self.factor:
            self._obs = []
            return True
        return False



@dataclass
class ServeStats:
    """What one ``OnlineServeLoop.run`` did, for tests and logs."""
    steps: int = 0
    latencies: List[float] = field(default_factory=list)
    swaps: List[Tuple[int, Dict[str, Any], float]] = field(
        default_factory=list)          # (global step, config, roofline value)
    kernel_swaps: List[Tuple[int, Dict[str, Any], float]] = field(
        default_factory=list)          # (global step, block config, step time)
    retunes_requested: int = 0
    kernel_retunes_requested: int = 0
    #: decode steps served by the Pallas flash-decode path vs the pure-JAX
    #: fallback (servers expose ``decode_dispatch``; a data plane without
    #: the attribute counts as pure-JAX — it IS the fallback)
    decode_steps_pallas: int = 0
    decode_steps_jax: int = 0


class OnlineServeLoop:
    """The serve-side control loop: between decode steps, poll the store and
    atomically swap in a strictly better config (no restart — the server
    keeps its params/cache and only re-derives its step functions); after
    each step, write the measured latency back as prod telemetry and check
    it against the deployed config's roofline prediction, enqueuing a
    ``RetuneRequest`` on drift.

    ``server`` is the data plane: ``decode_step() -> latency_s`` and
    ``apply_config(config_dict)``. The real one lives in
    ``repro.launch.serve.DecodeServer``; the simulation harness substitutes
    an in-process stub driven by a virtual clock.
    """

    def __init__(self, server, source: Optional[HotConfigSource] = None, *,
                 recorder: Optional[ProdRecorder] = None,
                 monitor: Optional[DriftMonitor] = None,
                 retune_queue=None, cell_key: str = "",
                 poll_every: int = 1, clock=time.time,
                 first_step_warmup: bool = False,
                 kernel_source: Optional[HotConfigSource] = None,
                 kernel_sources: Optional[List[HotConfigSource]] = None):
        self.server = server
        self.source = source
        # one loop can watch several kernel cells (flash + decode), each
        # hot-swapping and stale-enqueuing independently; ``kernel_source``
        # (singular) is the original single-cell spelling
        self.kernel_sources: List[HotConfigSource] = list(kernel_sources or ())
        if kernel_source is not None:
            self.kernel_sources.insert(0, kernel_source)
        self.kernel_source = (self.kernel_sources[0]
                              if self.kernel_sources else None)
        self.recorder = recorder
        self.monitor = monitor
        self.retune_queue = retune_queue
        self.cell_key = cell_key
        self.poll_every = max(int(poll_every), 1)
        self.clock = clock
        self.config: Optional[Dict[str, Any]] = (
            source.current[0] if source is not None and source.current
            else None)
        self.step = 0          # global decode-step counter across run() calls
        # first step after a swap pays the re-jit; a real (jit-compiled)
        # data plane also pays it on its very first step, before any swap —
        # the launcher passes first_step_warmup=True for that
        self._warmup = bool(first_step_warmup)

    def _maybe_swap(self, stats: ServeStats) -> None:
        hit = self.source.refresh() if self.source is not None else None
        if hit is None:
            # the deployed config can be re-ranked in place (an exact record
            # landing for it, or a better measurement): no swap, but the
            # drift monitor must judge against the CURRENT roofline
            if (self.monitor is not None and self.source is not None
                    and self.source.current is not None
                    and self.monitor.predicted != self.source.current[1]):
                self.monitor.rebase(self.source.current[1])
            return
        cfg, value = hit
        self.server.apply_config(cfg)
        self.config = dict(cfg)
        self._warmup = True
        if self.monitor is not None:
            self.monitor.rebase(value)
        stats.swaps.append((self.step, dict(cfg), value))

    def _maybe_swap_kernel(self, stats: ServeStats) -> None:
        """Kernel hot-swap mirrors the sharding one (same tier/margin
        hysteresis inside the source) but does NOT rebase the drift monitor:
        the roofline prediction judges the *sharding* config, and a kernel
        block change doesn't invalidate it."""
        apply = getattr(self.server, "apply_kernel_config", None)
        for src in self.kernel_sources:
            hit = src.refresh()
            if hit is None:
                continue
            cfg, value = hit
            if apply is None:
                continue     # data plane has no kernel dispatch (e.g. old stub)
            apply(cfg)
            self._warmup = True    # first post-swap step pays the re-jit
            stats.kernel_swaps.append((self.step, dict(cfg), value))

    def _maybe_retune_kernel(self, stats: ServeStats) -> None:
        """Kernel-cell staleness → durable retune request: while no exact
        record exists for this cell's kernel fingerprint (serving a
        cross-shape fallback or pure-JAX defaults), ask the fleet to tune
        it. The durable queue dedupes per cell key, so re-checking every
        poll costs one open-ticket lookup, not duplicate work; after a
        daemon services the request, the tuned record lands, ``stale``
        flips, and submissions stop."""
        if self.retune_queue is None:
            return
        from repro.core.engine import RetuneRequest
        for src in self.kernel_sources:
            if not src.stale:
                continue
            accepted = self.retune_queue.submit(RetuneRequest(
                key=src.objective_id, objective=src.objective_id,
                observed=math.nan, predicted=math.nan,
                reason="stale", t=float(self.clock())))
            stats.kernel_retunes_requested += int(accepted)

    def run(self, steps: int) -> ServeStats:
        stats = ServeStats()
        for _ in range(int(steps)):
            if self.step % self.poll_every == 0:
                self._maybe_swap(stats)
                self._maybe_swap_kernel(stats)
                self._maybe_retune_kernel(stats)
            dt = self.server.decode_step()
            stats.steps += 1
            stats.latencies.append(dt)
            if getattr(self.server, "decode_dispatch", "jax") == "pallas":
                stats.decode_steps_pallas += 1
            else:
                stats.decode_steps_jax += 1
            if self._warmup:
                # the first post-swap step includes the re-jit: neither
                # telemetry the warm start should learn from nor a latency
                # the drift monitor should judge the new config by
                self._warmup = False
                self.step += 1
                continue
            if self.recorder is not None:
                self.recorder.record(self.config, dt, phase="decode")
            if self.monitor is not None and self.monitor.observe(dt):
                if self.retune_queue is not None:
                    from repro.core.engine import RetuneRequest
                    accepted = self.retune_queue.submit(RetuneRequest(
                        key=self.cell_key or (
                            self.source.objective_id if self.source else ""),
                        objective=(self.source.objective_id
                                   if self.source else ""),
                        observed=self.monitor.last_stat,
                        predicted=self.monitor.predicted or math.nan,
                        t=float(self.clock())))
                    stats.retunes_requested += int(accepted)
            self.step += 1
        return stats
