"""Persistent tuning-record store + transfer-aware warm starts (DESIGN.md §11).

The observation/results subsystem: every layer produces into and consumes
from one append-only record store keyed by search-space fingerprints —
engine journals (checkpoint/resume), benchmark matrices, golden traces,
dry-run compile tunings, and the serve-time best-config lookup. §13 adds
the fleet-scale pieces: the sidecar segment index behind ``lazy=True``
opens, fence-locked segment compaction/GC, and the durable store-backed
tuning-job queue (exactly-once under N racing daemons via fencing tokens).
"""
from repro.store.records import (SpaceFingerprint, TuningRecord,
                                 TuningRecordStore)
from repro.store.transfer import warm_matches
from repro.store.migrate import (ingest_golden, is_legacy_checkpoint,
                                 migrate_checkpoint)
from repro.store.resolve import (apply_kernel_config, apply_sharding_config,
                                 best_sharding_config, cell_objective)
from repro.store.watch import (DriftMonitor, HotConfigSource, OnlineServeLoop,
                               ProdRecorder, ServeStats, StoreWatcher,
                               latency_summary, prod_objective)
from repro.store.index import (StoreIndex, build_index, index_path,
                               load_index, write_index)
from repro.store.compact import (CompactionLocked, CompactionStats,
                                 compact_store)
from repro.store.fence import FencedClaimError, FenceRegistry
from repro.store.queue import (JOB_TYPES, DurableRetuneQueue, JobTicket,
                               RetuneTicket, TuningJobQueue)

__all__ = ["SpaceFingerprint", "TuningRecord", "TuningRecordStore",
           "warm_matches", "ingest_golden", "is_legacy_checkpoint",
           "migrate_checkpoint", "apply_kernel_config",
           "apply_sharding_config",
           "best_sharding_config", "cell_objective", "prod_objective",
           "StoreWatcher", "HotConfigSource", "ProdRecorder", "DriftMonitor",
           "OnlineServeLoop", "ServeStats", "latency_summary",
           "StoreIndex", "build_index", "index_path", "load_index",
           "write_index", "CompactionLocked", "CompactionStats",
           "compact_store", "FencedClaimError", "FenceRegistry",
           "JOB_TYPES", "TuningJobQueue", "JobTicket",
           "DurableRetuneQueue", "RetuneTicket"]
