"""Segment compaction / GC: rewrite-and-swap that keeps live tails honest
(DESIGN.md §13).

An append-only store only ever grows; compaction folds the *sealed*
segments of a directory store — every segment except each writer pid's
highest-numbered one, which may still be held open by a live appender —
into a single ``segment-0-<gen>.jsonl``, dropping what retention allows:

  * superseded ``context="prod"`` telemetry past the retention window
    (a later measurement of the same (fingerprint, config) exists), so
    serving writeback is bounded by the number of distinct configs served
    rather than the number of decode steps;
  * completed re-tune control groups (``kind="retune"`` submit/claim/done
    triples whose ``done`` landed before the window).

Everything else — tuning observations, fingerprint descriptors, open
retune requests — survives verbatim, so resolution (``best_sharding_config``,
``HotConfigSource``) is identical before and after.

The swap is crash-safe and watcher-safe:

  1. the compacted segment is written complete to a temp name and renamed
     into place (atomic; its first line is a ``kind="compact"`` header
     naming the folded sources, and every copied record carries a
     ``src=[[segment, byte_offset], ...]`` provenance chain — one hop per
     compaction it has survived);
  2. only then are the source files unlinked.

A concurrent ``StoreWatcher`` keeps exactly-once delivery through the swap:
``segment-0-*`` sorts before every live segment, so a watcher meets the
header before it could touch a folded source again, retires those tails,
and checks the ``src`` hops against each incarnation's consumed byte
frontier to deliver precisely the records it had not yet seen. A crash between rename
and unlink leaves records duplicated on disk but NOT double-delivered to
watchers (the header retires the sources first); re-running compaction
converges. Single-file stores have no sealed segments and cannot be
compacted.

"Sealed" is judged per writer pid (everything below the pid's
highest-numbered segment), so it assumes at most one LIVE appender per
process: a process holding several open appenders on one store must close
(seal) all but its newest before compaction may run — the loop-sim's
``seal_segment`` models exactly that. A lock-file handshake making both
this and the one-compactor-at-a-time assumption explicit is a ROADMAP
item.
"""
from __future__ import annotations

import json
import math
import os
import re
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.store.index import build_index, iter_complete_lines, write_index
from repro.store.records import (_is_single_file, _segment_high_water,
                                 list_segments)

_SEG_RE = re.compile(r"segment-(\d+)-(\d+)\.jsonl$")


@dataclass
class CompactionStats:
    """What one ``compact_store`` call did."""

    sources: List[str] = field(default_factory=list)
    output: Optional[str] = None
    records_in: int = 0
    records_kept: int = 0
    dropped_prod: int = 0
    dropped_retune: int = 0
    bytes_before: int = 0
    bytes_after: int = 0

    @property
    def folded(self) -> bool:
        return self.output is not None


def _parse_seg(name: str) -> Optional[Tuple[int, int]]:
    m = _SEG_RE.match(name)
    return (int(m.group(1)), int(m.group(2))) if m else None


def compact_store(path: str, *, retention_s: float = math.inf,
                  now: Optional[float] = None,
                  clock=time.time) -> CompactionStats:
    """Fold the sealed segments of a directory store. ``retention_s`` bounds
    the GC window (default: keep everything — pure folding); ``now`` pins
    the window edge for deterministic tests. One compactor at a time."""
    if _is_single_file(path):
        raise ValueError("compaction requires a directory store "
                         "(a single-file journal is one live segment)")
    t_now = clock() if now is None else float(now)
    stats = CompactionStats()
    segs = [(seg, _parse_seg(os.path.basename(seg)))
            for seg in list_segments(path, False)]
    active: Dict[int, int] = {}
    for _, parsed in segs:
        if parsed and parsed[0] != 0:
            active[parsed[0]] = max(active.get(parsed[0], -1), parsed[1])
    sources = [seg for seg, parsed in segs
               if parsed and (parsed[0] == 0 or parsed[1] < active[parsed[0]])]
    if not sources:
        return stats
    stats.sources = [os.path.basename(s) for s in sources]

    # -- scan the sources: descriptors, surviving candidates, high water ----
    high_water: Dict[int, int] = {}
    fps: Dict[str, dict] = {}
    entries: List[Tuple[str, int, dict]] = []     # (src_name, line_no, dict)
    for seg in sources:
        name = os.path.basename(seg)
        pid, k = _parse_seg(name)
        high_water[pid] = max(high_water.get(pid, -1), k)
        stats.bytes_before += os.path.getsize(seg)
        for offset, nbytes, raw in iter_complete_lines(seg):
            text = raw.decode("utf-8").strip()
            if not text:
                continue
            d = json.loads(text)
            kind = d.get("kind")
            if kind == "compact":
                for p, hk in d.get("high_water", {}).items():
                    p = int(p)
                    high_water[p] = max(high_water.get(p, -1), int(hk))
            elif kind == "fp":
                fps.setdefault(d["digest"], d)
            else:
                entries.append((name, offset, d))
    stats.records_in = len(entries)

    # -- GC decisions -------------------------------------------------------
    prod_digests = {dg for dg, d in fps.items()
                    if d.get("context") == "prod"}
    # superseded = a LATER record for the same (fingerprint, config index)
    # exists among the folded sources (idx None — configless telemetry —
    # supersedes per fingerprint, bounding defaults journaling too)
    last_at: Dict[Tuple[str, Optional[int]], int] = {}
    retune_done_t: Dict[str, float] = {}
    for i, (_, _, d) in enumerate(entries):
        if d.get("kind") == "obs" and d.get("fp") in prod_digests:
            last_at[(d["fp"], d.get("idx"))] = i
        elif d.get("kind") == "retune" and d.get("state") == "done":
            rid = d.get("id", "")
            retune_done_t[rid] = max(retune_done_t.get(rid, 0.0),
                                     float(d.get("t", 0.0)))
    dead_retunes = {rid for rid, t in retune_done_t.items()
                    if t < t_now - retention_s}
    kept: List[Tuple[str, int, dict]] = []
    for i, (src, offset, d) in enumerate(entries):
        kind = d.get("kind")
        if kind == "obs" and d.get("fp") in prod_digests \
                and last_at[(d["fp"], d.get("idx"))] != i \
                and float(d.get("t", 0.0)) < t_now - retention_s:
            stats.dropped_prod += 1
            continue
        if kind == "retune" and d.get("id", "") in dead_retunes:
            stats.dropped_retune += 1
            continue
        kept.append((src, offset, d))
    stats.records_kept = len(kept)

    # -- rewrite and swap ---------------------------------------------------
    hw_disk = _segment_high_water(path)
    gen = max(high_water.get(0, -1), hw_disk.get(0, -1)) + 1
    out_name = f"segment-0-{gen}.jsonl"
    out_path = os.path.join(path, out_name)
    tmp = out_path + ".tmp"
    merged_hw = dict(hw_disk)
    for p, hk in high_water.items():
        merged_hw[p] = max(merged_hw.get(p, -1), hk)
    with open(tmp, "w") as f:
        f.write(json.dumps({
            "kind": "compact", "v": 1, "gen": gen, "t": t_now,
            "sources": stats.sources,
            "high_water": {str(p): hk for p, hk in
                           sorted(merged_hw.items())}}) + "\n")
        for digest in sorted(fps):
            f.write(json.dumps(fps[digest]) + "\n")
        for src, offset, d in kept:
            d = dict(d)
            # provenance CHAIN, one hop per survived compaction: a watcher
            # skips a record if ANY prior incarnation was already consumed
            # — a single hop is not enough when a compacted segment is
            # folded again before some watcher ever read it
            prior = d.get("src") or []
            d["src"] = list(prior) + [[src, offset]]
            f.write(json.dumps(d) + "\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, out_path)          # the swap: compacted data is visible
    for seg in sources:                # only now may the sources disappear
        os.unlink(seg)
    stats.output = out_name
    stats.bytes_after = os.path.getsize(out_path)
    write_index(path, build_index(path))   # keep lazy opens O(hot set)
    return stats
