"""Segment compaction / GC: rewrite-and-swap that keeps live tails honest
(DESIGN.md §13).

An append-only store only ever grows; compaction folds the *sealed*
segments of a directory store — every segment except each writer pid's
highest-numbered one, which may still be held open by a live appender —
into a single ``segment-0-<gen>.jsonl``, dropping what retention allows:

  * superseded ``context="prod"`` telemetry past the retention window
    (a later measurement of the same (fingerprint, config) exists), so
    serving writeback is bounded by the number of distinct configs served
    rather than the number of decode steps;
  * completed tuning-job control groups (``kind="job"`` — and legacy
    ``kind="retune"`` — submit/claim/done groups whose *accepted* ``done``
    landed before the window; a ``done`` from a fenced-out claimant never
    counts as completion).

Everything else — tuning observations, fingerprint descriptors, open
job requests — survives verbatim, so resolution (``best_sharding_config``,
``HotConfigSource``) is identical before and after.

The swap is crash-safe and watcher-safe:

  1. the compacted segment is written complete to a temp name and renamed
     into place (atomic; its first line is a ``kind="compact"`` header
     naming the folded sources, and every copied record carries a
     ``src=[[segment, byte_offset], ...]`` provenance chain — one hop per
     compaction it has survived);
  2. only then are the source files unlinked.

A concurrent ``StoreWatcher`` keeps exactly-once delivery through the swap:
``segment-0-*`` sorts before every live segment, so a watcher meets the
header before it could touch a folded source again, retires those tails,
and checks the ``src`` hops against each incarnation's consumed byte
frontier to deliver precisely the records it had not yet seen. A crash between rename
and unlink leaves records duplicated on disk but NOT double-delivered to
watchers (the header retires the sources first); re-running compaction
converges. Single-file stores have no sealed segments and cannot be
compacted.

"Sealed" is judged per writer pid (everything below the pid's
highest-numbered segment), so it assumes at most one LIVE appender per
process: a process holding several open appenders on one store must close
(seal) all but its newest before compaction may run — the loop-sim's
``seal_segment`` models exactly that.

One-compactor-at-a-time is ENFORCED, not assumed: the compactor takes a
fencing-token lock on the reserved key ``__compactor__``
(``repro.store.fence``) before scanning, re-validates it immediately before
the swap, and releases it when done. A second compactor raises
``CompactionLocked`` while the lock is fresh; a compactor that died holding
the lock is taken over once its holder stamp is older than ``lock_ttl`` —
takeover issues the NEXT token (markers are never deleted and re-created),
so a taken-over zombie that wakes finds its token superseded at the
pre-swap check and aborts instead of double-swapping.
"""
from __future__ import annotations

import json
import math
import os
import re
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.store.fence import FenceRegistry
from repro.store.index import build_index, iter_complete_lines, write_index
from repro.store.records import (_is_single_file, _segment_high_water,
                                 list_segments)

_SEG_RE = re.compile(r"segment-(\d+)-(\d+)\.jsonl$")

#: reserved fence key of the store-wide compaction lock
COMPACT_LOCK_KEY = "__compactor__"


class CompactionLocked(RuntimeError):
    """Another compactor holds (or just took over) the compaction lock."""


def _acquire_compact_lock(reg: FenceRegistry, t_now: float,
                          lock_ttl: float) -> int:
    """Take the compaction lock or raise ``CompactionLocked``. A live lock
    is one whose token is unreleased and whose holder stamp is younger than
    ``lock_ttl``; anything else is stale and taken over by issuing the next
    token (never by deleting the old marker — the unlink/recreate race
    would let a second taker remove a FRESH lock)."""
    cur = reg.highest(COMPACT_LOCK_KEY)
    if cur and not reg.released(COMPACT_LOCK_KEY, cur):
        holder = reg.holder(COMPACT_LOCK_KEY, cur) or {}
        age = t_now - float(holder.get("t", -math.inf))
        if age <= lock_ttl:
            raise CompactionLocked(
                f"compaction lock (token {cur}) held by "
                f"{holder.get('by', '?')!r}, {age:.0f}s old "
                f"(lock_ttl={lock_ttl:g}s)")
    token = reg.issue(COMPACT_LOCK_KEY, floor=cur,
                      by=f"compactor-{os.getpid()}")
    if token is None:
        raise CompactionLocked("lost the compaction-lock takeover race")
    return token


@dataclass
class CompactionStats:
    """What one ``compact_store`` call did."""

    sources: List[str] = field(default_factory=list)
    output: Optional[str] = None
    records_in: int = 0
    records_kept: int = 0
    dropped_prod: int = 0
    dropped_retune: int = 0
    bytes_before: int = 0
    bytes_after: int = 0

    @property
    def folded(self) -> bool:
        return self.output is not None


def _parse_seg(name: str) -> Optional[Tuple[int, int]]:
    m = _SEG_RE.match(name)
    return (int(m.group(1)), int(m.group(2))) if m else None


def compact_store(path: str, *, retention_s: float = math.inf,
                  now: Optional[float] = None,
                  clock=time.time, lock_ttl: float = 3600.0
                  ) -> CompactionStats:
    """Fold the sealed segments of a directory store. ``retention_s`` bounds
    the GC window (default: keep everything — pure folding); ``now`` pins
    the window edge for deterministic tests. One compactor at a time,
    enforced: raises ``CompactionLocked`` while another holds the lock
    (stale holders — older than ``lock_ttl`` — are taken over)."""
    if _is_single_file(path):
        raise ValueError("compaction requires a directory store "
                         "(a single-file journal is one live segment)")
    t_now = clock() if now is None else float(now)
    reg = FenceRegistry(path, clock=lambda: t_now)
    lock = _acquire_compact_lock(reg, t_now, float(lock_ttl))
    try:
        return _compact_locked(path, retention_s, t_now, reg, lock)
    finally:
        reg.release(COMPACT_LOCK_KEY, lock)


def _compact_locked(path: str, retention_s: float, t_now: float,
                    reg: FenceRegistry, lock: int) -> CompactionStats:
    stats = CompactionStats()
    segs = [(seg, _parse_seg(os.path.basename(seg)))
            for seg in list_segments(path, False)]
    active: Dict[int, int] = {}
    for _, parsed in segs:
        if parsed and parsed[0] != 0:
            active[parsed[0]] = max(active.get(parsed[0], -1), parsed[1])
    sources = [seg for seg, parsed in segs
               if parsed and (parsed[0] == 0 or parsed[1] < active[parsed[0]])]
    if not sources:
        return stats
    stats.sources = [os.path.basename(s) for s in sources]

    # -- scan the sources: descriptors, surviving candidates, high water ----
    high_water: Dict[int, int] = {}
    fps: Dict[str, dict] = {}
    entries: List[Tuple[str, int, dict]] = []     # (src_name, line_no, dict)
    for seg in sources:
        name = os.path.basename(seg)
        pid, k = _parse_seg(name)
        high_water[pid] = max(high_water.get(pid, -1), k)
        stats.bytes_before += os.path.getsize(seg)
        for offset, nbytes, raw in iter_complete_lines(seg):
            text = raw.decode("utf-8").strip()
            if not text:
                continue
            d = json.loads(text)
            kind = d.get("kind")
            if kind == "compact":
                for p, hk in d.get("high_water", {}).items():
                    p = int(p)
                    high_water[p] = max(high_water.get(p, -1), int(hk))
            elif kind == "fp":
                fps.setdefault(d["digest"], d)
            else:
                entries.append((name, offset, d))
    stats.records_in = len(entries)

    # -- GC decisions -------------------------------------------------------
    prod_digests = {dg for dg, d in fps.items()
                    if d.get("context") == "prod"}
    # superseded = a LATER record for the same (fingerprint, config index)
    # exists among the folded sources (idx None — configless telemetry —
    # supersedes per fingerprint, bounding defaults journaling too)
    last_at: Dict[Tuple[str, Optional[int]], int] = {}
    # job/retune groups are replayed with the queue's own fencing fold: a
    # ``done`` only closes its id if its token is not below the group's
    # highest UNRELEASED claim token at that point — a fenced-out
    # claimant's late ``done`` must not let GC fold away a job another
    # daemon is servicing, while a racer that backed off (claim + release)
    # must not fence the winner it deferred to
    job_done_t: Dict[str, float] = {}
    open_ids: Dict[str, Set[str]] = {}       # key -> open submit ids
    group_claims: Dict[str, Set[int]] = {}   # key -> unreleased claim tokens
    for i, (_, _, d) in enumerate(entries):
        kind = d.get("kind")
        if kind == "obs" and d.get("fp") in prod_digests:
            last_at[(d["fp"], d.get("idx"))] = i
            continue
        if kind not in ("retune", "job"):
            continue
        state, rid = d.get("state"), str(d.get("id", ""))
        key = str(d.get("key", ""))
        if state == "submit":
            open_ids.setdefault(key, set()).add(rid)
        elif state == "claim":
            if rid in open_ids.get(key, ()):
                group_claims.setdefault(key, set()).add(
                    int(d.get("token") or 0))
        elif state == "release":
            group_claims.get(key, set()).discard(int(d.get("token") or 0))
        elif state in ("done", "quarantine"):
            # quarantine is terminal exactly like done (a fresh-token close
            # of a poison group) — same fencing, same retention folding
            token = d.get("token")
            if token is not None \
                    and int(token) < max(group_claims.get(key, ()),
                                         default=0):
                continue                     # fenced: does not close the job
            if rid in open_ids.get(key, ()):
                open_ids[key].discard(rid)
                if not open_ids[key]:
                    group_claims.pop(key, None)  # group closed: fresh fences
            job_done_t[rid] = max(job_done_t.get(rid, 0.0),
                                  float(d.get("t", 0.0)))
    dead_jobs = {rid for rid, t in job_done_t.items()
                 if t < t_now - retention_s}
    kept: List[Tuple[str, int, dict]] = []
    for i, (src, offset, d) in enumerate(entries):
        kind = d.get("kind")
        if kind == "obs" and d.get("fp") in prod_digests \
                and last_at[(d["fp"], d.get("idx"))] != i \
                and float(d.get("t", 0.0)) < t_now - retention_s:
            stats.dropped_prod += 1
            continue
        if kind in ("retune", "job") and d.get("id", "") in dead_jobs:
            stats.dropped_retune += 1
            continue
        kept.append((src, offset, d))
    stats.records_kept = len(kept)

    # -- rewrite and swap ---------------------------------------------------
    hw_disk = _segment_high_water(path)
    gen = max(high_water.get(0, -1), hw_disk.get(0, -1)) + 1
    out_name = f"segment-0-{gen}.jsonl"
    out_path = os.path.join(path, out_name)
    tmp = out_path + ".tmp"
    merged_hw = dict(hw_disk)
    for p, hk in high_water.items():
        merged_hw[p] = max(merged_hw.get(p, -1), hk)
    with open(tmp, "w") as f:
        f.write(json.dumps({
            "kind": "compact", "v": 1, "gen": gen, "t": t_now,
            "lock": lock, "sources": stats.sources,
            "high_water": {str(p): hk for p, hk in
                           sorted(merged_hw.items())}}) + "\n")
        for digest in sorted(fps):
            f.write(json.dumps(fps[digest]) + "\n")
        for src, offset, d in kept:
            d = dict(d)
            # provenance CHAIN, one hop per survived compaction: a watcher
            # skips a record if ANY prior incarnation was already consumed
            # — a single hop is not enough when a compacted segment is
            # folded again before some watcher ever read it
            prior = d.get("src") or []
            d["src"] = list(prior) + [[src, offset]]
            f.write(json.dumps(d) + "\n")
        f.flush()
        os.fsync(f.fileno())
    # pre-swap revalidation: if a peer judged us stale and took the lock
    # over while we scanned, OUR view of the sources is the stale one —
    # abort rather than race the new holder's swap
    if reg.highest(COMPACT_LOCK_KEY) != lock \
            or reg.released(COMPACT_LOCK_KEY, lock):
        os.unlink(tmp)
        raise CompactionLocked(
            f"compaction lock token {lock} superseded mid-compaction "
            "(this compactor was presumed dead and taken over)")
    os.replace(tmp, out_path)          # the swap: compacted data is visible
    for seg in sources:                # only now may the sources disappear
        os.unlink(seg)
    stats.output = out_name
    stats.bytes_after = os.path.getsize(out_path)
    write_index(path, build_index(path))   # keep lazy opens O(hot set)
    return stats
