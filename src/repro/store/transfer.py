"""Transfer-aware warm-start matching (DESIGN.md §11).

Turns prior store records into ``WarmObservation``s for a new run:

  * exact matches — records under the SAME fingerprint digest (identical
    grid, restrictions, objective, context): positions come straight from
    the current space, no discount;
  * cross-size matches — records under a COMPATIBLE fingerprint (same
    parameter names in the same order, different grids/trim/objective — e.g.
    a 512-seq GEMM warm-starting the 4096-seq space): each record is
    renormalized under its OWN fingerprint's grids, nearest-neighbor matched
    into the current space, and discounted with an extra GP noise term that
    grows with the mapping distance, so far-fetched matches inform the
    surrogate weakly instead of poisoning it.

Only finite (valid) observations transfer — the paper never fits invalids to
the GP, and a prior invalid on a different problem size proves nothing here.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.searchspace import SearchSpace
from repro.core.strategies.base import WarmObservation
from repro.store.records import (SpaceFingerprint, TuningRecord,
                                 TuningRecordStore)

#: Base extra GP noise for any cross-fingerprint observation (the surfaces
#: differ even at a perfectly matched config).
CROSS_NOISE = 0.05

#: Additional noise per unit squared mapping distance in normalized space.
DIST_NOISE = 4.0

#: Default cap on transferred observations (GP cost grows with t²).
MAX_WARM = 256


def _finite(recs: Sequence[TuningRecord]) -> List[TuningRecord]:
    return [r for r in recs if np.isfinite(r.value) and r.config is not None]


def warm_matches(store: TuningRecordStore, fingerprint: SpaceFingerprint,
                 space: SearchSpace, *,
                 exclude_runs: Sequence[str] = (),
                 max_warm: int = MAX_WARM,
                 cross_noise: float = CROSS_NOISE,
                 dist_noise: float = DIST_NOISE) -> List[WarmObservation]:
    """Match prior records into ``space``. Exact matches first, then
    cross-size, deduplicated per target config (lowest discount wins).

    ``exclude_runs`` only filters SAME-fingerprint records: it exists so a
    resumed run doesn't warm-start from the very journal it is replaying.
    A run id recurring under a different fingerprint is a different problem
    (e.g. the same strategy/seed tag on another kernel) and transfers."""
    exclude = set(exclude_runs)
    out: List[WarmObservation] = []

    exact = [r for r in _finite(store.records(fp=fingerprint.digest))
             if r.run not in exclude]
    for r in exact:
        idx = r.idx if r.idx is not None else space.index_of(r.config)
        if idx is None or not (0 <= idx < space.size):
            continue
        out.append(WarmObservation(x=np.asarray(space.X_norm[int(idx)],
                                                np.float64),
                                   value=float(r.value), idx=int(idx),
                                   exact=True, noise=0.0,
                                   config=dict(r.config)))

    for digest, desc in store.fingerprints().items():
        if digest == fingerprint.digest or not fingerprint.compatible(desc):
            continue
        recs = _finite(store.records(fp=digest))
        if not recs:
            continue
        xs, kept = [], []
        for r in recs:
            x = desc.x_norm(r.config)
            if x is not None:
                xs.append(x)
                kept.append(r)
        if not xs:
            continue
        src = np.stack(xs)
        tgt = space.nearest_indices(src)          # NN parameter matching
        for r, x_src, i in zip(kept, src, tgt):
            x_tgt = np.asarray(space.X_norm[int(i)], np.float64)
            d2 = float(np.sum((x_src.astype(np.float64) - x_tgt) ** 2))
            out.append(WarmObservation(
                x=x_tgt, value=float(r.value), idx=int(i), exact=False,
                noise=cross_noise + dist_noise * d2, config=dict(r.config)))

    # dedupe per target config: exact beats cross, lower discount beats
    # higher, then better value — one observation per site keeps the GP
    # Cholesky well-conditioned
    by_idx: Dict[int, WarmObservation] = {}
    for w in out:
        prev = by_idx.get(w.idx)
        if (prev is None
                or (w.exact, -w.noise, -w.value)
                > (prev.exact, -prev.noise, -prev.value)):
            by_idx[w.idx] = w
    deduped = sorted(by_idx.values(),
                     key=lambda w: (not w.exact, w.noise, w.value))
    return deduped[:max_warm]
