"""Migration shims: the three ad-hoc JSON shapes -> one store schema.

Before the store existed, observations lived in

  1. bespoke engine checkpoints — ``{"objective", "budget", "journal":
     [[idx, key, value, af], ...]}`` rewritten wholesale per evaluation;
  2. golden traces — ``tests/golden/seed_traces.json``:
     ``{case: {"journal": [[key, value|null, af], ...], ...}}``;
  3. benchmark matrices — best-so-far traces only (no journals), written by
     ``benchmarks/common.py`` (which now records journals into the store
     directly, so those need no migration).

``migrate_checkpoint`` rewrites (1) in place as a single-file store segment,
so ``TuningRun.resume`` keeps working on journals written before this
refactor; ``ingest_golden`` lifts (2) into any store.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, List, Optional, Tuple

from repro.core.searchspace import SearchSpace
from repro.store.records import (SpaceFingerprint, TuningRecord,
                                 TuningRecordStore)


def _config_for(space: SearchSpace, idx: Optional[int],
                key: str) -> Optional[Dict[str, Any]]:
    if idx is not None and 0 <= int(idx) < space.size:
        return space.config(int(idx))
    if key.startswith("cfg:"):
        try:
            return json.loads(key[4:])
        except json.JSONDecodeError:
            return None
    return None


def is_legacy_checkpoint(path: str) -> bool:
    """The bespoke pre-store engine checkpoint: one JSON object holding the
    whole journal (rewritten per evaluation). Written by ``json.dump`` with
    no indent, so the whole object is the file's first line — reading that
    line sniffs files of any size without truncating mid-object."""
    if not os.path.isfile(path):
        return False
    with open(path) as f:
        first = f.readline()
    try:
        data = json.loads(first)
    except json.JSONDecodeError:
        return False
    return isinstance(data, dict) and "journal" in data and "kind" not in data


def migrate_checkpoint(path: str, fingerprint: SpaceFingerprint,
                       space: SearchSpace, run_id: str = "journal") -> int:
    """Rewrite a legacy checkpoint file in place as store records.

    The legacy format carried no fingerprint; the caller asserts the problem
    identity (as the legacy resume silently did). Returns #migrated."""
    with open(path) as f:
        data = json.load(f)
    if data.get("objective") and fingerprint.objective \
            and data["objective"] != fingerprint.objective:
        raise ValueError(
            f"legacy checkpoint {path} was written for objective "
            f"{data['objective']!r}, not {fingerprint.objective!r}")
    tmp = path + ".migrate.jsonl"      # suffix keeps single-file store mode
    if os.path.exists(tmp):
        os.remove(tmp)
    store = TuningRecordStore(tmp)
    for seq, (idx, key, value, af) in enumerate(data["journal"]):
        store.append(TuningRecord(
            fp=fingerprint.digest, run=run_id, seq=seq, key=key,
            idx=None if idx is None else int(idx),
            value=math.nan if value is None else float(value), af=af,
            config=_config_for(space, idx, key),
            meta={"migrated_from": "engine_checkpoint"}),
            fingerprint=fingerprint)
    store.close()
    os.replace(tmp, path)
    # the rewrite invalidated any sidecar index byte offsets; refresh it so
    # the next lazy open reads the index instead of rebuilding from scratch
    from repro.store import index as sidx
    if os.path.exists(sidx.index_path(path)):
        sidx.write_index(path, sidx.build_index(path))
    return len(data["journal"])


def ingest_golden(path: str, objective, store: TuningRecordStore,
                  context: str = "golden") -> int:
    """Lift seed golden traces into the store schema. ``objective`` must be
    the objective the traces were captured on (it provides the space for
    config resolution and the fingerprint identity)."""
    with open(path) as f:
        golden = json.load(f)
    fp = SpaceFingerprint.of(objective.space, objective=objective.name,
                             context=context)
    n = 0
    for case, payload in sorted(golden.items()):
        for seq, (key, value, af) in enumerate(payload["journal"]):
            idx: Optional[int] = None
            if not key.startswith("cfg:"):
                idx = int(key)
            store.append(TuningRecord(
                fp=fp.digest, run=f"golden:{case}", seq=seq, key=key, idx=idx,
                value=math.nan if value is None else float(value), af=af,
                config=_config_for(objective.space, idx, key),
                meta={"migrated_from": "golden_traces"}), fingerprint=fp)
            n += 1
    return n
