"""Sidecar segment index: O(hot-set) store opens (DESIGN.md §13).

A fleet-scale store holds millions of records across many segments; loading
all of them to answer "best config for one cell" is the scaling wall the
ROADMAP flags. The index is a JSON sidecar (``index.json`` inside a
directory store, ``<file>.index.json`` beside a single-file store) mapping

    digest -> [(segment, byte_offset, length, count, best_value), ...]

— contiguous byte extents of one fingerprint's lines within each segment —
plus per-segment indexed sizes and the fingerprint descriptors themselves.
``TuningRecordStore(path, lazy=True)`` opens by reading only the index,
scans just the bytes appended past each segment's indexed size (zero on a
freshly indexed store), and materializes a fingerprint's records only when
a caller touches that digest.

The index is a *cache*, never the truth: it is rebuilt from the segments on
demand when it is missing, unparsable (torn write), from a different
version, or references a segment that shrank or disappeared (compaction ran
without refreshing it). A segment that merely *grew* does not invalidate the
index — append-only writers extend segments, so the indexed prefix stays
valid and only the tail needs scanning. Writes are atomic
(tmp + ``os.replace``) and best-effort: a read-only store directory simply
keeps the index in memory.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

from repro.store.records import (SpaceFingerprint, _is_single_file,
                                 list_segments)

INDEX_VERSION = 1

#: record kinds that carry no observations: compaction headers and durable
#: control records (the tuning-job queue; ``retune`` is its legacy
#: single-daemon spelling) — cataloged separately or skipped
CONTROL_KINDS = ("compact", "retune", "job")


def index_path(store_path: str) -> str:
    """Where the sidecar lives. Inside a directory store it must not match
    the ``*.jsonl`` segment glob; beside a single-file store it must not
    itself look like a store."""
    if _is_single_file(store_path):
        return store_path + ".index.json"
    return os.path.join(store_path, "index.json")


def iter_complete_lines(seg: str, start: int = 0
                        ) -> Iterator[Tuple[int, int, bytes]]:
    """Yield ``(offset, nbytes, raw)`` for every COMPLETE (newline-terminated)
    line of ``seg`` from byte ``start``; a torn final line is not yielded —
    the same tolerance the loader and the watcher apply."""
    with open(seg, "rb") as f:
        f.seek(start)
        data = f.read()
    offset = start
    lines = data.split(b"\n")
    lines.pop()                        # b"" when data ends in a newline
    for raw in lines:
        yield offset, len(raw) + 1, raw
        offset += len(raw) + 1


@dataclass
class Extent:
    """A contiguous byte run of one digest's lines within one segment
    (descriptor + observation lines; ``count``/``best`` cover observations
    only). Runs of one tuning run's journal coalesce into a single extent;
    pathologically interleaved writers degrade to per-record extents, which
    is still correct, just a bigger sidecar."""

    segment: str                 # segment basename
    offset: int
    length: int
    count: int = 0
    best: Optional[float] = None     # min finite obs value, None if none

    def to_json(self) -> list:
        return [self.segment, self.offset, self.length, self.count, self.best]

    @classmethod
    def from_json(cls, row: list) -> "Extent":
        seg, offset, length, count, best = row
        return cls(segment=seg, offset=int(offset), length=int(length),
                   count=int(count),
                   best=None if best is None else float(best))


@dataclass
class StoreIndex:
    """Parsed sidecar: segment frontier + per-digest extents."""

    segments: Dict[str, int] = field(default_factory=dict)  # name -> bytes
    fps: Dict[str, SpaceFingerprint] = field(default_factory=dict)
    extents: Dict[str, List[Extent]] = field(default_factory=dict)
    controls: Dict[str, List[Extent]] = field(default_factory=dict)
    total: int = 0               # observation count over all extents

    def to_json(self) -> dict:
        return {"kind": "index", "v": INDEX_VERSION,
                "segments": self.segments,
                "fps": {d: fp.to_json() for d, fp in self.fps.items()},
                "extents": {d: [e.to_json() for e in exts]
                            for d, exts in self.extents.items()},
                "controls": {k: [e.to_json() for e in exts]
                             for k, exts in self.controls.items()},
                "total": self.total}

    @classmethod
    def from_json(cls, d: dict) -> "StoreIndex":
        return cls(
            segments={k: int(v) for k, v in d["segments"].items()},
            fps={dg: SpaceFingerprint.from_json(fd)
                 for dg, fd in d["fps"].items()},
            extents={dg: [Extent.from_json(r) for r in rows]
                     for dg, rows in d["extents"].items()},
            controls={k: [Extent.from_json(r) for r in rows]
                      for k, rows in d.get("controls", {}).items()},
            total=int(d["total"]))

    def best_value(self, digest: str) -> Optional[float]:
        vals = [e.best for e in self.extents.get(digest, ()) if
                e.best is not None]
        return min(vals) if vals else None


class _ExtentBuilder:
    """Coalesces consecutive same-key lines of one segment into extents."""

    def __init__(self, segment_name: str):
        self.segment = segment_name
        self.key: Optional[Tuple[str, str]] = None   # ("fp"|"ctl", id)
        self.cur: Optional[Extent] = None
        self.out: List[Tuple[Tuple[str, str], Extent]] = []

    def add(self, key: Tuple[str, str], offset: int, nbytes: int,
            value: Optional[float] = None, is_obs: bool = False) -> None:
        if self.cur is not None and key == self.key \
                and offset == self.cur.offset + self.cur.length:
            self.cur.length += nbytes
        else:
            self.flush()
            self.key = key
            self.cur = Extent(self.segment, offset, nbytes)
        if is_obs:
            self.cur.count += 1
            if value is not None and math.isfinite(value) \
                    and (self.cur.best is None or value < self.cur.best):
                self.cur.best = value

    def flush(self) -> None:
        if self.cur is not None:
            self.out.append((self.key, self.cur))
            self.cur, self.key = None, None


def scan_segment(seg: str, idx: StoreIndex, start: int = 0) -> int:
    """Index one segment's complete lines from ``start``; returns the byte
    frontier reached (the offset past the last complete line)."""
    name = os.path.basename(seg)
    builder = _ExtentBuilder(name)
    frontier = start
    for offset, nbytes, raw in iter_complete_lines(seg, start):
        frontier = offset + nbytes
        text = raw.decode("utf-8").strip()
        if not text:
            if builder.cur is not None:     # blank inside a run: absorb
                builder.cur.length += nbytes
            continue
        try:
            d = json.loads(text)
        except json.JSONDecodeError:
            raise ValueError(
                f"{seg}:@{offset}: corrupt record line — if this is a "
                "legacy engine checkpoint, migrate it with "
                "repro.store.migrate.migrate_checkpoint")
        kind = d.get("kind")
        if kind == "fp":
            fp = SpaceFingerprint.from_json(d)
            idx.fps.setdefault(fp.digest, fp)
            builder.add(("fp", fp.digest), offset, nbytes)
        elif kind == "obs":
            v = d.get("value")
            builder.add(("fp", d["fp"]), offset, nbytes,
                        value=None if v is None else float(v), is_obs=True)
            idx.total += 1
        elif kind == "compact":
            builder.flush()                 # header: no extent
        elif kind in ("retune", "job"):
            builder.add(("ctl", kind), offset, nbytes, is_obs=True)
        else:
            raise ValueError(
                f"{seg}:@{offset}: unknown record kind {kind!r} — if this "
                "is a legacy engine checkpoint, migrate it with "
                "repro.store.migrate.migrate_checkpoint")
    builder.flush()
    for (group, key), extent in builder.out:
        target = idx.extents if group == "fp" else idx.controls
        target.setdefault(key, []).append(extent)
    return frontier


def build_index(store_path: str) -> StoreIndex:
    """Full scan of every segment — the rebuild path."""
    idx = StoreIndex()
    for seg in list_segments(store_path, _is_single_file(store_path)):
        idx.segments[os.path.basename(seg)] = scan_segment(seg, idx, 0)
    return idx


def load_index(store_path: str) -> Optional[StoreIndex]:
    """The sidecar, or None when missing/torn/foreign-version — any of which
    means "rebuild"."""
    path = index_path(store_path)
    try:
        with open(path) as f:
            d = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError, UnicodeDecodeError):
        return None
    if not isinstance(d, dict) or d.get("kind") != "index" \
            or d.get("v") != INDEX_VERSION:
        return None
    try:
        return StoreIndex.from_json(d)
    except (KeyError, TypeError, ValueError):
        return None


def write_index(store_path: str, idx: StoreIndex) -> bool:
    """Atomic best-effort sidecar write (a reader on a read-only filesystem
    keeps its index in memory instead of failing the open)."""
    path = index_path(store_path)
    tmp = path + ".tmp"
    try:
        with open(tmp, "w") as f:
            json.dump(idx.to_json(), f)
        os.replace(tmp, path)
        return True
    except OSError:
        try:
            os.remove(tmp)
        except OSError:
            pass
        return False


def index_is_stale(store_path: str, idx: StoreIndex) -> bool:
    """True when a segment the index references shrank or vanished —
    something rewrote the store (compaction without an index refresh), so
    every recorded offset is suspect. Growth is NOT staleness: appends only
    extend segments, the indexed prefix stays valid."""
    single = _is_single_file(store_path)
    on_disk = {os.path.basename(s): s
               for s in list_segments(store_path, single)}
    for name, nbytes in idx.segments.items():
        seg = on_disk.get(name)
        if seg is None:
            return True
        if os.path.getsize(seg) < nbytes:
            return True
    return False
