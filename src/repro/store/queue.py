"""Durable re-tune queue: the serve→tune control plane IN the store
(DESIGN.md §13).

PR 4's ``repro.core.engine.RetuneQueue`` lives in one process's memory — a
drift request dies with the server that noticed it, and a re-tune daemon on
another host can never see it. This module moves the queue into the record
store itself as append-only ``kind="retune"`` control records, so the queue
inherits every durability property observations already have (per-record
flush, torn-line tolerance, segment rollover, compaction survival):

    {"kind": "retune", "state": "submit", "id", "key", "objective",
     "observed", "predicted", "reason", "t", "by"}
    {"kind": "retune", "state": "claim",  "id", "key", "by", "t"}
    {"kind": "retune", "state": "done",   "id", "key", "by", "t"}

A request's lifecycle is the fold of its records: *open* until a ``done``
lands; *claimable* while no unexpired claim exists (a claimant that died
re-arms after ``claim_ttl``). Dedupe is per cell ``key``: one open request
per cell however many servers observe the same drift — the ``submit`` check
is check-then-append, so servers racing within one flush latency can slip
duplicates through, and ``done`` therefore coalesces: servicing a cell
closes every open request for it (one re-tune satisfies them all; drift
after the swap re-arms fresh). Claim arbitration is
first-timestamp-wins — ``claim()`` appends its claim, re-reads, and only
returns the ticket if its own claim is the earliest unexpired one; with a
single daemon per store this is exactly-once, with racing daemons it is
best-effort dedupe (the race window is the flush latency of one line).

Crash matrix:
  * submitter dies after ``submit`` — the request is on disk; any daemon
    claims and services it;
  * claimant dies before ``done`` — the claim expires after ``claim_ttl``
    and the request becomes claimable again;
  * claimant dies after ``done`` — the cell re-arms; the *work* (the
    re-tune run's observations) was journaled by the engine as it ran;
  * torn final line of any control record — invisible (incomplete lines
    are never consumed), state unchanged;
  * compaction — open requests are copied verbatim; completed
    submit/claim/done groups older than the retention window are folded
    away (``repro.store.compact``).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.store.index import index_is_stale, load_index
from repro.store.records import TuningRecordStore, _is_single_file
from repro.store.watch import StoreWatcher


@dataclass
class RetuneTicket:
    """Folded state of one request id."""

    id: str
    key: str
    objective: str = ""
    observed: float = float("nan")
    predicted: float = float("nan")
    reason: str = "drift"
    t: float = 0.0
    submitted_by: str = ""
    claims: List[Tuple[float, str]] = field(default_factory=list)
    done: bool = False


class DurableRetuneQueue:
    """Store-backed drift-request intake; drop-in for the in-process
    ``RetuneQueue``'s ``submit`` side of the online serve loop, plus
    ``claim``/``done`` for daemons. All state is the store — a fresh
    instance on the same path sees everything prior processes did."""

    def __init__(self, path: str, *, worker: Optional[str] = None,
                 claim_ttl: float = 3600.0, clock=time.time, appender=None,
                 use_index: bool = True):
        """``appender`` shares an already-open ``TuningRecordStore`` for the
        control-record writes. Pass the process's existing appender (the
        serve loop passes its ``ProdRecorder``'s) — compaction judges
        "sealed" per pid, so a process must keep ONE live append segment,
        not one per component.

        Cold start is index-seeded when the sidecar index is present and
        fresh (``use_index=True``): only the ``kind="retune"`` extents are
        read — O(control lines), not O(store) — and the watcher starts each
        indexed segment at its indexed frontier, so a daemon opening a
        million-record store folds a handful of lines instead of parsing
        every observation ever journaled. A missing/stale index falls back
        to the full replay."""
        self.path = path
        self.worker = worker or f"proc-{os.getpid()}"
        self.claim_ttl = float(claim_ttl)
        self.clock = clock
        self._owns_store = appender is None
        self._store = (appender if appender is not None
                       else TuningRecordStore(path, load=False))
        self._tickets: Dict[str, RetuneTicket] = {}
        self.seeded_from_index = False
        start_offsets = None
        if use_index:
            idx = load_index(path)
            if idx is not None and not index_is_stale(path, idx):
                single = _is_single_file(path)
                for ext in idx.controls.get("retune", ()):
                    seg = (path if single
                           else os.path.join(path, ext.segment))
                    self._fold_extent(seg, ext.offset, ext.length)
                start_offsets = dict(idx.segments)
                self.seeded_from_index = True
        self._watcher = StoreWatcher(path, from_start=True,
                                     collect_controls=True,
                                     start_offsets=start_offsets)
        # fold the store's current control state NOW: the post-index tail
        # (or, unseeded, every segment) is replayed at construction, keeping
        # it off the serve loop's decode latency path (submit happens
        # between decode steps).
        self._refresh()

    def _fold_extent(self, seg: str, offset: int, length: int) -> None:
        """Fold the retune lines of one indexed extent. Extents span whole
        lines by construction (and may include absorbed blank lines);
        folding is idempotent, so re-seeing a line — e.g. a compacted copy —
        is harmless."""
        try:
            with open(seg, "rb") as f:
                f.seek(offset)
                data = f.read(length)
        except OSError:
            return
        for line in data.split(b"\n"):
            text = line.decode("utf-8", errors="replace").strip()
            if not text:
                continue
            try:
                d = json.loads(text)
            except json.JSONDecodeError:
                continue
            if d.get("kind") == "retune":
                self._fold(d)

    # -- folding ------------------------------------------------------------
    def _fold(self, d: dict) -> None:
        state, rid = d.get("state"), str(d.get("id", ""))
        if not rid:
            return
        if state == "submit":
            if rid not in self._tickets:
                self._tickets[rid] = RetuneTicket(
                    id=rid, key=str(d.get("key", "")),
                    objective=str(d.get("objective", "")),
                    observed=float(d.get("observed", float("nan"))),
                    predicted=float(d.get("predicted", float("nan"))),
                    reason=str(d.get("reason", "drift")),
                    t=float(d.get("t", 0.0)),
                    submitted_by=str(d.get("by", "")))
        elif state == "claim":
            tk = self._tickets.get(rid)
            if tk is not None:
                entry = (float(d.get("t", 0.0)), str(d.get("by", "")))
                if entry not in tk.claims:
                    tk.claims.append(entry)
        elif state == "done":
            tk = self._tickets.get(rid)
            if tk is not None:
                tk.done = True

    def _refresh(self) -> None:
        self._watcher.poll()            # observations are not our business
        for d in self._watcher.drain_controls():
            self._fold(d)

    def _active_claim(self, tk: RetuneTicket,
                      now: float) -> Optional[Tuple[float, str]]:
        live = [c for c in tk.claims if now - c[0] <= self.claim_ttl]
        return min(live) if live else None

    # -- producer side (serve loop) -----------------------------------------
    def submit(self, req) -> bool:
        """Enqueue unless the cell already has an open request. ``req`` is
        anything with the ``RetuneRequest`` fields (key/objective/observed/
        predicted/reason/t). Durable once this returns True."""
        self._refresh()
        key = str(req.key)
        if any(tk.key == key and not tk.done
               for tk in self._tickets.values()):
            return False
        t = float(getattr(req, "t", 0.0) or self.clock())
        # full-precision timestamp in the id: %g truncates to 6 significant
        # digits, which at wall-clock magnitudes collides within hours and
        # would fold a fresh submit into an old done ticket
        d = {"kind": "retune", "state": "submit",
             "id": f"{key}@{t!r}/{self.worker}", "key": key,
             "objective": str(getattr(req, "objective", "")),
             "observed": float(getattr(req, "observed", float("nan"))),
             "predicted": float(getattr(req, "predicted", float("nan"))),
             "reason": str(getattr(req, "reason", "drift")),
             "t": t, "by": self.worker}
        self._store.append_control(d)
        self._fold(d)
        return True

    # -- consumer side (retune daemon) --------------------------------------
    def claim(self) -> Optional[RetuneTicket]:
        """Claim the oldest claimable request: append the claim, re-read,
        and win only if our claim is the earliest unexpired one."""
        self._refresh()
        now = self.clock()
        open_unclaimed = [tk for tk in self._tickets.values()
                          if not tk.done
                          and self._active_claim(tk, now) is None]
        if not open_unclaimed:
            return None
        tk = min(open_unclaimed, key=lambda tk: (tk.t, tk.id))
        mine = (float(now), self.worker)
        d = {"kind": "retune", "state": "claim", "id": tk.id, "key": tk.key,
             "by": self.worker, "t": mine[0]}
        self._store.append_control(d)
        self._fold(d)
        self._refresh()                 # absorb racing claims
        winner = self._active_claim(tk, self.clock())
        return tk if winner == mine else None

    def done(self, ticket) -> None:
        """Mark a claimed request serviced; the cell re-arms for new
        submissions. ``ticket`` is a RetuneTicket or an id string.

        Coalesces: every OTHER open request for the same cell is closed
        too — ``submit``'s dedupe is check-then-append, so servers racing
        within one flush latency can durably enqueue duplicates for one
        drift event, and the re-tune that just ran satisfies all of them
        (post-swap drift re-arms fresh)."""
        rid = ticket if isinstance(ticket, str) else ticket.id
        self._refresh()
        tk = self._tickets.get(rid)
        key = tk.key if tk is not None else ""
        now = float(self.clock())
        close = [rid] + [other.id for other in self._tickets.values()
                         if key and other.key == key and not other.done
                         and other.id != rid]
        for cid in close:
            d = {"kind": "retune", "state": "done", "id": cid, "key": key,
                 "by": self.worker, "t": now}
            self._store.append_control(d)
            self._fold(d)

    # -- introspection ------------------------------------------------------
    def open_tickets(self) -> List[RetuneTicket]:
        self._refresh()
        return sorted((tk for tk in self._tickets.values() if not tk.done),
                      key=lambda tk: (tk.t, tk.id))

    def __len__(self) -> int:
        return len(self.open_tickets())

    def close(self) -> None:
        if self._owns_store:               # never close a shared appender
            self._store.close()
