"""Durable tuning-job queue: the fleet control plane IN the store
(DESIGN.md §13).

PR 4's ``repro.core.engine.RetuneQueue`` lives in one process's memory — a
drift request dies with the server that noticed it, and a daemon on another
host can never see it. This module keeps the queue in the record store
itself as append-only ``kind="job"`` control records, so it inherits every
durability property observations already have (per-record flush, torn-line
tolerance, segment rollover, compaction survival) — and, unlike the PR 5
``kind="retune"`` queue it generalizes, it is **exactly-once under N racing
daemons** via fencing tokens (``repro.store.fence``):

    {"kind": "job", "state": "submit", "id", "key", "job_type", "objective",
     "observed", "predicted", "reason", "t", "by"[, "budget"]}
    {"kind": "job", "state": "claim",      "id", "key", "by", "t", "token"}
    {"kind": "job", "state": "release",    "id", "key", "by", "t", "token"}
    {"kind": "job", "state": "done",       "id", "key", "by", "t", "token"}
    {"kind": "job", "state": "quarantine", "id", "key", "by", "t", "token"}

``job_type`` ∈ {"retune", "cold_tune", "scheduled_retune", "bench_sweep"}
(anything a fleet worker knows how to service); legacy ``kind="retune"``
records fold in as ``job_type="retune"`` with token-0 claims, so every
pre-existing store keeps working.

Protocol (the fold of a key's records is the truth):

  * **Groups.** All open submits for one ``key`` form one job group; the
    canonical ticket is the earliest ``(t, id)``. ``submit`` is
    commit-then-check: append, re-read, and report accepted only if your
    submit became the canonical one — racing duplicates coalesce into ONE
    open job instead of slipping through the old check-then-append window.
  * **Claims are fenced leases.** ``claim()`` snapshots the tokens it has
    seen, atomically obtains the next fencing token for the key
    (``FenceRegistry.issue`` — one winner per token value, monotone per
    key), appends the claim, re-reads, and keeps the lease only if no
    higher token appeared and no *unseen live* lower-token claim landed in
    the race window (in which case it appends a ``release`` and backs
    off). Exactly one claimant survives any interleaving — see the crash
    matrix below.
  * **Expiry is judged on the reader's clock.** Each claim is stamped
    ``seen`` with the reader's own clock when it first folds; a lease is
    expired when ``reader_now - seen > claim_ttl``. Append order is the
    only cross-host truth — writer wall-clock stamps never enter the
    arbitration, so cross-machine clock skew cannot shorten (steal a live
    lease) or extend (wedge the queue on) a TTL. The claimant itself folds
    its own claim earliest, so its own view expires first: it always
    observes itself fenced before any peer could have taken over.
  * **Writes are fenced.** ``done`` carries the claim's token; the fold
    rejects a ``done`` whose token is below the group's highest UNRELEASED
    claim token (a racer that backed off released its token — it must not
    fence the winner it deferred to), and ``done()`` itself raises
    ``FencedClaimError`` when the caller has been superseded — a daemon
    that paused past its TTL and woke mid-service cannot close a job
    another daemon re-claimed. The
    retune engine run stamps the same token into every journaled
    observation (``meta["fence"]``), which ``HotConfigSource`` checks.
  * **Poison jobs are quarantined, not re-armed forever.** With
    ``quarantine_after=K > 0``, a claimant that finds K or more *expired
    unreleased* leases on a group (K consecutive claimants took the job
    and died or stalled past ``claim_ttl`` — voluntary releases never
    count) does not claim it again: it obtains a fresh fencing token and
    appends a ``quarantine`` record per open submit id (coalescing like
    ``done``). The fold treats ``quarantine`` as a token-fenced terminal
    state — the group closes, ``open_tickets`` stops offering it, and the
    ``quarantined`` counter ticks. A NEW submit for the key re-arms it
    fresh (fresh ids, higher fence floor). ``quarantine_after=0``
    (default) disables the check: folds are byte-identical to PR 9.

Crash matrix:
  * submitter dies after ``submit`` — the job is on disk; any daemon
    claims and services it;
  * claimant dies before ``done`` — the lease expires after ``claim_ttl``
    (on each reader's own clock) and the job re-arms; the next claim takes
    a higher token, permanently fencing the dead claimant out;
  * claimant pauses and wakes after losing the lease — its ``done`` and
    its journaled observations are rejected by token comparison; the only
    residual window is a pause between ``done()``'s own fence check and
    its append landing, which closes a job the new claimant is (re)doing —
    the *work* of both is journaled and the later records win resolution;
  * claimant dies between token issue and claim append — the token is
    burned, never claimed; the next claimant's ``issue`` simply grants a
    higher one;
  * torn final line of any control record — invisible (incomplete lines
    are never consumed), state unchanged;
  * compaction — open jobs are copied verbatim; completed groups older
    than the retention window are folded away, with fenced (rejected)
    ``done`` records never counting as completion (``repro.store.compact``,
    which also enforces the single-compactor lock on the same tokens).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.store.fence import FencedClaimError, FenceRegistry
from repro.store.index import index_is_stale, load_index
from repro.store.records import TuningRecordStore, _is_single_file, natural_key
from repro.store.watch import StoreWatcher

JOB_TYPES = ("retune", "cold_tune", "scheduled_retune", "bench_sweep")


@dataclass
class _Claim:
    """One folded claim record. ``seen`` is the READER's clock at first
    fold — the only timestamp lease expiry ever consults; ``t`` (the
    writer's stamp) is carried for logs only."""

    token: int
    t: float
    by: str
    seen: float
    released: bool = False


@dataclass
class JobTicket:
    """Folded state of one submit id (``RetuneTicket`` in PR 5)."""

    id: str
    key: str
    job_type: str = "retune"
    objective: str = ""
    observed: float = float("nan")
    predicted: float = float("nan")
    reason: str = "drift"
    t: float = 0.0
    submitted_by: str = ""
    budget: Optional[int] = None
    claims: List[_Claim] = field(default_factory=list)
    done: bool = False
    #: terminal without service: K consecutive claimants died on this job
    quarantined: bool = False
    #: the fencing token of the lease ``claim()`` granted the caller; 0 on
    #: tickets obtained any other way (``open_tickets``)
    token: int = 0
    #: other open submit ids coalesced into this canonical ticket
    dup_ids: List[str] = field(default_factory=list)

    @property
    def terminal(self) -> bool:
        """Closed for good: serviced (``done``) or poisoned
        (``quarantined``). Terminal tickets never re-arm."""
        return self.done or self.quarantined


#: legacy alias — PR 5 callers/tests constructed these by name
RetuneTicket = JobTicket


class TuningJobQueue:
    """Store-backed job intake: drop-in for the in-process ``RetuneQueue``'s
    ``submit`` side of the online serve loop, plus fenced ``claim``/``done``
    for a fleet of daemons. All state is the store — a fresh instance on
    the same path sees everything prior processes did."""

    def __init__(self, path: str, *, worker: Optional[str] = None,
                 claim_ttl: float = 3600.0, clock=time.time, appender=None,
                 use_index: bool = True, quarantine_after: int = 0):
        """``appender`` shares an already-open ``TuningRecordStore`` for the
        control-record writes. Pass the process's existing appender (the
        serve loop passes its ``ProdRecorder``'s) — compaction judges
        "sealed" per pid, so a process must keep ONE live append segment,
        not one per component.

        Cold start is index-seeded when the sidecar index is present and
        fresh (``use_index=True``): only the ``kind="job"``/``kind="retune"``
        extents are read — O(control lines), not O(store) — and the watcher
        starts each indexed segment at its indexed frontier, so a daemon
        opening a million-record store folds a handful of lines instead of
        parsing every observation ever journaled. A missing/stale index
        falls back to the full replay."""
        self.path = path
        self.worker = worker or f"proc-{os.getpid()}"
        self.claim_ttl = float(claim_ttl)
        #: quarantine a job once this many consecutive claimants took its
        #: lease and expired without releasing or finishing (0 = never)
        self.quarantine_after = int(quarantine_after)
        self.clock = clock
        self._owns_store = appender is None
        self._store = (appender if appender is not None
                       else TuningRecordStore(path, load=False))
        self._fence = FenceRegistry(path, clock=clock)
        self._tickets: Dict[str, JobTicket] = {}
        #: highest claim token ever folded per key — the issuance floor
        #: (survives group completion; markers alone can be GC'd)
        self._token_floor: Dict[str, int] = {}
        #: fenced ``done`` records the fold refused (superseded claimants)
        self.rejected_writes = 0
        #: submit ids this instance folded into the quarantined state
        self.quarantined = 0
        self.seeded_from_index = False
        start_offsets = None
        if use_index:
            idx = load_index(path)
            if idx is not None and not index_is_stale(path, idx):
                single = _is_single_file(path)
                exts = [e for k in ("retune", "job")
                        for e in idx.controls.get(k, ())]
                # fold in store order: within one segment done-fencing is
                # order-sensitive, and retune/job extents may interleave
                exts.sort(key=lambda e: (natural_key(e.segment), e.offset))
                for ext in exts:
                    seg = (path if single
                           else os.path.join(path, ext.segment))
                    self._fold_extent(seg, ext.offset, ext.length)
                start_offsets = dict(idx.segments)
                self.seeded_from_index = True
        self._watcher = StoreWatcher(path, from_start=True,
                                     collect_controls=True,
                                     start_offsets=start_offsets)
        # fold the store's current control state NOW: the post-index tail
        # (or, unseeded, every segment) is replayed at construction, keeping
        # it off the serve loop's decode latency path (submit happens
        # between decode steps).
        self._refresh()

    def _fold_extent(self, seg: str, offset: int, length: int) -> None:
        """Fold the control lines of one indexed extent. Extents span whole
        lines by construction (and may include absorbed blank lines);
        folding is idempotent, so re-seeing a line — e.g. a compacted copy —
        is harmless."""
        try:
            with open(seg, "rb") as f:
                f.seek(offset)
                data = f.read(length)
        except OSError:
            return
        for line in data.split(b"\n"):
            text = line.decode("utf-8", errors="replace").strip()
            if not text:
                continue
            try:
                d = json.loads(text)
            except json.JSONDecodeError:
                continue
            if d.get("kind") in ("retune", "job"):
                self._fold(d)

    # -- folding ------------------------------------------------------------
    def _fold(self, d: dict) -> None:
        state, rid = d.get("state"), str(d.get("id", ""))
        if not rid:
            return
        if state == "submit":
            if rid not in self._tickets:
                budget = d.get("budget")
                self._tickets[rid] = JobTicket(
                    id=rid, key=str(d.get("key", "")),
                    job_type=str(d.get("job_type", "retune")),
                    objective=str(d.get("objective", "")),
                    observed=float(d.get("observed", float("nan"))),
                    predicted=float(d.get("predicted", float("nan"))),
                    reason=str(d.get("reason", "drift")),
                    t=float(d.get("t", 0.0)),
                    submitted_by=str(d.get("by", "")),
                    budget=None if budget is None else int(budget))
        elif state in ("claim", "release"):
            key = str(d.get("key", ""))
            token = int(d.get("token") or 0)
            if token > self._token_floor.get(key, 0):
                self._token_floor[key] = token
            tk = self._claim_target(rid, key)
            if tk is None:
                return
            entry = self._find_claim(tk, token, d)
            if state == "claim":
                if entry is None:
                    tk.claims.append(_Claim(
                        token=token, t=float(d.get("t", 0.0)),
                        by=str(d.get("by", "")),
                        seen=float(self.clock())))
            elif entry is not None:
                entry.released = True
        elif state in ("done", "quarantine"):
            token_floor = int(d.get("token") or 0)
            key = str(d.get("key", ""))
            if key and token_floor > self._token_floor.get(key, 0):
                self._token_floor[key] = token_floor
            tk = self._tickets.get(rid)
            if tk is None or tk.terminal:
                return
            token = d.get("token")
            if token is not None:
                # fence: a done/quarantine below the group's highest
                # UNRELEASED claim token is a superseded claimant's late
                # write — refuse to close the job. Released claims are
                # aborted racers that explicitly backed off; they must not
                # fence the winner.
                if int(token) < self._group_top(tk.key):
                    self.rejected_writes += 1
                    return
            if state == "quarantine":
                tk.quarantined = True
                self.quarantined += 1
            else:
                tk.done = True

    def _claim_target(self, rid: str, key: str) -> Optional[JobTicket]:
        """The open ticket a claim/release attaches to: its own id if still
        open, else dangling (a claim folding after its group closed belongs
        to no lease — the group it raced is already done)."""
        tk = self._tickets.get(rid)
        return tk if tk is not None and not tk.terminal else None

    @staticmethod
    def _find_claim(tk: JobTicket, token: int, d: dict) -> Optional[_Claim]:
        for c in tk.claims:
            if token > 0 and c.token == token:
                return c
            if token == 0 and c.token == 0 \
                    and (c.t, c.by) == (float(d.get("t", 0.0)),
                                        str(d.get("by", ""))):
                return c
        return None

    def _refresh(self) -> None:
        self._watcher.poll()            # observations are not our business
        for d in self._watcher.drain_controls():
            if d.get("kind") in ("retune", "job"):
                self._fold(d)

    # -- group / lease arbitration ------------------------------------------
    def _group(self, key: str) -> List[JobTicket]:
        """All open tickets of one key, canonical first."""
        return sorted((tk for tk in self._tickets.values()
                       if tk.key == key and not tk.terminal),
                      key=lambda tk: (tk.t, tk.id))

    def _canonical(self, key: str) -> Optional[JobTicket]:
        grp = self._group(key)
        return grp[0] if grp else None

    def _expired(self, c: _Claim, now: float) -> bool:
        return c.released or now - c.seen > self.claim_ttl

    def _group_top(self, key: str) -> int:
        """Highest UNRELEASED claim token of a key's group — the token a
        ``done`` must carry to be accepted. Released claims are aborted
        racers (they backed off in ``_try_claim``'s post-append check);
        they are transparent to arbitration, else a loser would fence out
        the very winner it deferred to."""
        return max((c.token for tk in self._group(key) for c in tk.claims
                    if not c.released), default=0)

    def _lease(self, key: str, now: float) -> Optional[_Claim]:
        """The claim currently holding ``key``, or None if claimable. The
        highest unreleased token rules; it being expired does NOT fall
        back to a lower one (lower tokens are fenced out forever), and
        released claims are transparent (aborted racers). Token-0 claims
        are the legacy queue's: earliest unexpired wins among them, and
        any tokened claim supersedes them all."""
        claims = [c for tk in self._group(key) for c in tk.claims
                  if not c.released]
        if not claims:
            return None
        top = max(c.token for c in claims)
        if top > 0:
            cand = next(c for c in claims if c.token == top)
            return None if self._expired(cand, now) else cand
        live = [c for c in claims if not self._expired(c, now)]
        return min(live, key=lambda c: (c.t, c.by)) if live else None

    # -- producer side (serve loop) -----------------------------------------
    def submit(self, req, *, job_type: str = "retune",
               budget: Optional[int] = None) -> bool:
        """Enqueue unless the key already has an open job. ``req`` is
        anything with the ``RetuneRequest`` fields (key/objective/observed/
        predicted/reason/t). Commit-then-check: the append happens first and
        acceptance is judged on the read-back, so two submitters racing
        within one flush latency yield ONE accepted (canonical) job — the
        loser's record folds in as a coalesced duplicate of the winner's.
        Durable once this returns True."""
        self._refresh()
        key = str(req.key)
        if self._canonical(key) is not None:
            return False
        t = float(getattr(req, "t", 0.0) or self.clock())
        # full-precision timestamp in the id: %g truncates to 6 significant
        # digits, which at wall-clock magnitudes collides within hours and
        # would fold a fresh submit into an old done ticket
        d = {"kind": "job", "state": "submit",
             "id": f"{key}@{t!r}/{self.worker}", "key": key,
             "job_type": str(job_type),
             "objective": str(getattr(req, "objective", "")),
             "observed": float(getattr(req, "observed", float("nan"))),
             "predicted": float(getattr(req, "predicted", float("nan"))),
             "reason": str(getattr(req, "reason", "drift")),
             "t": t, "by": self.worker}
        if budget is not None:
            d["budget"] = int(budget)
        self._store.append_control(d)
        self._fold(d)
        self._refresh()                 # absorb racing submits
        canon = self._canonical(key)
        return canon is not None and canon.id == d["id"]

    # -- consumer side (daemons) --------------------------------------------
    def claim(self) -> Optional[JobTicket]:
        """Claim the oldest claimable job under a fenced lease. Returns the
        canonical ticket with ``ticket.token`` set, or None when nothing is
        claimable (or every race this round was lost)."""
        self._refresh()
        now = self.clock()
        seen_keys: set = set()
        order: List[JobTicket] = []
        for tk in sorted((t for t in self._tickets.values()
                          if not t.terminal),
                         key=lambda t: (t.t, t.id)):
            if tk.key not in seen_keys:
                seen_keys.add(tk.key)
                order.append(tk)
        for canon in order:
            got = self._try_claim(canon, now)
            if got is not None:
                return got
        return None

    def _burned_claims(self, key: str, now: float) -> int:
        """Consecutive claimants this group has eaten: unreleased tokened
        claims whose leases expired without a ``done``. Voluntary releases
        (aborted racers, graceful shutdowns) never count — only leases
        that silently died."""
        return sum(1 for tk in self._group(key) for c in tk.claims
                   if c.token > 0 and not c.released
                   and now - c.seen > self.claim_ttl)

    def _try_claim(self, canon: JobTicket, now: float) -> Optional[JobTicket]:
        key = canon.key
        if self._lease(key, now) is not None:
            return None
        if self.quarantine_after > 0 \
                and self._burned_claims(key, now) >= self.quarantine_after:
            self._quarantine(canon, now)
            return None
        # tokens visible BEFORE our claim: the post-append check may only
        # back off for a lower-token claim that was NOT in this snapshot
        # (an unseen racer) — backing off for an already-expired one would
        # deadlock the key
        pre = {c.token for tk in self._group(key) for c in tk.claims}
        floor = max(self._token_floor.get(key, 0), max(pre, default=0))
        token = self._fence.issue(key, floor=floor, by=self.worker)
        if token is None:
            return None                 # lost the marker race this instant
        d = {"kind": "job", "state": "claim", "id": canon.id, "key": key,
             "by": self.worker, "t": float(now), "token": token}
        self._store.append_control(d)
        self._fold(d)
        self._refresh()                 # absorb racing claims
        claims = [c for tk in self._group(key) for c in tk.claims]
        top = max((c.token for c in claims), default=token)
        check_now = self.clock()
        stolen = any(c.token < token and c.token not in pre
                     and not self._expired(c, check_now) for c in claims)
        if top > token or self._fence.highest(key) > token or stolen:
            # superseded (a higher token exists) or we fenced out a live
            # claim we never saw: release so arbitration need not wait out
            # our TTL, and back off. In every interleaving at most one
            # contender passes this check (see module docstring).
            self._release(canon.id, key, token)
            return None
        tk = self._tickets.get(canon.id)
        if tk is None or tk.done:
            self._release(canon.id, key, token)
            return None
        tk.token = token
        tk.dup_ids = [g.id for g in self._group(key) if g.id != tk.id]
        return tk

    def _quarantine(self, canon: JobTicket, now: float) -> None:
        """Close a poison group terminally: take a FRESH fencing token
        (permanently fencing every dead claimant out, exactly as a new
        claim would) and append a ``quarantine`` record per open submit id,
        coalescing like ``done``. Losing the token race is fine — the
        winner either services the job or reaches this same verdict."""
        key = canon.key
        pre = {c.token for tk in self._group(key) for c in tk.claims}
        floor = max(self._token_floor.get(key, 0), max(pre, default=0))
        token = self._fence.issue(key, floor=floor, by=self.worker)
        if token is None:
            return
        for cid in [g.id for g in self._group(key)]:
            d = {"kind": "job", "state": "quarantine", "id": cid,
                 "key": key, "by": self.worker, "t": float(now),
                 "token": token}
            self._store.append_control(d)
            self._fold(d)

    def _release(self, rid: str, key: str, token: int) -> None:
        self._fence.release(key, token)
        d = {"kind": "job", "state": "release", "id": rid, "key": key,
             "by": self.worker, "t": float(self.clock()), "token": token}
        self._store.append_control(d)
        self._fold(d)

    def release(self, ticket) -> None:
        """Voluntarily give a claimed job back (service failed, shutting
        down): the lease drops immediately instead of waiting out the TTL."""
        if ticket is None or not getattr(ticket, "token", 0):
            return
        self._release(ticket.id, ticket.key, int(ticket.token))

    def done(self, ticket) -> None:
        """Mark a claimed job serviced; the key re-arms for new submissions.
        ``ticket`` is a JobTicket or an id string.

        Fenced: if the caller's lease token has been superseded (the daemon
        paused past ``claim_ttl`` and another claimed the job), raises
        ``FencedClaimError`` — and even a done append that slips through is
        rejected by every fold (queue instances, compaction GC).

        Coalesces: every open duplicate submit of the same key is closed
        too — one service satisfies them all (drift after the swap re-arms
        fresh)."""
        rid = ticket if isinstance(ticket, str) else ticket.id
        token = 0 if isinstance(ticket, str) else int(
            getattr(ticket, "token", 0) or 0)
        self._refresh()
        tk = self._tickets.get(rid)
        if tk is None or tk.terminal:
            # idempotent no-op: the group this ticket belonged to is already
            # closed (or GC'd by compaction). Critically, do NOT fall through
            # to the coalescing append — the key may have re-armed with a NEW
            # generation of submits this stale ticket must not close.
            return
        key = tk.key
        group = self._group(key)
        top = self._group_top(key)
        now = float(self.clock())
        if token:
            if top > token:
                raise FencedClaimError(
                    f"done({rid!r}) under token {token} but the lease moved "
                    f"to token {top}: this claimant was fenced out "
                    f"(claim_ttl={self.claim_ttl:g}s elapsed on a reader's "
                    "clock and the job was re-claimed)")
        elif top > 0:
            holder = next((c for g in group for c in g.claims
                           if c.token == top), None)
            if holder is not None and holder.by != self.worker \
                    and not self._expired(holder, now):
                raise FencedClaimError(
                    f"done({rid!r}) without a token while {holder.by!r} "
                    f"holds the live lease (token {top})")
            token = top if holder is not None \
                and holder.by == self.worker else 0
        close = [rid] + [g.id for g in group if g.id != rid]
        for cid in close:
            d = {"kind": "job", "state": "done", "id": cid, "key": key,
                 "by": self.worker, "t": now}
            if token:
                d["token"] = token
            self._store.append_control(d)
            self._fold(d)

    # -- introspection ------------------------------------------------------
    def open_tickets(self) -> List[JobTicket]:
        """Canonical open ticket per key (duplicates coalesced into
        ``dup_ids``), oldest first."""
        self._refresh()
        out: List[JobTicket] = []
        for key in {tk.key for tk in self._tickets.values()
                    if not tk.terminal}:
            grp = self._group(key)
            if grp:
                grp[0].dup_ids = [g.id for g in grp[1:]]
                out.append(grp[0])
        return sorted(out, key=lambda tk: (tk.t, tk.id))

    def __len__(self) -> int:
        return len(self.open_tickets())

    def close(self) -> None:
        if self._owns_store:               # never close a shared appender
            self._store.close()


#: legacy alias — PR 5's single-daemon queue, now fleet-safe
DurableRetuneQueue = TuningJobQueue
