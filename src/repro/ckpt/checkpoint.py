"""Sharded, atomic, elastic checkpointing.

Layout (one directory per step):
    ckpt_dir/step_000123.tmp/     — written first
        manifest.json             — tree structure, shapes, dtypes, extras
        arr_00000.npy ...         — one file per leaf (per-shard at scale)
    ckpt_dir/step_000123/         — atomic os.replace when complete

Guarantees:
  * atomicity — a crash mid-write never corrupts the latest checkpoint
    (`latest()` only sees fully renamed directories);
  * determinism — leaves are indexed in jax tree order;
  * elasticity — arrays are saved as GLOBAL arrays; on restore the caller
    passes target shardings and each process reads its slice
    (`restore_sharded`), so the mesh may differ between save and restore
    (node failure → restart at smaller/larger scale);
  * async — `AsyncCheckpointer` snapshots to host then writes in a thread,
    overlapping I/O with the next training steps.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bf16/fp8 natively — store a uint view + dtype tag
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _to_savable(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][1]), name
    return arr, name


def _from_savable(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][0])
    return arr


def _flatten(tree) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, extras: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        sav, name = _to_savable(arr)
        dtypes.append(name)
        np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), sav)
    meta = {"step": step, "n_leaves": len(leaves), "dtypes": dtypes,
            "extras": extras or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [d for d in os.listdir(ckpt_dir)
             if re.fullmatch(r"step_\d+", d)
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    if not steps:
        return None
    return os.path.join(ckpt_dir, max(steps))


def load_manifest(path: str) -> Dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def restore(path: str, like_tree) -> Tuple[Any, Dict]:
    """Restore into the structure of `like_tree` (host numpy arrays)."""
    meta = load_manifest(path)
    leaves, treedef = _flatten(like_tree)
    assert meta["n_leaves"] == len(leaves), (
        f"checkpoint has {meta['n_leaves']} leaves, target {len(leaves)}")
    out = [_from_savable(np.load(os.path.join(path, f"arr_{i:05d}.npy")), name)
           for i, name in enumerate(meta["dtypes"])]
    return jax.tree_util.tree_unflatten(treedef, out), meta["extras"]


def restore_sharded(path: str, like_tree, shardings) -> Tuple[Any, Dict]:
    """Elastic restore: place each global array with the TARGET sharding
    (which may differ from the sharding at save time)."""
    host_tree, extras = restore(path, like_tree)
    sh_leaves = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "addressable_devices"))
    leaves, treedef = _flatten(host_tree)
    placed = [jax.device_put(a, s) for a, s in zip(leaves, sh_leaves)]
    return jax.tree_util.tree_unflatten(treedef, placed), extras


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree, extras: Optional[Dict] = None):
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(self.ckpt_dir, step, host, extras)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(d for d in os.listdir(self.ckpt_dir)
                       if re.fullmatch(r"step_\d+", d))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, d), ignore_errors=True)
