"""Fault-tolerant training loop.

Production posture (what would run on each pod controller at 1000 nodes):
  * checkpoint/restart — async sharded checkpoints every N steps carrying
    params, optimizer state, data cursor and RNG; `TrainLoop.create` restores
    from the latest manifest automatically (crash → rerun the same command);
  * straggler mitigation — per-step wall time tracked against an EWMA; steps
    slower than `straggler_factor ×` EWMA are logged as straggler events and
    surface in metrics (on a real cluster this feeds the scheduler's
    replace/requeue decision — here it drives tests and the demo);
  * elastic rescale — checkpoints store GLOBAL arrays; restoring onto a
    different mesh re-shards (repro.ckpt.restore_sharded), so the same job
    continues after losing/gaining pods;
  * failure injection — `fail_at_step` raises mid-run to exercise all of the
    above in tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs.arch import ArchConfig
from repro.data.pipeline import DataConfig, DataIterator, make_source
from repro.models.params import init_params, model_specs
from repro.models.stepfn import make_train_step
from repro.parallel.sharding import ParallelConfig, ShardCtx, param_shardings
from repro.optim.optimizers import AdamW, warmup_cosine


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class LoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    fail_at_step: Optional[int] = None     # failure injection (tests/demo)
    peak_lr: float = 3e-3
    warmup: int = 100


@dataclass
class LoopMetrics:
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    straggler_events: List[int] = field(default_factory=list)
    restored_from: Optional[str] = None
    start_step: int = 0


class TrainLoop:
    def __init__(self, arch: ArchConfig, data_cfg: DataConfig,
                 loop_cfg: LoopConfig, pcfg: Optional[ParallelConfig] = None,
                 mesh=None):
        self.arch = arch
        self.data_cfg = data_cfg
        self.loop_cfg = loop_cfg
        self.pcfg = pcfg or ParallelConfig(flash_threshold=1 << 30, logits_chunk=0)
        self.mesh = mesh
        self.px = ShardCtx(mesh=mesh, pcfg=self.pcfg)
        # a warmup longer than the whole run would cap LR at a fraction of
        # peak (sub-bf16-resolution updates on short smoke runs: nothing
        # learns). Only the degenerate case is clamped — an explicit warmup
        # that fits inside the run is honored as configured.
        warmup = (max(loop_cfg.steps // 10, 1)
                  if loop_cfg.warmup >= loop_cfg.steps else loop_cfg.warmup)
        self.optimizer = AdamW(
            schedule=warmup_cosine(loop_cfg.peak_lr, warmup,
                                   max(loop_cfg.steps, 1)),
            weight_decay=0.01)
        self.metrics = LoopMetrics()

        key = jax.random.PRNGKey(loop_cfg.seed)
        self.params = init_params(arch, key)
        self.opt_state = self.optimizer.init(self.params)
        self.data = DataIterator(make_source(data_cfg))
        self.step = 0

        if loop_cfg.ckpt_dir:
            path = ckpt.latest(loop_cfg.ckpt_dir)
            if path:
                self._restore(path)

        self._step_fn = jax.jit(make_train_step(arch, self.px, self.optimizer),
                                donate_argnums=(0, 1))
        self._ckpt = (ckpt.AsyncCheckpointer(loop_cfg.ckpt_dir)
                      if loop_cfg.ckpt_dir else None)

    # -- checkpoint/restore --------------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt_state": self.opt_state}

    def _restore(self, path: str):
        if self.mesh is not None:
            sh = param_shardings(model_specs(self.arch), self.mesh, self.pcfg)
            shardings = {"params": sh,
                         "opt_state": {"mu": sh, "nu": sh,
                                       "count": jax.tree.leaves(sh)[0]}}
            state, extras = ckpt.restore_sharded(path, self._state_tree(), shardings)
        else:
            state, extras = ckpt.restore(path, self._state_tree())
            state = jax.tree.map(jax.numpy.asarray, state)
        self.params = state["params"]
        self.opt_state = state["opt_state"]
        self.step = int(extras["step"])
        self.data.restore(extras["data"])
        self.metrics.restored_from = path
        self.metrics.start_step = self.step

    def _save(self):
        if not self._ckpt:
            return
        self._ckpt.save(self.step, self._state_tree(),
                        extras={"step": self.step, "data": self.data.state()})

    # -- main loop -------------------------------------------------------------
    def run(self) -> LoopMetrics:
        lc = self.loop_cfg
        ewma = None
        first_timed = True   # first step includes XLA compile — exclude from EWMA
        while self.step < lc.steps:
            if lc.fail_at_step is not None and self.step == lc.fail_at_step:
                raise SimulatedFailure(f"injected failure at step {self.step}")
            batch_np = next(self.data)
            batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
            t0 = time.time()
            self.params, self.opt_state, m = self._step_fn(
                self.params, self.opt_state, batch, self.step)
            loss = float(m["loss"])
            dt = time.time() - t0
            self.metrics.losses.append(loss)
            self.metrics.step_times.append(dt)
            if ewma is not None and dt > lc.straggler_factor * ewma:
                self.metrics.straggler_events.append(self.step)
            if first_timed:
                first_timed = False   # compile step: seed nothing
            elif ewma is None:
                ewma = dt
            else:
                ewma = lc.ewma_alpha * dt + (1 - lc.ewma_alpha) * ewma
            self.step += 1
            if lc.log_every and self.step % lc.log_every == 0:
                print(f"[train] step {self.step} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)")
            if self._ckpt and self.step % lc.ckpt_every == 0:
                self._save()
        if self._ckpt:
            self._save()
            self._ckpt.wait()
        return self.metrics


def run_with_restarts(make_loop: Callable[[int], TrainLoop],
                      max_restarts: int = 3) -> LoopMetrics:
    """Supervisor: restart from the latest checkpoint on failure.

    `make_loop(attempt)` builds a fresh loop; with a ckpt_dir set it restores
    automatically. Failure injection should be conditioned on `attempt` so a
    deterministic injected fault doesn't re-fire after the restart.
    """
    attempt = 0
    while True:
        loop = make_loop(attempt)
        try:
            return loop.run()
        except SimulatedFailure as e:
            # drain in-flight async checkpoint writes before the next attempt
            # scans ckpt_dir: an unfinished .tmp write is invisible to
            # latest(), so restarting immediately would lose the newest step
            if loop._ckpt is not None:
                loop._ckpt.wait()
            attempt += 1
            if attempt > max_restarts:
                raise
            print(f"[train] {e} — restarting ({attempt}/{max_restarts})")
