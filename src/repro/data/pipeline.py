"""Deterministic, sharded, restartable LM data pipeline.

Two sources:
  * SyntheticLM — seeded token stream (a mixture of Zipfian unigrams and
    repeated n-gram motifs so a ~100M model actually has something to learn);
  * MemmapCorpus — flat uint16/uint32 token file, memory-mapped.

Both are (a) deterministic in (seed, step) — a restarted job re-reads the
exact same batch for any step, which makes checkpoint/restart bitwise
reproducible — and (b) host-shardable: each host materializes only its
slice of the global batch (`host_slice`), the layout expected by
jax.make_array_from_process_local_data at 1000-node scale.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"          # synthetic | memmap
    path: Optional[str] = None       # for memmap
    n_motifs: int = 512
    motif_len: int = 16


class SyntheticLM:
    """Zipf unigrams + motif insertions; ~40% of tokens belong to motifs."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        self.motifs = base.integers(
            0, cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len)).astype(np.int32)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.unigram = p / p.sum()

    def batch(self, step: int, host_slice: Tuple[int, int] = (0, 1)
              ) -> Dict[str, np.ndarray]:
        """Global-batch rows [lo, hi) for this host, deterministic in step."""
        cfg = self.cfg
        shard, n_shards = host_slice
        rows = range(shard * cfg.global_batch // n_shards,
                     (shard + 1) * cfg.global_batch // n_shards)
        out = np.empty((len(rows), cfg.seq_len), np.int32)
        for i, row in enumerate(rows):
            rng = np.random.default_rng((cfg.seed, step, row))
            seq = rng.choice(cfg.vocab_size, size=cfg.seq_len, p=self.unigram)
            n_ins = cfg.seq_len // (2 * cfg.motif_len)
            for _ in range(n_ins):
                m = rng.integers(cfg.n_motifs)
                pos = rng.integers(0, cfg.seq_len - cfg.motif_len)
                seq[pos:pos + cfg.motif_len] = self.motifs[m]
            out[i] = seq
        return {"tokens": out}


class MemmapCorpus:
    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        assert cfg.path
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.n_tokens = len(self.data)

    def batch(self, step: int, host_slice: Tuple[int, int] = (0, 1)
              ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        shard, n_shards = host_slice
        rows = range(shard * cfg.global_batch // n_shards,
                     (shard + 1) * cfg.global_batch // n_shards)
        out = np.empty((len(rows), cfg.seq_len), np.int32)
        span = self.n_tokens - cfg.seq_len - 1
        for i, row in enumerate(rows):
            rng = np.random.default_rng((cfg.seed, step, row))
            start = int(rng.integers(0, span))
            out[i] = self.data[start:start + cfg.seq_len]
        return {"tokens": out}


def make_source(cfg: DataConfig):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg)
    if cfg.kind == "memmap":
        return MemmapCorpus(cfg)
    raise ValueError(cfg.kind)


class DataIterator:
    """Stateful cursor over a source; state = just the step (restartable)."""

    def __init__(self, source, start_step: int = 0,
                 host_slice: Tuple[int, int] = (0, 1)):
        self.source = source
        self.step = start_step
        self.host_slice = host_slice

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.source.batch(self.step, self.host_slice)
        self.step += 1
        return b

    def state(self) -> Dict:
        return {"step": self.step}

    def restore(self, state: Dict):
        self.step = int(state["step"])
