"""MusicGen-Large backbone [arXiv:2306.05284; hf].

48L d_model=2048, 32 heads MHA, d_ff=8192, per-codebook vocab 2048.
Decoder-only over EnCodec tokens. The EnCodec frontend is a STUB:
input_specs() provides precomputed frame embeddings (4 codebooks already
summed) per the assignment; cross-attention to stub text-conditioning
embeddings is part of the backbone.
"""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="embeddings",
    cross_attention=True,
    cross_seq=64,
    mlp_act="geglu",
)
