"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048, 32 q heads / 4 kv heads (head_dim 128), qk-norm,
128 routed experts top-8 with d_expert=768, no shared expert.
"""
from repro.configs.arch import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                   # routed expert dim
    vocab_size=151_936,
    qk_norm=True,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=768,
                  num_shared_experts=0, capacity_factor=1.25,
                  router_score="softmax"),
    rope_theta=1_000_000.0,
)
