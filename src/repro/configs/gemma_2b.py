"""Gemma-2B [arXiv:2403.08295; hf].

18L d_model=2048, 8 heads with head_dim=256, MQA (kv=1), GeGLU d_ff=16384,
vocab 256000, tied + sqrt(d)-scaled embeddings.
"""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256_000,
    mlp_act="geglu",
    tie_embeddings=True,
    scale_embeddings=True,
)
