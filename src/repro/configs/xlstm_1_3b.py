"""xLSTM-1.3B [arXiv:2405.04517].

48 blocks d_model=2048 in a 7:1 mLSTM:sLSTM pattern, 4 heads, d_ff=0
(feed-forward lives inside the blocks: mLSTM pre-up-projection x2, sLSTM
post-FFN x4/3). Runs long_500k: constant-size matrix-memory state.
"""
from repro.configs.arch import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    xlstm=XLSTMConfig(num_heads=4, mlstm_proj_factor=2.0,
                      slstm_proj_factor=4.0 / 3.0, mlstm_chunk=64),
)
