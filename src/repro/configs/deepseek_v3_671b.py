"""DeepSeek-V3 671B [arXiv:2412.19437; hf].

61L d_model=7168, MLA with 128 heads, MoE: first 3 layers dense (d_ff=18432),
then 1 shared + 256 routed experts (top-8, d_expert=2048). MTP available as a
config flag (off for dry-runs; see DESIGN.md). The assigned table's d_ff=2048
is the routed-expert dim; kv=128 reflects MLA's per-head latent heads.
"""
from repro.configs.arch import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,                  # routed expert dim
    dense_d_ff=18432,           # first-3 dense layers
    vocab_size=129_280,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                  num_shared_experts=1, capacity_factor=1.25,
                  router_score="sigmoid"),
    moe_dense_first=3,
    rope_theta=10_000.0,
    mtp=False,
    notes="MLA latent cache (c_kv=512 + k_rope=64) makes decode_32k cache ~18x "
          "smaller than GQA-equivalent; decode uses absorbed-weight MLA.",
)
