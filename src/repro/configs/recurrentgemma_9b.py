"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38 layers in a (RG-LRU, RG-LRU, local-attn) 2:1 pattern, d_model=4096,
MQA local attention (16 heads, kv=1, head_dim=256) with a 2048 window,
GeGLU d_ff=12288. Runs long_500k: state is O(d) + a bounded window cache.
"""
from repro.configs.arch import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    mlp_act="geglu",
    block_pattern=("rglru", "rglru", "attn"),
    rglru=RGLRUConfig(lru_width=4096, conv_width=4, c_exponent=8.0),
    local_window=2048,
    scale_embeddings=True,
    tie_embeddings=True,
)
