"""Registry of assigned architectures + reduced smoke variants."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.arch import ArchConfig, MLAConfig, MoEConfig, RGLRUConfig, XLSTMConfig

from repro.configs import (  # noqa: E402
    deepseek_v3_671b,
    qwen3_moe_30b_a3b,
    recurrentgemma_9b,
    gemma_2b,
    mistral_large_123b,
    internlm2_1_8b,
    stablelm_3b,
    musicgen_large,
    chameleon_34b,
    xlstm_1_3b,
)

ARCHS: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        deepseek_v3_671b,
        qwen3_moe_30b_a3b,
        recurrentgemma_9b,
        gemma_2b,
        mistral_large_123b,
        internlm2_1_8b,
        stablelm_3b,
        musicgen_large,
        chameleon_34b,
        xlstm_1_3b,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """A reduced config of the same family, runnable on CPU in seconds.

    Same block pattern / attention type / MoE-ness, tiny widths. The FULL
    configs are exercised only through the dry-run (ShapeDtypeStruct, no
    allocation).
    """
    cfg = get_arch(name)
    kw = dict(
        name=cfg.name + "-smoke",
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 96,
        dense_d_ff=96 if cfg.dense_d_ff else None,
        vocab_size=256,
        cross_seq=8,
    )
    # Keep the pattern but shrink the depth to ~one cycle + remainder.
    if cfg.moe is not None:
        kw["num_layers"] = 3 if cfg.moe_dense_first else 2
        kw["moe_dense_first"] = 1 if cfg.moe_dense_first else 0
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, d_expert=96,
        )
    elif cfg.name.startswith("recurrentgemma"):
        kw["num_layers"] = 5  # (rglru, rglru, attn) + 2 remainder rglru
    elif cfg.name.startswith("xlstm"):
        kw["num_layers"] = 9  # one full 7:1 cycle + remainder
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, num_heads=2, mlstm_chunk=8)
    else:
        kw["num_layers"] = 2
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.rglru is not None:
        kw["rglru"] = dataclasses.replace(cfg.rglru, lru_width=64)
    if cfg.local_window is not None:
        kw["local_window"] = 16
    return cfg.replace(**kw)
