"""Chameleon-34B [arXiv:2405.09818].

48L d_model=8192, 64 heads / 8 kv heads, SwiGLU d_ff=22016, vocab 65536.
Early fusion: VQ image tokens live inside the 65536-entry vocabulary, so the
backbone is token-in/token-out — no separate patch frontend is needed
(DESIGN.md §4). qk-norm per the paper.
"""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65_536,
    qk_norm=True,
)
