"""Architecture configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``: a declarative
description of a block-pattern decoder. The model code in ``repro.models``
consumes only this dataclass — adding an architecture means adding a config
file, not editing model code.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # "softmax" (classic top-k softmax) or "sigmoid" (DeepSeek-V3 style
    # sigmoid scores with normalized top-k weights).
    router_score: str = "softmax"


@dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU recurrent block (Griffin / RecurrentGemma)."""

    lru_width: int = 0          # 0 -> d_model
    conv_width: int = 4
    c_exponent: float = 8.0     # the fixed `c` in a_t = a^(c * r_t)


@dataclass(frozen=True)
class XLSTMConfig:
    """mLSTM / sLSTM blocks (xLSTM)."""

    num_heads: int = 4
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    mlstm_chunk: int = 64       # chunkwise-parallel chunk length for training
    qk_dim_factor: float = 0.5  # d_qk = qk_dim_factor * d_inner (per head after split)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None    # None -> d_model // num_heads
    mlp_act: str = "swiglu"           # swiglu | geglu
    attention: str = "gqa"            # gqa | mla
    # One cycle of the layer pattern; repeated over the depth.
    # kinds: "attn", "rglru", "mlstm", "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)
    # Layers whose MLP is dense even when `moe` is set (e.g. DeepSeek first 3).
    moe_dense_first: int = 0
    dense_d_ff: Optional[int] = None  # d_ff of those dense layers (None -> d_ff)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    local_window: Optional[int] = None  # local attention window (hybrid archs)
    qk_norm: bool = False
    tie_embeddings: bool = False
    scale_embeddings: bool = False    # gemma-style sqrt(d) embed scaling
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # Modality frontend: None -> token ids; "embeddings" -> input_specs()
    # provides precomputed frame/patch embeddings (B, S, d_model).
    frontend: Optional[str] = None
    cross_attention: bool = False     # musicgen text-conditioning cross-attn
    cross_seq: int = 64               # stub text-conditioning length
    mtp: bool = False                 # DeepSeek multi-token-prediction head
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def pattern_layers(self) -> Tuple[Tuple[int, Tuple[str, ...]], ...]:
        """Decompose depth into homogeneous scan segments.

        Returns ``((n_repeat, cycle), ...)`` where each segment repeats its
        cycle of layer kinds ``n_repeat`` times; sum(n * len(cycle)) plus the
        dense-MoE prefix equals num_layers. Segments keep the lowered HLO
        small: each segment is one ``lax.scan``.
        """
        segs = []
        remaining = self.num_layers
        if self.moe is not None and self.moe_dense_first > 0:
            segs.append((self.moe_dense_first, ("attn_dense",)))
            remaining -= self.moe_dense_first
        cyc = self.block_pattern
        full = remaining // len(cyc)
        rem = remaining - full * len(cyc)
        if full > 0:
            segs.append((full, cyc))
        if rem > 0:
            segs.append((1, cyc[:rem]))
        return tuple(segs)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6ND math."""
        from repro.models.params import count_params  # local import, no cycle

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params

        return count_params(self, active_only=True)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(arch: "ArchConfig", shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k requires sub-quadratic context handling (see DESIGN.md §4)."""
    if shape.name == "long_500k" and arch.family not in ("hybrid", "ssm"):
        return False, (
            "long_500k skipped: pure full-attention arch would need a 524288-token "
            "KV cache with no sub-quadratic mechanism (DESIGN.md §4)"
        )
    return True, ""
