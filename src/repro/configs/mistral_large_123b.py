"""Mistral-Large-Instruct-2407 (123B) [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288, 96 heads / 8 kv heads (head_dim 128), SwiGLU d_ff=28672,
vocab 32768. The largest dense assigned arch.
"""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32_768,
    rope_theta=1_000_000.0,
)
