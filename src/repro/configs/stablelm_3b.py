"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b family].

32L d_model=2560, 32 heads MHA (kv=32, head_dim 80), SwiGLU d_ff=6912,
vocab 50304.
"""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50_304,
)
