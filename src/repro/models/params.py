"""Declarative parameter specs for every architecture family.

Each parameter is described once as a ``ParamSpec`` (shape, logical sharding
axes, init, dtype). From the spec tree we derive, without ever allocating the
full model:
  * ``jax.ShapeDtypeStruct`` trees (for the multi-pod dry-run),
  * ``NamedSharding`` trees via ``repro.parallel.sharding`` logical rules,
  * real initialized params (for smoke tests / the ~100M example run),
  * parameter counts (for 6ND roofline math).

The spec tree and the runtime param tree share the exact same dict structure;
``repro.models.layers`` indexes both identically.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.arch import ArchConfig


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | lru_a | rope_none
    scale: Optional[float] = None
    dtype: Optional[str] = None  # None -> cfg.dtype; norms/gates are fp32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


Tree = Dict[str, Any]


def _norm(d: int) -> Tree:
    return {"scale": ParamSpec((d,), (None,), init="ones", dtype="float32")}


def _mlp_specs(cfg: ArchConfig, d_ff: int) -> Tree:
    d = cfg.d_model
    return {
        "wg": ParamSpec((d, d_ff), ("embed", "mlp")),
        "wu": ParamSpec((d, d_ff), ("embed", "mlp")),
        "wd": ParamSpec((d_ff, d), ("mlp", "embed"), scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _gqa_specs(cfg: ArchConfig, cross: bool = False) -> Tree:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    t: Tree = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"),
                        scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qk_norm and not cross:
        t["q_norm"] = _norm(hd)
        t["k_norm"] = _norm(hd)
    return t


def _mla_specs(cfg: ArchConfig) -> Tree:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    return {
        "wq_a": ParamSpec((d, m.q_lora_rank), ("embed", "lora")),
        "q_a_norm": _norm(m.q_lora_rank),
        "wq_b": ParamSpec((m.q_lora_rank, h, dn + dr), ("lora", "heads", "head_dim")),
        "wkv_a": ParamSpec((d, m.kv_lora_rank), ("embed", "lora")),
        "kv_a_norm": _norm(m.kv_lora_rank),
        "wk_rope": ParamSpec((d, dr), ("embed", None)),
        "wk_nope": ParamSpec((m.kv_lora_rank, h, dn), ("lora", "heads", "head_dim")),
        "wv": ParamSpec((m.kv_lora_rank, h, dv), ("lora", "heads", "head_dim")),
        "wo": ParamSpec((h, dv, d), ("heads", "head_dim", "embed"),
                        scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _moe_specs(cfg: ArchConfig) -> Tree:
    mo = cfg.moe
    d, e, f = cfg.d_model, mo.num_experts, mo.d_expert
    t: Tree = {
        "router": ParamSpec((d, e), ("embed", None), dtype="float32"),
        "wg": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "wu": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "wd": ParamSpec((e, f, d), ("experts", "mlp", "embed"),
                        scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }
    if mo.router_score == "sigmoid":
        t["router_bias"] = ParamSpec((e,), (None,), init="zeros", dtype="float32")
    if mo.num_shared_experts > 0:
        t["shared"] = _mlp_specs(cfg, mo.num_shared_experts * mo.d_expert)
    return t


def _rglru_specs(cfg: ArchConfig) -> Tree:
    r = cfg.rglru
    d = cfg.d_model
    width = r.lru_width or d
    nb = cfg.num_heads                 # block-diagonal gate blocks
    bs = width // nb
    return {
        "wx": ParamSpec((d, width), ("embed", "mlp")),
        "wy": ParamSpec((d, width), ("embed", "mlp")),
        "conv_w": ParamSpec((r.conv_width, width), (None, "mlp")),
        "conv_b": ParamSpec((width,), ("mlp",), init="zeros"),
        "gate_r_w": ParamSpec((nb, bs, bs), ("heads", None, None)),
        "gate_r_b": ParamSpec((width,), ("mlp",), init="zeros"),
        "gate_i_w": ParamSpec((nb, bs, bs), ("heads", None, None)),
        "gate_i_b": ParamSpec((width,), ("mlp",), init="zeros"),
        "a_param": ParamSpec((width,), ("mlp",), init="lru_a", dtype="float32"),
        "wo": ParamSpec((width, d), ("mlp", "embed"),
                        scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _mlstm_specs(cfg: ArchConfig) -> Tree:
    x = cfg.xlstm
    d = cfg.d_model
    inner = int(x.mlstm_proj_factor * d)
    nh = x.num_heads
    d_v = inner // nh
    d_qk = int(x.qk_dim_factor * d_v)
    return {
        "w_up": ParamSpec((d, 2, inner), ("embed", None, "mlp")),
        "conv_w": ParamSpec((4, inner), (None, "mlp")),
        "conv_b": ParamSpec((inner,), ("mlp",), init="zeros"),
        "wq": ParamSpec((inner, nh, d_qk), ("mlp", "heads", None)),
        "wk": ParamSpec((inner, nh, d_qk), ("mlp", "heads", None)),
        "wv": ParamSpec((inner, nh, d_v), ("mlp", "heads", None)),
        "w_igate": ParamSpec((inner, nh), ("mlp", "heads"), dtype="float32"),
        "b_igate": ParamSpec((nh,), ("heads",), init="zeros", dtype="float32"),
        "w_fgate": ParamSpec((inner, nh), ("mlp", "heads"), dtype="float32"),
        "b_fgate": ParamSpec((nh,), ("heads",), init="ones", dtype="float32"),
        "out_norm": _norm(inner),
        "w_down": ParamSpec((inner, d), ("mlp", "embed"),
                            scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _slstm_specs(cfg: ArchConfig) -> Tree:
    x = cfg.xlstm
    d = cfg.d_model
    nh = x.num_heads
    dh = d // nh
    f = int(x.slstm_proj_factor * d)
    return {
        "wx": ParamSpec((d, 4, nh, dh), ("embed", None, "heads", None)),
        "r": ParamSpec((4, nh, dh, dh), (None, "heads", None, None)),
        "b": ParamSpec((4, nh, dh), (None, "heads", None), init="zeros", dtype="float32"),
        "group_norm": _norm(d),
    }


def layer_specs(cfg: ArchConfig, kind: str) -> Tree:
    """Specs for one layer of a given kind."""
    if kind in ("attn", "attn_dense"):
        t: Tree = {"ln1": _norm(cfg.d_model), "ln2": _norm(cfg.d_model)}
        t["attn"] = _mla_specs(cfg) if cfg.attention == "mla" else _gqa_specs(cfg)
        if cfg.cross_attention:
            t["ln_cross"] = _norm(cfg.d_model)
            t["cross"] = _gqa_specs(cfg, cross=True)
        if cfg.moe is not None and kind == "attn":
            t["moe"] = _moe_specs(cfg)
        else:
            d_ff = (cfg.dense_d_ff or cfg.d_ff) if kind == "attn_dense" else cfg.d_ff
            t["mlp"] = _mlp_specs(cfg, d_ff)
        return t
    if kind == "rglru":
        return {"ln1": _norm(cfg.d_model), "rec": _rglru_specs(cfg),
                "ln2": _norm(cfg.d_model), "mlp": _mlp_specs(cfg, cfg.d_ff)}
    if kind == "mlstm":
        return {"ln1": _norm(cfg.d_model), "mlstm": _mlstm_specs(cfg)}
    if kind == "slstm":
        return {"ln1": _norm(cfg.d_model), "slstm": _slstm_specs(cfg),
                "ln2": _norm(cfg.d_model),
                "ffn": _mlp_specs(cfg, int(cfg.xlstm.slstm_proj_factor * cfg.d_model))}
    raise ValueError(f"unknown layer kind {kind!r}")


def _stack_spec(spec: ParamSpec, n: int) -> ParamSpec:
    return dataclasses.replace(spec, shape=(n, *spec.shape),
                               logical=("layers", *spec.logical))


def model_specs(cfg: ArchConfig) -> Tree:
    """Full spec tree. Segments are stacked along a leading `layers` axis."""
    t: Tree = {}
    if cfg.frontend != "embeddings":
        t["embed"] = {"table": ParamSpec((cfg.vocab_size, cfg.d_model),
                                         ("vocab", "embed"), scale=0.02)}
    segs = []
    for (n_rep, cycle) in cfg.pattern_layers():
        cyc_tree: Tree = {}
        for j, kind in enumerate(cycle):
            layer = layer_specs(cfg, kind)
            cyc_tree[f"{j}:{kind}"] = jax.tree.map(
                lambda s: _stack_spec(s, n_rep), layer,
                is_leaf=lambda x: isinstance(x, ParamSpec))
        segs.append(cyc_tree)
    t["segments"] = segs
    t["final_norm"] = _norm(cfg.d_model)
    # Tied archs read logits from the embed table; frontend archs have no
    # embed table so they always need an explicit head.
    if cfg.frontend == "embeddings" or not cfg.tie_embeddings:
        t["lm_head"] = {"w": ParamSpec((cfg.d_model, cfg.vocab_size),
                                       ("embed", "vocab"), scale=0.02)}
    return t


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_leaves(tree: Tree):
    return jax.tree.leaves(tree, is_leaf=is_spec)


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    """Total (or active, for MoE 6·N_active·D math) parameter count."""
    total = 0
    for path, spec in jax.tree_util.tree_flatten_with_path(
            model_specs(cfg), is_leaf=is_spec)[0]:
        n = int(np.prod(spec.shape))
        if active_only and cfg.moe is not None:
            keys = "/".join(getattr(k, "key", str(k)) for k in path)
            if "/moe/" in keys or keys.endswith("router"):
                if "/shared/" not in keys and "router" not in keys.rsplit("/", 1)[-1]:
                    n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total


def abstract_params(cfg: ArchConfig, shardings: Optional[Tree] = None) -> Tree:
    """ShapeDtypeStruct tree (optionally with shardings attached)."""
    def mk(spec: ParamSpec, sh=None):
        dt = jnp.dtype(spec.dtype or cfg.dtype)
        if sh is not None:
            return jax.ShapeDtypeStruct(spec.shape, dt, sharding=sh)
        return jax.ShapeDtypeStruct(spec.shape, dt)

    specs = model_specs(cfg)
    if shardings is None:
        return jax.tree.map(mk, specs, is_leaf=is_spec)
    return jax.tree.map(mk, specs, shardings, is_leaf=is_spec)


def init_params(cfg: ArchConfig, key: jax.Array) -> Tree:
    """Real initialization (used for smoke tests and the ~100M example)."""
    specs = model_specs(cfg)
    flat, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(flat))

    def one(spec: ParamSpec, k):
        dt = jnp.dtype(spec.dtype or cfg.dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        if spec.init == "lru_a":
            # Griffin init: a = sigmoid(Lambda) spread in (0.9, 0.999)
            u = jax.random.uniform(k, spec.shape, jnp.float32, 0.9, 0.999)
            return jnp.log(u / (1.0 - u)).astype(dt)
        scale = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dt)

    return jax.tree_util.tree_unflatten(treedef, [one(s, k) for s, k in zip(flat, keys)])
