"""Forward functions for every layer family.

Pure functions over param dicts produced by ``repro.models.params``. All
layers share the signature pattern ``(params, x, *, cfg, px, mode, cache,
positions) -> (y, new_cache)`` where
  * mode  — "train" | "prefill" | "decode"
  * cache — per-layer state dict (None in train mode)
  * positions — (B, S) int32 absolute positions (decode: (B, 1) = current pos)
  * px    — ShardCtx threading mesh + ParallelConfig for GSPMD constraints
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.arch import ArchConfig
from repro.parallel.sharding import ShardCtx, constrain

Cache = Optional[Dict[str, jax.Array]]

# ---------------------------------------------------------------------------
# basics


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def _act(name: str):
    return jax.nn.gelu if name == "geglu" else jax.nn.silu


def mlp(p, x: jax.Array, cfg: ArchConfig, px: ShardCtx) -> jax.Array:
    h = _act(cfg.mlp_act)(x @ p["wg"]) * (x @ p["wu"])
    h = constrain(h, ("act_batch", "act_seq", "act_mlp"), px)
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# RoPE


def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """positions (B,S) -> cos/sin (B,S,head_dim/2), fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B,S,H,hd); rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# attention cores


def _direct_attention(q, k, v, *, q_pos, k_pos, window, scale):
    """Materialized-scores attention (small seq / smoke tests).

    q (B,Sq,H,hd), k/v (B,Sk,KV,hd); GQA by head grouping.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    hd_v = v.shape[-1]  # MLA: v head dim differs from q/k head dim
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    mask = k_pos[:, None, :] <= q_pos[:, :, None]  # (B,Sq,Sk) causal
    if window is not None:
        mask &= k_pos[:, None, :] > q_pos[:, :, None] - window
    scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd_v)


def _flash_attention(q, k, v, *, q_pos, k_pos, window, scale, px: ShardCtx):
    """Blockwise online-softmax attention (lax.scan over KV blocks).

    Keeps O(Sq·block_kv) transients instead of O(Sq·Sk). With
    ``px.pcfg.attn_q_chunks > 1`` the causal upper-triangle of KV blocks is
    statically skipped per q-chunk (saves ~(1 - (c+1)/2c) of attention FLOPs).
    """
    pcfg = px.pcfg
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]  # MLA: v head dim differs from q/k head dim
    G = H // KV
    bk = min(pcfg.attn_block_kv, Sk)
    n_chunks = pcfg.attn_q_chunks if (Sq == Sk and Sq % pcfg.attn_q_chunks == 0) else 1

    def run_chunk(qc, qc_pos, k_part, v_part, kp_part):
        nk = k_part.shape[1] // bk
        kb = k_part.reshape(B, nk, bk, KV, hd)
        vb = v_part.reshape(B, nk, bk, KV, hd_v)
        kpb = kp_part.reshape(B, nk, bk)
        Sqc = qc.shape[1]
        qg = qc.reshape(B, Sqc, KV, G, hd)

        def body(carry, blk):
            m, l, acc = carry
            k_j, v_j, kp_j = blk  # (B,bk,KV,hd),(B,bk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_j).astype(jnp.float32) * scale
            msk = kp_j[:, None, :] <= qc_pos[:, :, None]
            if window is not None:
                msk &= kp_j[:, None, :] > qc_pos[:, :, None] - window
            s = jnp.where(msk[:, None, None, :, :], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (m_new == -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v_j.dtype), v_j)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, Sqc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, Sqc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, Sqc, hd_v), jnp.float32)
        (m, l, acc), _ = lax.scan(
            body, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
             kpb.transpose(1, 0, 2)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, Sqc, H, hd_v).astype(q.dtype)

    if n_chunks == 1:
        return run_chunk(q, q_pos, k, v, k_pos)
    # causal q-chunking: chunk i only sees KV up to its own end (static slice)
    outs = []
    cq = Sq // n_chunks
    for i in range(n_chunks):
        hi = (i + 1) * cq
        hi_k = ((hi + bk - 1) // bk) * bk  # round up to block boundary
        outs.append(run_chunk(q[:, i * cq:hi], q_pos[:, i * cq:hi],
                              k[:, :hi_k], v[:, :hi_k], k_pos[:, :hi_k]))
    return jnp.concatenate(outs, axis=1)


def _pallas_flash_ok(S: int, hd: int, hd_v: int, window, kc) -> bool:
    """Static preconditions for dispatching the Pallas flash kernel: opted in
    via KernelConfig, plain causal attention (no local window), equal q/k/v
    head dims (the kernel streams one (S, hd) layout), and a sequence the
    tuned blocks tile exactly. Anything else falls back to the pure-JAX
    paths — dispatch never changes semantics, only the implementation."""
    return (kc is not None and kc.use_flash and window is None
            and hd == hd_v and S % kc.flash_block_q == 0
            and S % kc.flash_block_kv == 0)


def _pallas_flash_attention(q, k, v, kc):
    """GQA-expanded dispatch into the tuned Pallas flash kernel.

    The kernel is an MHA core (its fp32 (m, l, acc) state lives in VMEM and
    never round-trips through HBM, which the lax.scan formulation above
    cannot express); GQA feeds it by expanding KV heads to the q head count.
    Assumes contiguous positions starting at 0 — what train/prefill steps
    produce; windowed/decode paths never reach here (``_pallas_flash_ok``).
    """
    from repro.kernels import ops as kernel_ops
    G = q.shape[2] // k.shape[2]
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    return kernel_ops.flash_attention(
        q, k, v, block_q=kc.flash_block_q, block_kv=kc.flash_block_kv,
        causal=True, interpret=kc.interpret)


def _pallas_decode_ok(hd: int, hd_v: int, kc) -> bool:
    """Static preconditions for the Pallas flash-decode kernel: opted in via
    KernelConfig and equal k/v head dims (the split kernel accumulates one
    (G, hd) layout — the MLA ``dn+dr != dv`` variant stays pure-JAX).
    Windows, rolling caches, partial occupancy, and capacities that don't
    tile into the tuned blocks are all handled inside the kernel wrapper
    (validity-bias + padding), so they don't gate dispatch."""
    return kc is not None and kc.use_decode and hd == hd_v


def _pallas_decode_attention(q, k_cache, v_cache, *, cache_pos, cur_pos,
                             window, kc):
    """Dispatch one decode step into the tuned split-KV flash-decode kernel
    (semantics-matched to ``_decode_attention``; parity pinned in tests)."""
    from repro.kernels import ops as kernel_ops
    return kernel_ops.decode_attention(
        q, k_cache, v_cache, cache_pos, cur_pos, window=window,
        block_kv=kc.decode_block_kv, num_splits=kc.decode_num_splits,
        combine=kc.decode_combine, interpret=kc.interpret)


def _decode_attention(q, k_cache, v_cache, *, cache_pos, cur_pos, window, scale):
    """Single-token attention over a cache. q (B,1,H,hd), cache (B,S,KV,hd).

    cache_pos (B,S): absolute position stored in each cache slot (-1 = empty).
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32) * scale
    valid = (cache_pos >= 0) & (cache_pos <= cur_pos[:, None])
    if window is not None:
        valid &= cache_pos > cur_pos[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# GQA / MQA / MHA attention layer (optionally local-windowed, cross-attn)


def gqa_attention(p, x, *, cfg: ArchConfig, px: ShardCtx, mode: str,
                  cache: Cache, positions, window=None) -> Tuple[jax.Array, Cache]:
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, ("act_batch", "act_seq", "act_heads", None), px)
    k = constrain(k, ("act_batch", "act_seq", "act_kv_heads", None), px)

    new_cache = cache
    if mode == "decode":
        assert cache is not None and S == 1
        slot = _cache_slot(positions[:, 0], cache["k"].shape[1], window)
        k_cache = _insert_slot(cache["k"], k, slot)
        v_cache = _insert_slot(cache["v"], v, slot)
        cache_pos = _insert_slot(cache["pos"], positions, slot)
        if _pallas_decode_ok(hd, v.shape[-1], px.pcfg.kernel):
            out = _pallas_decode_attention(
                q, k_cache, v_cache, cache_pos=cache_pos,
                cur_pos=positions[:, 0], window=window, kc=px.pcfg.kernel)
        else:
            out = _decode_attention(q, k_cache, v_cache, cache_pos=cache_pos,
                                    cur_pos=positions[:, 0], window=window,
                                    scale=scale)
        new_cache = {"k": k_cache, "v": v_cache, "pos": cache_pos}
    else:
        q_pos = positions
        k_pos = positions
        if _pallas_flash_ok(S, hd, v.shape[-1], window, px.pcfg.kernel):
            out = _pallas_flash_attention(q, k, v, px.pcfg.kernel)
        elif S >= px.pcfg.flash_threshold:
            out = _flash_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                   window=window, scale=scale, px=px)
        else:
            out = _direct_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                    window=window, scale=scale)
        if mode == "prefill":
            assert cache is not None
            cap = cache["k"].shape[1]
            new_cache = _prefill_cache(cache, k, v, positions, cap, window)
    out = constrain(out, ("act_batch", "act_seq", "act_heads", None), px)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def cross_attention(p, x, cond_kv, *, cfg: ArchConfig, px: ShardCtx) -> jax.Array:
    """Attention over precomputed (k, v) from conditioning embeddings."""
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = cond_kv
    B, Sq, H, _ = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, Sq, KV, H // KV, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    prob = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", prob.astype(v.dtype), v).reshape(B, Sq, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cond_kv(p, cond, *, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", cond, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", cond, p["wv"])
    return k, v


def _cache_slot(pos, capacity, window):
    """Rolling slot for windowed caches; direct slot otherwise."""
    return jnp.remainder(pos, capacity) if window is not None else pos


def _insert_slot(buf, val, slot):
    """Insert val (B,1,...) at per-batch slot (B,) along axis 1."""
    B = buf.shape[0]
    return buf.at[jnp.arange(B), slot].set(val[:, 0] if val.ndim == buf.ndim else val[:, 0])


def _prefill_cache(cache, k, v, positions, cap, window):
    """Write prefill K/V into a fresh cache (last `cap` tokens if windowed)."""
    B, S = positions.shape
    if S >= cap:
        kk, vv, pp = k[:, S - cap:], v[:, S - cap:], positions[:, S - cap:]
        if window is not None:
            # decode inserts at slot = pos % cap; rearrange so slot s holds the
            # entry whose position ≡ s (mod cap): source j = (s - p0) mod cap.
            idx = (jnp.arange(cap)[None, :] - pp[:, 0:1]) % cap  # (B, cap)
            kk = jnp.take_along_axis(kk, idx[..., None, None], axis=1)
            vv = jnp.take_along_axis(vv, idx[..., None, None], axis=1)
            pp = jnp.take_along_axis(pp, idx, axis=1)
        return {"k": kk, "v": vv, "pos": pp}
    pad = cap - S
    kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pp = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
    return {"k": kk, "v": vv, "pos": pp}


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)


def mla_attention(p, x, *, cfg: ArchConfig, px: ShardCtx, mode: str,
                  cache: Cache, positions) -> Tuple[jax.Array, Cache]:
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)

    q_lat = rms_norm(x @ p["wq_a"], p["q_a_norm"]["scale"], cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", q_lat, p["wq_b"])  # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    c_kv = rms_norm(x @ p["wkv_a"], p["kv_a_norm"]["scale"], cfg.norm_eps)  # (B,S,r_kv)
    k_rope = x @ p["wk_rope"]  # (B,S,dr) shared across heads

    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    q_nope = constrain(q_nope, ("act_batch", "act_seq", "act_heads", None), px)

    if mode == "decode":
        assert cache is not None and S == 1
        slot = positions[:, 0]
        ckv_cache = cache["c_kv"].at[jnp.arange(B), slot].set(c_kv[:, 0])
        krope_cache = cache["k_rope"].at[jnp.arange(B), slot].set(k_rope[:, 0])
        pos_cache = cache["pos"].at[jnp.arange(B), slot].set(positions[:, 0])
        # absorbed-weight decode: score/combine in the compressed space
        q_c = jnp.einsum("bshn,lhn->bshl", q_nope, p["wk_nope"])  # (B,1,H,r_kv)
        s = (jnp.einsum("bshl,btl->bhst", q_c, ckv_cache) +
             jnp.einsum("bshr,btr->bhst", q_rope, krope_cache)).astype(jnp.float32)
        s = s * scale
        valid = (pos_cache >= 0) & (pos_cache <= positions[:, :1])  # (B, cap)
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        prob = jax.nn.softmax(s, axis=-1)
        ctx_c = jnp.einsum("bhst,btl->bshl", prob.astype(ckv_cache.dtype), ckv_cache)
        out = jnp.einsum("bshl,lhv->bshv", ctx_c, p["wv"])  # (B,1,H,dv)
        y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
        return y, {"c_kv": ckv_cache, "k_rope": krope_cache, "pos": pos_cache}

    # train / prefill: expand k_nope & v per head, run flash path
    k_nope = jnp.einsum("bsl,lhn->bshn", c_kv, p["wk_nope"])
    v = jnp.einsum("bsl,lhv->bshv", c_kv, p["wv"])
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    if _pallas_flash_ok(S, dn + dr, dv, None, px.pcfg.kernel):
        # MLA head dims rarely line up (dn+dr != dv); when they do the
        # tuned kernel applies unchanged — scale is 1/sqrt(q head dim)
        out = _pallas_flash_attention(q_full, k_full, v, px.pcfg.kernel)
    elif S >= px.pcfg.flash_threshold:
        out = _flash_attention(q_full, k_full, v, q_pos=positions, k_pos=positions,
                               window=None, scale=scale, px=px)
    else:
        out = _direct_attention(q_full, k_full, v, q_pos=positions, k_pos=positions,
                                window=None, scale=scale)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    new_cache = cache
    if mode == "prefill":
        assert cache is not None
        cap = cache["c_kv"].shape[1]
        pad = cap - S
        new_cache = {
            "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
            "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
            "pos": jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1),
        }
    return y, new_cache


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based scatter dispatch, EP over `model` axis)


def moe_block(p, x, *, cfg: ArchConfig, px: ShardCtx) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss). Dispatch: top-k → position-in-expert via
    one-hot cumsum → scatter into (G, E, C, d) expert buffers (E sharded over
    `model` = expert parallelism; G = data-parallel dispatch groups)."""
    mo = cfg.moe
    B, S, d = x.shape
    E, K = mo.num_experts, mo.top_k
    cf = px.pcfg.capacity_factor or mo.capacity_factor
    G = max(px.axis_sizes.get("data", 1) * px.axis_sizes.get("pod", 1), 1)
    T = B * S
    if T % G != 0:
        G = 1
    Tg = T // G
    C = int(max(math.ceil(Tg * K / E * cf), K))
    C = min(C, Tg)

    xg = x.reshape(G, Tg, d)
    xg = constrain(xg, ("act_group", None, "act_embed"), px)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if mo.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"][None, None, :]
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        sel = scores
    top_vals, top_idx = lax.top_k(sel, K)  # (G,Tg,K)
    if mo.router_score == "sigmoid":
        gate = jnp.take_along_axis(scores, top_idx, axis=-1)
        weights = gate / (gate.sum(-1, keepdims=True) + 1e-9)
    else:
        weights = jnp.take_along_axis(scores, top_idx, axis=-1)
        weights = weights / (weights.sum(-1, keepdims=True) + 1e-9)

    # position-in-expert via cumsum of one-hot over flattened (token, k) copies
    flat_e = top_idx.reshape(G, Tg * K)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (G, Tg*K, E)
    pos_all = jnp.cumsum(oh, axis=1) - 1                        # occupancy - 1
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=-1)[..., 0]
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)                             # C = drop slot
    pos_k = pos_c.reshape(G, Tg, K)
    keep_k = keep.reshape(G, Tg, K)

    # Dispatch = ONE int-index scatter + ONE gather. Scattering the d-wide
    # activations into an (E-sharded) buffer makes GSPMD materialize and
    # all-reduce the full buffer per layer (measured: 56 TB/step on
    # deepseek-v3 — EXPERIMENTS.md §Perf B); an (E,C) int32 routing table is
    # 7168x smaller, and the gather from data-sharded tokens is local.
    g_idx = jnp.arange(G)[:, None]
    token_ids = jnp.broadcast_to(jnp.arange(Tg, dtype=jnp.int32)[None, :], (G, Tg))
    idx_buf = jnp.full((G, E, C + 1), Tg, jnp.int32)      # sentinel -> zero row
    for j in range(K):  # K small (≤8): unrolled int scatters
        idx_buf = idx_buf.at[g_idx, top_idx[:, :, j], pos_k[:, :, j]].set(token_ids)
    idx_buf = idx_buf[:, :, :C]
    x_pad = jnp.concatenate([xg, jnp.zeros((G, 1, d), x.dtype)], axis=1)
    buf = jnp.take_along_axis(x_pad, idx_buf.reshape(G, E * C)[..., None],
                              axis=1).reshape(G, E, C, d)
    buf = constrain(buf, ("act_group", "act_experts", None, None), px)

    h = _act(cfg.mlp_act)(jnp.einsum("gecd,edf->gecf", buf, p["wg"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["wu"])
    h = constrain(h, ("act_group", "act_experts", None, "act_mlp"), px)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    if px.pcfg.moe_combine == "a2a":
        # axis-swap reshard E->d over `model`: GSPMD emits a true all-to-all
        # and the combine gathers below become device-local (§Perf B6)
        out_buf = constrain(out_buf, ("act_group", None, None, "act_mlp"), px)
    else:
        out_buf = constrain(out_buf, ("act_group", "act_experts", None, None), px)
    out_buf = jnp.pad(out_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))  # drop slot→0

    y = jnp.zeros_like(xg)
    for j in range(K):
        gathered = out_buf[g_idx, top_idx[:, :, j], pos_k[:, :, j]]  # (G,Tg,d)
        w = (weights[:, :, j] * keep_k[:, :, j]).astype(x.dtype)
        y = y + gathered * w[..., None]
    if px.pcfg.moe_combine == "a2a":
        y = constrain(y, ("act_group", None, "act_mlp"), px)

    if mo.num_shared_experts > 0:
        y = y + mlp(p["shared"], xg, cfg, px)

    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=(1, 2))
    ce = jnp.mean(scores, axis=1)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * E * mo.router_aux_weight
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma recurrent block)


def _block_diag(x, w, b):
    """x (...,L) with w (nb, bs, bs): block-diagonal linear."""
    nb, bs, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, bs)
    y = jnp.einsum("...nb,nbc->...nc", xs, w)
    return y.reshape(*x.shape) + b


def _causal_conv(x, w, b, state):
    """Depthwise causal conv, width cw. x (B,S,L), state (B,cw-1,L) or None."""
    cw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xp[:, j:j + S] * w[j] for j in range(cw)) + b
    new_state = xp[:, xp.shape[1] - (cw - 1):]
    return y, new_state


def rglru_block(p, x, *, cfg: ArchConfig, px: ShardCtx, mode: str,
                cache: Cache) -> Tuple[jax.Array, Cache]:
    r = cfg.rglru
    B, S, _ = x.shape
    gate_y = jax.nn.gelu(x @ p["wy"])
    xx = x @ p["wx"]
    xx = constrain(xx, ("act_batch", "act_seq", "act_mlp"), px)
    conv_state = cache["conv"] if cache is not None else None
    xx, new_conv = _causal_conv(xx, p["conv_w"], p["conv_b"], conv_state)

    rg = jax.nn.sigmoid(_block_diag(xx, p["gate_r_w"], p["gate_r_b"]).astype(jnp.float32))
    ig = jax.nn.sigmoid(_block_diag(xx, p["gate_i_w"], p["gate_i_b"]).astype(jnp.float32))
    log_a = -r.c_exponent * jax.nn.softplus(p["a_param"]) * rg  # (B,S,L) fp32
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    gated = mult * ig * xx.astype(jnp.float32)

    h0 = cache["h"].astype(jnp.float32) if cache is not None else jnp.zeros(
        (B, xx.shape[-1]), jnp.float32)
    if mode == "decode":
        h = a[:, 0] * h0 + gated[:, 0]
        hs = h[:, None, :]
        new_h = h
    else:
        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2
        A, Bc = lax.associative_scan(comb, (a, gated), axis=1)
        hs = A * h0[:, None, :] + Bc
        new_h = hs[:, -1]
    y = (gate_y * hs.astype(x.dtype)) @ p["wo"]
    new_cache = None if cache is None else {"conv": new_conv.astype(cache["conv"].dtype),
                                            "h": new_h.astype(cache["h"].dtype)}
    return y, new_cache


# ---------------------------------------------------------------------------
# xLSTM blocks


def _mlstm_chunkwise(q, k, v, ig, fg, c0, n0, m0, chunk: int,
                     bf16_streams: bool = False):
    """Chunkwise-parallel stabilized mLSTM (beyond-paper §Perf hillclimb A).

    Exact reformulation of the per-step recurrence: the matrix state is
    updated once per chunk (HBM traffic ÷ chunk) and intra-chunk work is
    (C×C)·(C×d) matmuls (MXU-shaped). Stabilizers cancel algebraically;
    only fp rounding differs from the sequential scan (tests assert ≈).

    q,k (B,S,nh,dqk) [q pre-scaled], v (B,S,nh,dv), ig/fg (B,S,nh) raw gates;
    state c0 (B,nh,dqk,dv), n0 (B,nh,dqk), m0 (B,nh).
    """
    B, S, nh, dqk = q.shape
    dv = v.shape[-1]
    C = chunk
    nc = S // C
    f32 = jnp.float32

    def resh(a, d):
        return a.reshape(B, nc, C, nh, d).transpose(1, 0, 3, 2, 4)  # (nc,B,nh,C,d)

    # bf16_streams: keep q/k/v and the (C,*) intermediates in bf16 (gates,
    # normalizers and the carried state stay fp32) — §Perf hillclimb A4.
    sdt = jnp.bfloat16 if bf16_streams else f32
    qs, ks, vs = resh(q.astype(sdt), dqk), resh(k.astype(sdt), dqk), resh(v.astype(sdt), dv)
    gi = ig.reshape(B, nc, C, nh).transpose(1, 0, 3, 2)              # (nc,B,nh,C)
    logf = jax.nn.log_sigmoid(fg).reshape(B, nc, C, nh).transpose(1, 0, 3, 2)

    causal = jnp.tril(jnp.ones((C, C), bool))

    def step(carry, inp):
        c0, n0, m0 = carry                     # (B,nh,dqk,dv),(B,nh,dqk),(B,nh)
        q_c, k_c, v_c, ig_c, lf_c = inp        # (B,nh,C,*)
        b = jnp.cumsum(lf_c, axis=-1)          # (B,nh,C) inclusive log-decay
        btot = b[..., -1]
        w = ig_c - b                           # log source weight vs chunk start
        m_c = jnp.max(w, axis=-1)              # (B,nh)
        e_src = jnp.exp(w - m_c[..., None])    # (B,nh,C) ≤ 1
        decay = jnp.exp(b)                     # (B,nh,C) ≤ 1

        # intra-chunk: W[j,s] = decay_j * e_src_s (separable), causal mask
        Wm = (decay[..., :, None] * e_src[..., None, :] * causal).astype(sdt)
        s_qk = jnp.einsum("bhjd,bhsd->bhjs", q_c, k_c,
                          preferred_element_type=f32)
        wqk = (s_qk * Wm.astype(f32)).astype(sdt)
        num_i = jnp.einsum("bhjs,bhsv->bhjv", wqk, v_c,
                           preferred_element_type=f32)
        # n_intra_j = Σ_s W[j,s] k_s ; den_i = q_j · n_intra_j
        n_i = jnp.einsum("bhjs,bhsd->bhjd", Wm, k_c,
                         preferred_element_type=f32)
        den_i = jnp.einsum("bhjd,bhjd->bhj", q_c.astype(f32), n_i)

        # inter-chunk (previous state), per-position combine like flash
        mu = jnp.maximum(m0[..., None] + b, m_c[..., None])     # (B,nh,C)
        sc_prev = jnp.exp(m0[..., None] + b - mu)
        sc_intra = jnp.exp(m_c[..., None] - mu)
        num_p = jnp.einsum("bhjd,bhdv->bhjv", q_c.astype(f32), c0)
        den_p = jnp.einsum("bhjd,bhd->bhj", q_c.astype(f32), n0)
        num = sc_prev[..., None] * num_p + sc_intra[..., None] * num_i
        den = sc_prev * den_p + sc_intra * den_i
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-mu))[..., None]

        # end-of-chunk state
        M = jnp.maximum(m0, m_c)
        e2 = jnp.exp(w - M[..., None])                           # (B,nh,C)
        kw_ = (e2[..., None].astype(sdt) * k_c)
        c_new = (jnp.exp(m0 - M)[..., None, None] * c0
                 + jnp.einsum("bhsd,bhsv->bhdv", kw_, v_c,
                              preferred_element_type=f32))
        n_new = (jnp.exp(m0 - M)[..., None] * n0
                 + jnp.sum(kw_, axis=-2).astype(f32))
        m_new = btot + M
        return (c_new, n_new, m_new), h

    (c, n, m), hs = lax.scan(step, (c0, n0, m0), (qs, ks, vs, gi, logf))
    # hs (nc,B,nh,C,dv) -> (B,S,nh,dv)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, nh, dv)
    return h, (c, n, m)


def mlstm_block(p, x, *, cfg: ArchConfig, px: ShardCtx, mode: str,
                cache: Cache) -> Tuple[jax.Array, Cache]:
    xc = cfg.xlstm
    B, S, d = x.shape
    up = jnp.einsum("bsd,dti->bsti", x, p["w_up"])
    gate_br, inner_in = up[:, :, 0], up[:, :, 1]
    inner_in = constrain(inner_in, ("act_batch", "act_seq", "act_mlp"), px)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(inner_in, p["conv_w"], p["conv_b"], conv_state)
    conv_out = jax.nn.silu(conv_out)

    nh = xc.num_heads
    q = jnp.einsum("bsi,ihk->bshk", conv_out, p["wq"])
    k = jnp.einsum("bsi,ihk->bshk", conv_out, p["wk"])
    v = jnp.einsum("bsi,ihk->bshk", inner_in, p["wv"])
    dqk = q.shape[-1]
    q = q / math.sqrt(dqk)
    ig = (jnp.einsum("bsi,ih->bsh", conv_out.astype(jnp.float32), p["w_igate"])
          + p["b_igate"])
    fg = (jnp.einsum("bsi,ih->bsh", conv_out.astype(jnp.float32), p["w_fgate"])
          + p["b_fgate"])

    if cache is not None:
        c0 = cache["c"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)
    else:
        dv = v.shape[-1]
        c0 = jnp.zeros((B, nh, dqk, dv), jnp.float32)
        n0 = jnp.zeros((B, nh, dqk), jnp.float32)
        m0 = jnp.zeros((B, nh), jnp.float32)

    chunk = px.pcfg.mlstm_chunk
    if mode != "decode" and chunk and S % chunk == 0 and S > chunk:
        h, (c, n, m) = _mlstm_chunkwise(q, k, v, ig, fg, c0, n0, m0, chunk,
                                        bf16_streams=px.pcfg.mlstm_bf16_streams)
        h = h.reshape(B, S, -1)
        h = rms_norm(h, p["out_norm"]["scale"], cfg.norm_eps)
        h = h * jax.nn.silu(gate_br)
        y = jnp.einsum("bsi,id->bsd", h.astype(x.dtype), p["w_down"])
        new_cache = None if cache is None else {
            "c": c.astype(cache["c"].dtype), "n": n.astype(cache["n"].dtype),
            "m": m.astype(cache["m"].dtype),
            "conv": new_conv.astype(cache["conv"].dtype)}
        return y, new_cache

    def step(carry, inp):
        c, n, m = carry
        q_t, k_t, v_t, ig_t, fg_t = inp
        logf = jax.nn.log_sigmoid(fg_t)                      # (B,nh)
        m_new = jnp.maximum(logf + m, ig_t)
        i_p = jnp.exp(ig_t - m_new)
        f_p = jnp.exp(logf + m - m_new)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        c = f_p[..., None, None] * c + i_p[..., None, None] * kv
        n = f_p[..., None] * n + i_p[..., None] * k_t.astype(jnp.float32)
        num = jnp.einsum("bhk,bhkv->bhv", q_t.astype(jnp.float32), c)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", q_t.astype(jnp.float32), n))
        den = jnp.maximum(den, jnp.exp(-m_new))
        h = num / den[..., None]
        return (c, n, m_new), h

    seq = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
           v.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2), fg.transpose(1, 0, 2))
    (c, n, m), hs = lax.scan(step, (c0, n0, m0), seq)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, -1)           # (B,S,inner)
    h = rms_norm(h, p["out_norm"]["scale"], cfg.norm_eps)
    h = h * jax.nn.silu(gate_br)
    y = jnp.einsum("bsi,id->bsd", h.astype(x.dtype), p["w_down"])
    new_cache = None if cache is None else {
        "c": c.astype(cache["c"].dtype), "n": n.astype(cache["n"].dtype),
        "m": m.astype(cache["m"].dtype), "conv": new_conv.astype(cache["conv"].dtype)}
    return y, new_cache


def slstm_block(p, x, *, cfg: ArchConfig, px: ShardCtx, mode: str,
                cache: Cache) -> Tuple[jax.Array, Cache]:
    xc = cfg.xlstm
    B, S, d = x.shape
    nh = xc.num_heads
    dh = d // nh
    xg = jnp.einsum("bsd,dghk->bsghk", x, p["wx"]).astype(jnp.float32)  # (B,S,4,nh,dh)

    if cache is not None:
        c0, n0, h0, m0 = (cache[k].astype(jnp.float32) for k in ("c", "n", "h", "m"))
    else:
        z = jnp.zeros((B, nh, dh), jnp.float32)
        c0, n0, h0, m0 = z, z + 1e-6, z, z

    def step(carry, xg_t):
        c, n, h, m = carry
        rec = jnp.einsum("bhk,ghkl->bghl", h, p["r"].astype(jnp.float32))
        pre = xg_t + rec + p["b"]
        i_raw, f_raw, z_raw, o_raw = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        m_new = jnp.maximum(f_raw + m, i_raw)
        i_g = jnp.exp(i_raw - m_new)
        f_g = jnp.exp(f_raw + m - m_new)
        c = f_g * c + i_g * jnp.tanh(z_raw)
        n = f_g * n + i_g
        h_new = jax.nn.sigmoid(o_raw) * (c / jnp.maximum(n, 1e-6))
        return (c, n, h_new, m_new), h_new

    (c, n, h, m), hs = lax.scan(step, (c0, n0, h0, m0), xg.transpose(1, 0, 2, 3, 4))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, d)
    y = rms_norm(y, p["group_norm"]["scale"], cfg.norm_eps).astype(x.dtype)
    new_cache = None if cache is None else {
        "c": c.astype(cache["c"].dtype), "n": n.astype(cache["n"].dtype),
        "h": h.astype(cache["h"].dtype), "m": m.astype(cache["m"].dtype)}
    return y, new_cache
