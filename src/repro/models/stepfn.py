"""Train / prefill / decode step functions.

These are the functions the launcher jits and the dry-run lowers. They are
pure; distribution comes from input shardings + internal constraints.

Kernel dispatch (DESIGN.md §14): when ``px.pcfg.kernel`` is set, the
attention layers these steps trace route train/prefill attention through
the tuned Pallas flash kernel (``models/layers.py::_pallas_flash_ok``
gates it statically, so the choice is baked into the jitted step — a
kernel hot-swap means re-deriving the step fns, which
``launch/serve.py::DecodeServer`` memoizes in its compiled-kernel cache).
With ``kernel=None`` (the default) every path is pure-JAX and
byte-identical to pre-§14 traces.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.arch import ArchConfig
from repro.models import model as M
from repro.models.layers import rms_norm
from repro.parallel.sharding import ShardCtx, constrain

Tree = Dict[str, Any]


# ---------------------------------------------------------------------------
# loss


def chunked_xent(x: jax.Array, head_w: jax.Array, labels: jax.Array,
                 px: ShardCtx) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy with the (B,S,V) logits never fully materialized.

    Scans over sequence chunks; each chunk's logits live only inside one scan
    step. Returns (sum_loss, n_valid). labels == -1 are masked.
    """
    B, S, d = x.shape
    chunk = px.pcfg.logits_chunk
    V = head_w.shape[-1]

    def chunk_loss(xc, lc):
        logits = jnp.einsum("btd,dv->btv", xc, head_w.astype(xc.dtype),
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"), px)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None],
                                 axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((logz - ll) * mask), jnp.sum(mask)

    if chunk and S > chunk and S % chunk == 0:
        n = S // chunk
        xs = x.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
        ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)

        def body(carry, inp):
            tot, cnt = carry
            s, c = chunk_loss(*inp)
            return (tot + s, cnt + c), None

        (tot, cnt), _ = lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xs, ls))
        return tot, cnt
    return chunk_loss(x, labels)


def loss_fn(params: Tree, batch: Tree, *, cfg: ArchConfig, px: ShardCtx) -> Tuple[jax.Array, Tree]:
    if cfg.frontend == "embeddings":
        embeds = batch["frame_embeddings"]
        labels = batch["labels"]
        tokens = None
        B, S = labels.shape
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((B, 1), -1, tokens.dtype)], axis=1)
        embeds = None
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    cond = batch.get("cond")
    x, _, aux = M.forward(params, cfg=cfg, px=px, mode="train", tokens=tokens,
                          embeds=embeds, cond=cond, positions=positions, cache=None)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = params["lm_head"]["w"] if "lm_head" in params else params["embed"]["table"].T
    tot, cnt = chunked_xent(x, head, labels, px)
    xent = tot / jnp.maximum(cnt, 1.0)
    loss = xent + aux
    return loss, {"xent": xent, "aux": aux, "n_tokens": cnt}


# ---------------------------------------------------------------------------
# steps


def make_train_step(cfg: ArchConfig, px: ShardCtx, optimizer):
    """Returns train_step(params, opt_state, batch, step) -> (params, opt_state, metrics)."""
    mb = px.pcfg.microbatches

    def grads_of(params, batch):
        (loss, met), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg=cfg, px=px), has_aux=True)(params)
        return loss, met, grads

    def train_step(params, opt_state, batch, step):
        if mb > 1:
            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
            mbatch = jax.tree.map(split, batch)

            def body(carry, b):
                acc, loss_acc = carry
                loss, met, grads = grads_of(params, b)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / mb, acc, grads)
                return (acc, loss_acc + loss / mb), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = lax.scan(body, (zeros, jnp.zeros(())), mbatch)
            met = {}
        else:
            loss, met, grads = grads_of(params, batch)
        new_params, new_opt, opt_met = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, **met, **opt_met, "step": step}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, px: ShardCtx, cache_cap: int):
    """prefill_step(params, batch) -> (last_token_logits, cache)."""

    def prefill_step(params, batch):
        if cfg.frontend == "embeddings":
            embeds = batch["frame_embeddings"]
            tokens = None
            B, S = embeds.shape[:2]
        else:
            tokens = batch["tokens"]
            embeds = None
            B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
        cache = M.init_cache(cfg, B, cache_cap)
        x, new_cache, _ = M.forward(params, cfg=cfg, px=px, mode="prefill",
                                    tokens=tokens, embeds=embeds,
                                    cond=batch.get("cond"), positions=positions,
                                    cache=cache)
        logits = M.output_head(params, cfg, x[:, -1:, :])[:, 0]
        return logits, new_cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, px: ShardCtx):
    """decode_step(params, cache, batch, pos) -> (logits (B,V), cache).

    ``pos`` is the (scalar int32) position of the incoming token; the KV cache
    holds positions < pos.
    """

    def decode_step(params, cache, batch, pos):
        if cfg.frontend == "embeddings":
            embeds = batch["frame_embeddings"]
            tokens = None
            B = embeds.shape[0]
        else:
            tokens = batch["tokens"]
            embeds = None
            B = tokens.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        x, new_cache, _ = M.forward(params, cfg=cfg, px=px, mode="decode",
                                    tokens=tokens, embeds=embeds, cond=None,
                                    positions=positions, cache=cache)
        logits = M.output_head(params, cfg, x)[:, 0]
        return logits, new_cache

    return decode_step
