"""Composable block-pattern decoder: forward pass + cache management.

The model is a sequence of *segments* (homogeneous layer cycles). Each segment
is executed with one ``lax.scan`` over its stacked parameters (and stacked
cache in inference modes), keeping compile time O(distinct layer kinds), not
O(depth) — essential for 61-layer MoE models lowered against 512 devices.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.arch import ArchConfig
from repro.models import layers as L
from repro.models.params import ParamSpec, is_spec, _stack_spec
from repro.parallel.sharding import ShardCtx, constrain

Tree = Dict[str, Any]


# ---------------------------------------------------------------------------
# cache specs


def _cache_layer_specs(cfg: ArchConfig, kind: str, batch: int, cap: int) -> Tree:
    dt = cfg.dtype
    if kind in ("attn", "attn_dense"):
        if cfg.attention == "mla":
            m = cfg.mla
            t: Tree = {
                "c_kv": ParamSpec((batch, cap, m.kv_lora_rank),
                                  ("act_batch", "act_cache_seq", None), init="zeros", dtype=dt),
                "k_rope": ParamSpec((batch, cap, m.qk_rope_head_dim),
                                    ("act_batch", "act_cache_seq", None), init="zeros", dtype=dt),
                "pos": ParamSpec((batch, cap), ("act_batch", "act_cache_seq"),
                                 init="neg_ones", dtype="int32"),
            }
        else:
            c = min(cap, cfg.local_window) if cfg.local_window else cap
            kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            t = {
                "k": ParamSpec((batch, c, kv, hd),
                               ("act_batch", "act_cache_seq", "act_kv_heads", None),
                               init="zeros", dtype=dt),
                "v": ParamSpec((batch, c, kv, hd),
                               ("act_batch", "act_cache_seq", "act_kv_heads", None),
                               init="zeros", dtype=dt),
                "pos": ParamSpec((batch, c), ("act_batch", "act_cache_seq"),
                                 init="neg_ones", dtype="int32"),
            }
        if cfg.cross_attention:
            kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            t["cross_k"] = ParamSpec((batch, cfg.cross_seq, kv, hd),
                                     ("act_batch", None, "act_kv_heads", None),
                                     init="zeros", dtype=dt)
            t["cross_v"] = ParamSpec((batch, cfg.cross_seq, kv, hd),
                                     ("act_batch", None, "act_kv_heads", None),
                                     init="zeros", dtype=dt)
        return t
    if kind == "rglru":
        r = cfg.rglru
        width = r.lru_width or cfg.d_model
        return {
            "conv": ParamSpec((batch, r.conv_width - 1, width),
                              ("act_batch", None, "act_mlp"), init="zeros", dtype=dt),
            "h": ParamSpec((batch, width), ("act_batch", "act_mlp"),
                           init="zeros", dtype="float32"),
        }
    if kind == "mlstm":
        x = cfg.xlstm
        inner = int(x.mlstm_proj_factor * cfg.d_model)
        nh = x.num_heads
        dv = inner // nh
        dqk = int(x.qk_dim_factor * dv)
        return {
            "c": ParamSpec((batch, nh, dqk, dv), ("act_batch", "act_heads", None, None),
                           init="zeros", dtype="float32"),
            "n": ParamSpec((batch, nh, dqk), ("act_batch", "act_heads", None),
                           init="zeros", dtype="float32"),
            "m": ParamSpec((batch, nh), ("act_batch", "act_heads"),
                           init="zeros", dtype="float32"),
            "conv": ParamSpec((batch, 3, inner), ("act_batch", None, "act_mlp"),
                              init="zeros", dtype=dt),
        }
    if kind == "slstm":
        x = cfg.xlstm
        nh = x.num_heads
        dh = cfg.d_model // nh
        mk = lambda init: ParamSpec((batch, nh, dh), ("act_batch", "act_heads", None),
                                    init=init, dtype="float32")
        return {"c": mk("zeros"), "n": mk("ones"), "h": mk("zeros"), "m": mk("zeros")}
    raise ValueError(kind)


def cache_specs(cfg: ArchConfig, batch: int, cap: int) -> Tree:
    segs = []
    for (n_rep, cycle) in cfg.pattern_layers():
        cyc: Tree = {}
        for j, kind in enumerate(cycle):
            layer = _cache_layer_specs(cfg, kind, batch, cap)
            cyc[f"{j}:{kind}"] = jax.tree.map(lambda s: _stack_spec(s, n_rep), layer,
                                              is_leaf=is_spec)
        segs.append(cyc)
    return {"segments": segs}


def abstract_cache(cfg: ArchConfig, batch: int, cap: int,
                   shardings: Optional[Tree] = None) -> Tree:
    specs = cache_specs(cfg, batch, cap)

    def mk(spec: ParamSpec, sh=None):
        dt = jnp.dtype(spec.dtype or cfg.dtype)
        if sh is not None:
            return jax.ShapeDtypeStruct(spec.shape, dt, sharding=sh)
        return jax.ShapeDtypeStruct(spec.shape, dt)

    if shardings is None:
        return jax.tree.map(mk, specs, is_leaf=is_spec)
    return jax.tree.map(mk, specs, shardings, is_leaf=is_spec)


def init_cache(cfg: ArchConfig, batch: int, cap: int) -> Tree:
    def one(spec: ParamSpec):
        dt = jnp.dtype(spec.dtype or cfg.dtype)
        if spec.init == "neg_ones":
            return jnp.full(spec.shape, -1, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        return jnp.zeros(spec.shape, dt)

    return jax.tree.map(one, cache_specs(cfg, batch, cap), is_leaf=is_spec)


# ---------------------------------------------------------------------------
# forward


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _apply_layer(kind: str, p: Tree, x: jax.Array, *, cfg: ArchConfig,
                 px: ShardCtx, mode: str, cache, positions, cond):
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    if kind in ("attn", "attn_dense"):
        h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        if cfg.attention == "mla":
            a_cache = {k: cache[k] for k in ("c_kv", "k_rope", "pos")} if cache else None
            a_out, a_cache = L.mla_attention(p["attn"], h, cfg=cfg, px=px, mode=mode,
                                             cache=a_cache, positions=positions)
        else:
            a_cache = {k: cache[k] for k in ("k", "v", "pos")} if cache else None
            a_out, a_cache = L.gqa_attention(p["attn"], h, cfg=cfg, px=px, mode=mode,
                                             cache=a_cache, positions=positions,
                                             window=cfg.local_window if kind == "attn"
                                             and cfg.block_pattern != ("attn",) else None)
        x = x + a_out
        if cache is not None:
            new_cache = dict(cache)
            new_cache.update(a_cache)
        if cfg.cross_attention:
            hc = L.rms_norm(x, p["ln_cross"]["scale"], cfg.norm_eps)
            if mode == "decode":
                ckv = (cache["cross_k"], cache["cross_v"])
            else:
                ckv = L.cond_kv(p["cross"], cond, cfg=cfg)
                if cache is not None:
                    new_cache["cross_k"], new_cache["cross_v"] = ckv
            x = x + L.cross_attention(p["cross"], hc, ckv, cfg=cfg, px=px)
        h2 = L.rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        if "moe" in p:
            m_out, aux = L.moe_block(p["moe"], h2, cfg=cfg, px=px)
        else:
            m_out = L.mlp(p["mlp"], h2, cfg, px)
        x = x + m_out
    elif kind == "rglru":
        h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        r_out, new_cache = L.rglru_block(p["rec"], h, cfg=cfg, px=px, mode=mode,
                                         cache=cache)
        x = x + r_out
        h2 = L.rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h2, cfg, px)
    elif kind == "mlstm":
        h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        m_out, new_cache = L.mlstm_block(p["mlstm"], h, cfg=cfg, px=px, mode=mode,
                                         cache=cache)
        x = x + m_out
    elif kind == "slstm":
        h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        s_out, s_cache = L.slstm_block(p["slstm"], h, cfg=cfg, px=px, mode=mode,
                                       cache=cache)
        x = x + s_out
        h2 = L.rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        x = x + L.mlp(p["ffn"], h2, cfg, px)
        new_cache = s_cache
    else:
        raise ValueError(kind)
    return x, new_cache, aux


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)  # "full": recompute everything


def forward(params: Tree, *, cfg: ArchConfig, px: ShardCtx, mode: str,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            cond: Optional[jax.Array] = None,
            positions: jax.Array,
            cache: Optional[Tree] = None) -> Tuple[jax.Array, Optional[Tree], jax.Array]:
    """Returns (hidden (B,S,d) pre-final-norm, new_cache, aux_loss)."""
    if cfg.frontend == "embeddings":
        assert embeds is not None
        x = embeds + _sinusoidal(positions, cfg.d_model).astype(embeds.dtype)
    else:
        x = params["embed"]["table"][tokens]
        if cfg.scale_embeddings:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"), px)

    aux_total = jnp.zeros((), jnp.float32)
    new_cache_segs = []
    segs = cfg.pattern_layers()
    for si, (n_rep, cycle) in enumerate(segs):
        seg_params = params["segments"][si]
        seg_cache = cache["segments"][si] if cache is not None else None

        def cycle_fn(x, cyc_params, cyc_cache):
            aux = jnp.zeros((), jnp.float32)
            new_cc: Tree = {}
            for j, kind in enumerate(cycle):
                key = f"{j}:{kind}"
                lc = cyc_cache[key] if cyc_cache is not None else None
                x, nlc, a = _apply_layer(kind, cyc_params[key], x, cfg=cfg, px=px,
                                         mode=mode, cache=lc, positions=positions,
                                         cond=cond)
                new_cc[key] = nlc
                aux = aux + a
            return x, (new_cc if cyc_cache is not None else None), aux

        if px.pcfg.scan_layers and n_rep > 1:
            if seg_cache is not None:
                def body(carry, xs):
                    xx, aux = carry
                    cp, cc = xs
                    xx, ncc, a = _remat_wrap(
                        lambda x_, p_, c_: cycle_fn(x_, p_, c_),
                        px.pcfg.remat if mode == "train" else "none")(xx, cp, cc)
                    return (xx, aux + a), ncc
                (x, aux), new_seg_cache = lax.scan(body, (x, aux_total),
                                                   (seg_params, seg_cache))
                aux_total = aux
            else:
                def body(carry, cp):
                    xx, aux = carry
                    xx, _, a = _remat_wrap(
                        lambda x_, p_: cycle_fn(x_, p_, None),
                        px.pcfg.remat if mode == "train" else "none")(xx, cp)
                    return (xx, aux + a), None
                (x, aux_total), _ = lax.scan(body, (x, aux_total), seg_params)
                new_seg_cache = None
        else:
            # unrolled: index the stacked leaves layer by layer
            new_stack = [] if seg_cache is not None else None
            for i in range(n_rep):
                cp = jax.tree.map(lambda a: a[i], seg_params)
                cc = (jax.tree.map(lambda a: a[i], seg_cache)
                      if seg_cache is not None else None)
                fn = _remat_wrap(lambda x_, p_, c_=cc: cycle_fn(x_, p_, c_),
                                 px.pcfg.remat if mode == "train" else "none")
                x, ncc, a = fn(x, cp)
                aux_total = aux_total + a
                if new_stack is not None:
                    new_stack.append(ncc)
            new_seg_cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_stack)
                             if new_stack else None)
        new_cache_segs.append(new_seg_cache)

    new_cache = {"segments": new_cache_segs} if cache is not None else None
    return x, new_cache, aux_total


def output_head(params: Tree, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Final norm + logits projection. x (B,S,d) -> (B,S,V) fp32."""
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if "lm_head" in params:
        w = params["lm_head"]["w"]
    else:
        w = params["embed"]["table"].T
    return (x @ w.astype(x.dtype)).astype(jnp.float32)
