"""TPU v5e roofline model (targets; this container only compiles).

Terms per the assignment:
  compute    = HLO_FLOPs      / (chips * PEAK_FLOPS)
  memory     = HLO_bytes      / (chips * HBM_BW)
  collective = collective_B   / (chips * ICI_BW)   (DCN portion / DCN_BW)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link
DCN_BW = 6.25e9         # ~50 Gbit/s per host NIC (documented assumption)
VMEM_BYTES = 16 * 2**20  # ~16 MiB usable more-or-less per core
HBM_BYTES = 16 * 2**30   # v5e HBM capacity


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    dcn_bytes: float
    chips: int
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        ici = (self.coll_bytes - self.dcn_bytes) / (self.chips * ICI_BW)
        dcn = self.dcn_bytes / (self.chips * DCN_BW)
        return ici + dcn

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound: max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if self.model_flops and self.flops:
            return self.model_flops / self.flops
        return None

    @property
    def roofline_fraction(self) -> Optional[float]:
        """MODEL_FLOPS-based MFU bound at the roofline step time."""
        if not self.model_flops:
            return None
        t = self.step_time
        if t <= 0:
            return None
        return self.model_flops / (t * self.chips * PEAK_FLOPS)

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "dcn_bytes": self.dcn_bytes,
            "chips": self.chips, "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "step_time": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) for train; 2·N·D for inference."""
    n = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens
