import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and only the dry-run) builds the 512-chip production meshes
# out of placeholder host devices; smoke tests / benches see 1 device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent (sharding
propagation succeeds, the collectives exist, memory fits) and extracts the
roofline terms (EXPERIMENTS.md §Dry-run / §Roofline):
  * compiled.memory_analysis()  — bytes/device
  * compiled.cost_analysis()    — HLO FLOPs / bytes
  * HLO text                    — collective bytes (repro.launch.hlo)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k \
      --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.arch import SHAPES_BY_NAME, shape_applicable
from repro.configs.registry import ARCHS, get_arch
from repro.launch import hlo as hlo_mod
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, model_flops_for
from repro.launch.specs import input_specs
from repro.models.stepfn import make_decode_step, make_prefill_step, make_train_step
from repro.optim.optimizers import AdamW, constant_lr
from repro.parallel.sharding import ParallelConfig, ShardCtx


def _pcfg_from_args(args) -> ParallelConfig:
    kw = {}
    if args.remat:
        kw["remat"] = args.remat
    if args.q_chunks:
        kw["attn_q_chunks"] = args.q_chunks
    if args.microbatches:
        kw["microbatches"] = args.microbatches
    if args.capacity_factor:
        kw["capacity_factor"] = args.capacity_factor
    if args.logits_chunk is not None:
        kw["logits_chunk"] = args.logits_chunk
    if args.attn_block_kv:
        kw["attn_block_kv"] = args.attn_block_kv
    if getattr(args, "opt_moment_dtype", None):
        kw["opt_moment_dtype"] = args.opt_moment_dtype
    if getattr(args, "no_flash", False):
        kw["flash_threshold"] = 1 << 30
    if getattr(args, "mlstm_chunk", None):
        kw["mlstm_chunk"] = args.mlstm_chunk
    if getattr(args, "mlstm_bf16", False):
        kw["mlstm_bf16_streams"] = True
    if getattr(args, "moe_combine", None):
        kw["moe_combine"] = args.moe_combine
    if getattr(args, "attn_block_q", None):
        kw["attn_block_q"] = args.attn_block_q
    if getattr(args, "grad_compression", None):
        kw["grad_compression"] = args.grad_compression
    if getattr(args, "grad_compression_topk", None):
        kw["grad_compression_topk"] = args.grad_compression_topk
    if args.rules:
        # "act_cache_seq=model,embed=None" style overrides
        pr = dict(ParallelConfig().param_rules)
        ar = dict(ParallelConfig().act_rules)
        for item in args.rules.split(","):
            k, v = item.split("=")
            tgt = None if v in ("None", "none", "") else (tuple(v.split("+")) if "+" in v else v)
            (ar if k.startswith("act_") else pr)[k] = tgt
        kw["param_rules"] = pr
        kw["act_rules"] = ar
    return ParallelConfig(**kw)


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             pcfg: ParallelConfig | None = None, save_hlo: str | None = None) -> dict:
    """Lower+compile one cell; returns the §Dry-run record."""
    cfg = get_arch(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
                "status": "skip", "reason": why}

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size
    pcfg = pcfg or ParallelConfig()
    px = ShardCtx(mesh=mesh, pcfg=pcfg)
    t0 = time.time()
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
           "chips": int(chips), "pcfg": {k: str(v) for k, v in
                                         dataclasses.asdict(pcfg).items()}}
    try:
        if shape.kind == "train":
            opt = AdamW(schedule=constant_lr(1e-4), moment_dtype=pcfg.opt_moment_dtype)
            step_fn = make_train_step(cfg, px, opt)
            specs = input_specs(cfg, shape, mesh, pcfg, optimizer=opt)
            jfn = jax.jit(step_fn, donate_argnums=(0, 1))
            lowered = jfn.lower(specs["params"], specs["opt_state"],
                                specs["batch"], specs["step"])
        elif shape.kind == "prefill":
            step_fn = make_prefill_step(cfg, px, cache_cap=shape.seq_len)
            specs = input_specs(cfg, shape, mesh, pcfg)
            jfn = jax.jit(step_fn)
            lowered = jfn.lower(specs["params"], specs["batch"])
        else:
            step_fn = make_decode_step(cfg, px)
            specs = input_specs(cfg, shape, mesh, pcfg)
            jfn = jax.jit(step_fn, donate_argnums=(1,))
            lowered = jfn.lower(specs["params"], specs["cache"],
                                specs["batch"], specs["pos"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # jax<=0.4.x wraps it in a list
            cost = cost[0] if cost else {}
        hlo_text = compiled.as_text()
        if save_hlo:
            Path(save_hlo).write_text(hlo_text)

        # Trip-count-aware analysis (XLA's cost_analysis counts scan bodies
        # once — see hlo_cost.py); everything is per-device → ×chips = global.
        ana = hlo_cost.analyze(hlo_text, dcn_stride=256 if multi else None)
        mf = model_flops_for(cfg, shape)
        roof = Roofline(flops=ana["flops"] * chips, hbm_bytes=ana["bytes"] * chips,
                        coll_bytes=ana["coll_bytes"] * chips,
                        dcn_bytes=ana["dcn_bytes"] * chips,
                        chips=chips, model_flops=mf)
        mem_attrs = {}
        for a in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "temp_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
            v = getattr(mem, a, None)
            if v is not None:
                mem_attrs[a] = int(v)
        rec.update({
            "status": "ok",
            "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
            "memory": mem_attrs,
            "xla_cost_analysis": {k: float(v) for k, v in cost.items()
                                  if isinstance(v, (int, float)) and "{" not in k},
            "coll_by_kind": ana["coll_by_kind"],
            "top_scopes": ana["top_scopes"],
            "top_bytes_scopes": ana["top_bytes_scopes"],
            "roofline": roof.to_dict(),
            "hlo_bytes_len": len(hlo_text),
            "while_trip_counts": hlo_mod.count_while_trip_counts(hlo_text)[:8],
        })
        print(f"[dryrun] {arch_name} × {shape_name} × {mesh_kind}: OK "
              f"compile={t_compile:.1f}s dominant={roof.dominant} "
              f"t=({roof.t_compute:.4f},{roof.t_memory:.4f},{roof.t_collective:.4f})s")
    except Exception as e:  # noqa: BLE001 — a failing cell is a *finding*
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[dryrun] {arch_name} × {shape_name} × {mesh_kind}: "
              f"FAIL {type(e).__name__}: {e}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--q-chunks", dest="q_chunks", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--capacity-factor", dest="capacity_factor", type=float, default=None)
    ap.add_argument("--logits-chunk", dest="logits_chunk", type=int, default=None)
    ap.add_argument("--attn-block-kv", dest="attn_block_kv", type=int, default=None)
    ap.add_argument("--opt-moment-dtype", dest="opt_moment_dtype", default=None)
    ap.add_argument("--no-flash", dest="no_flash", action="store_true")
    ap.add_argument("--mlstm-chunk", dest="mlstm_chunk", type=int, default=None)
    ap.add_argument("--mlstm-bf16", dest="mlstm_bf16", action="store_true")
    ap.add_argument("--moe-combine", dest="moe_combine", default=None,
                    choices=["gather", "a2a"])
    ap.add_argument("--attn-block-q", dest="attn_block_q", type=int,
                    default=None)
    ap.add_argument("--grad-compression", dest="grad_compression",
                    default=None, choices=["none", "topk", "int8"])
    ap.add_argument("--grad-compression-topk", dest="grad_compression_topk",
                    type=float, default=None)
    ap.add_argument("--rules", default=None, help="logical=mesh overrides, comma-sep")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    pcfg = _pcfg_from_args(args)
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES_BY_NAME) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    for a, s, m in cells:
        fname = outdir / f"{args.tag}__{a}__{s}__{m}.json"
        rec = run_cell(a, s, m, pcfg, save_hlo=args.save_hlo)
        fname.write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
