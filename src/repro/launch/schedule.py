"""Scheduled-job producer: submit tuning jobs into the durable queue on a
fixed interval.

    PYTHONPATH=src python -m repro.launch.schedule --store results/tune_store \
        --job "dryrun[moe×decode×v5e-8]:scheduled_retune:3600" \
        --job "kernel[gemm×4096x4096x4096×v5e]:bench_sweep:86400:80" \
        [--once] [--poll-every 5]

The third leg of the fleet control plane (DESIGN.md §13): servers submit
drift-triggered jobs, ``repro.launch.retune`` daemons claim and service
them — this process is the *cron* half, submitting ``scheduled_retune`` /
``bench_sweep`` jobs for configured keys every ``every_s`` seconds so cells
re-tune and bench curves refresh even when nothing drifts. The queue and
the daemons already speak these job types; this is one loop over
``TuningJobQueue.submit``.

Idempotence falls out of the queue's own semantics, not producer state:
``submit`` refuses a key that already has an open job (commit-then-check
group coalescing), so a restarted producer — or N producers racing on the
same store — cannot stack duplicates, and an interval shorter than the
fleet's service latency degrades to "submit as soon as the previous run
finishes". The in-memory ``_last`` stamp only spaces *successful* submits;
it deliberately does not persist (a restart submitting one interval early
is harmless for the same reason).

Job specs are ``key:job_type:every_s[:budget]`` — the key must be one the
retune daemons can resolve to an objective (``dryrun[...]``,
``kernel[...]``), ``job_type`` ∈ JOB_TYPES, ``every_s`` the submit period
in seconds, and the optional ``budget`` overrides the servicing daemon's
default unique-eval budget for this job.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.store.queue import JOB_TYPES, TuningJobQueue
from repro.store.records import TuningRecordStore


@dataclass(frozen=True)
class JobSpec:
    """One scheduled submission: ``key`` every ``every_s`` seconds."""

    key: str
    job_type: str = "scheduled_retune"
    every_s: float = 3600.0
    budget: Optional[int] = None

    @classmethod
    def parse(cls, text: str) -> "JobSpec":
        """``key:job_type:every_s[:budget]`` — cell keys (``dryrun[...]``,
        ``kernel[...]``) never contain ``:``."""
        parts = text.split(":")
        if len(parts) == 4:
            key, job_type, every, budget = parts
            spec = cls(key, job_type, float(every), int(budget))
        elif len(parts) == 3:
            key, job_type, every = parts
            spec = cls(key, job_type, float(every))
        else:
            raise ValueError(
                f"job spec {text!r}: want key:job_type:every_s[:budget]")
        if spec.job_type not in JOB_TYPES:
            raise ValueError(f"job spec {text!r}: job_type must be one of "
                             f"{JOB_TYPES}")
        if spec.every_s <= 0:
            raise ValueError(f"job spec {text!r}: every_s must be > 0")
        return spec


class _ScheduledReq:
    """The submit payload: anything with the RetuneRequest fields."""

    def __init__(self, key: str, t: float):
        self.key = key
        self.objective = f"{key}@scheduled"
        self.observed = float("nan")
        self.predicted = float("nan")
        self.reason = "scheduled"
        self.t = t


class ScheduleProducer:
    """Submit each spec's job whenever its interval has elapsed since the
    last ACCEPTED submit. All durable state is the queue itself."""

    def __init__(self, store_path: str, specs: Sequence[JobSpec], *,
                 worker: Optional[str] = None, clock=time.time,
                 store=None, verbose: bool = False):
        self.specs = list(specs)
        self.clock = clock
        self.verbose = verbose
        self._owns_store = store is None
        self.store = (store if store is not None
                      else TuningRecordStore(store_path, load=False))
        self.queue = TuningJobQueue(store_path, worker=worker,
                                    clock=clock, appender=self.store)
        #: per-spec time of the last accepted submit (None = never: every
        #: spec fires on the first step, then spaces by its interval)
        self._last: Dict[JobSpec, Optional[float]] = {
            s: None for s in self.specs}
        self.submitted = 0
        #: submits the queue refused (an open job already holds the key —
        #: the fleet is still servicing the previous interval's run)
        self.coalesced = 0

    def step(self, now: Optional[float] = None) -> int:
        """Submit every spec whose interval has elapsed; returns how many
        submissions the queue ACCEPTED this step."""
        now = float(self.clock() if now is None else now)
        accepted = 0
        for spec in self.specs:
            last = self._last[spec]
            if last is not None and now - last < spec.every_s:
                continue
            ok = self.queue.submit(_ScheduledReq(spec.key, now),
                                   job_type=spec.job_type,
                                   budget=spec.budget)
            if ok:
                self._last[spec] = now
                self.submitted += 1
                accepted += 1
                if self.verbose:
                    print(f"[schedule] submitted {spec.job_type} for "
                          f"{spec.key} (every {spec.every_s:g}s)")
            else:
                self.coalesced += 1
                if self.verbose:
                    print(f"[schedule] {spec.key} already has an open job; "
                          "coalesced")
        return accepted

    def run(self, *, poll_every_s: float = 5.0,
            max_steps: Optional[int] = None) -> int:
        """Loop ``step`` until ``max_steps`` (None = forever); returns the
        total number of accepted submissions."""
        steps = 0
        while max_steps is None or steps < max_steps:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
            time.sleep(poll_every_s)
        return self.submitted

    def close(self) -> None:
        if self._owns_store:
            self.store.close()


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", required=True,
                    help="shared tuning-record store (directory) holding "
                         "the durable job queue")
    ap.add_argument("--job", action="append", required=True,
                    metavar="KEY:TYPE:EVERY_S[:BUDGET]",
                    help="scheduled job spec; repeatable. TYPE is usually "
                         "scheduled_retune or bench_sweep")
    ap.add_argument("--once", action="store_true",
                    help="run one submission pass and exit")
    ap.add_argument("--poll-every", type=float, default=5.0,
                    help="seconds between interval checks")
    ap.add_argument("--worker", default=None,
                    help="producer name stamped into submit records")
    args = ap.parse_args(argv)
    specs = [JobSpec.parse(s) for s in args.job]
    prod = ScheduleProducer(args.store, specs, worker=args.worker,
                            verbose=True)
    try:
        if args.once:
            n = prod.step()
            print(f"[schedule] one pass: {n} job(s) submitted, "
                  f"{prod.coalesced} coalesced")
        else:
            prod.run(poll_every_s=args.poll_every)
    finally:
        prod.close()


if __name__ == "__main__":
    main()
