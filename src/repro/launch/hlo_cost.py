"""Trip-count-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any model
that scans over layers (every serious model here) is undercounted by ~depth×,
and collectives inside the scan are likewise undercounted. This module parses
the post-optimization HLO text and computes
    flops, memory bytes, collective bytes (ICI + DCN split)
compositionally: fusions recurse into their called computation for FLOPs but
count one kernel's worth of memory traffic; ``while`` multiplies body+cond by
``known_trip_count``; collectives sum *operand* bytes times their trip factor.

Also produces a by-op_name attribution (top FLOPs contributors) used by the
§Perf hillclimbing loop.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?"?n"?\s*[:=]\s*"?(\d+)"?')
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_META_RE = re.compile(r'op_name="([^"]*)"')

_FREE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota"}


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    rtype: str
    op: str
    operands: List[str]
    line: str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    dcn_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.dcn_bytes += other.dcn_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult


def parse_instr(line: str) -> Optional[Tuple[str, str, str]]:
    """(name, result_type, op) — robust to tuple types with /*index=N*/ comments."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):         # tuple type: scan balanced parens
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        rtype = rest[:i + 1]
        rest = rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        rest = rest[sp:]
    om = re.match(r"\s+([\w\-]+)\(", rest)
    if not om:
        return None
    return name, rtype, om.group(1)


def _parse_operands(line: str, op: str) -> List[str]:
    i = line.find(op + "(")
    if i < 0:
        return []
    s = line[i + len(op):]
    depth = 0
    arg = ""
    for ch in s:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            arg += ch
    return re.findall(r"(%[\w.\-]+)", arg)


def _groups_span_dcn(line: str, dcn_stride: int) -> bool:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(t) for t in re.findall(r"\d+", grp)]
            if ids and (max(ids) // dcn_stride) != (min(ids) // dcn_stride):
                return True
        return False
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims)))
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.reshape(dims).transpose(perm).reshape(-1)
        groups = ids.reshape(g, s)
        pods = groups // dcn_stride
        return bool((pods.max(axis=1) != pods.min(axis=1)).any())
    return False


class HloCostModel:
    def __init__(self, hlo_text: str, dcn_stride: Optional[int] = None):
        self.dcn_stride = dcn_stride
        self.comps: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self.by_scope: Dict[str, float] = defaultdict(float)
        self.bytes_by_scope: Dict[str, float] = defaultdict(float)

    def _parse(self, text: str):
        cur: Optional[str] = None
        self.roots: Dict[str, Instr] = {}
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_RE.match(line.strip()) if line.strip().endswith("{") else None
                if m and "=" not in line.split("(")[0]:
                    cur = m.group(1)
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur
                    self.comps[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
            parsed = parse_instr(line)
            if parsed:
                name, rtype, op = parsed
                ins = Instr(name, rtype, op, _parse_operands(line, op), line)
                self.comps[cur].append(ins)
                if line.lstrip().startswith("ROOT"):
                    self.roots[cur] = ins

    # -- cost of one computation (memoized) --------------------------------
    def comp_cost(self, comp: str, scope_mult: float = 1.0) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        instrs = self.comps.get(comp, [])
        sizes = {i.name: shape_bytes(i.rtype) for i in instrs}
        total = Cost()
        for ins in instrs:
            c = self._instr_cost(ins, sizes)
            total.add(c)
        self._memo[comp] = total
        return total

    def _instr_cost(self, ins: Instr, sizes: Dict[str, int]) -> Cost:
        op = ins.op
        c = Cost()
        if op in _FREE_OPS:
            return c
        if op == "while":
            body = _BODY_RE.search(ins.line)
            cond = _COND_RE.search(ins.line)
            trip = 1
            tm = _TRIP_RE.search(ins.line)
            if tm:
                trip = int(tm.group(1))
            sub = Cost()
            if body:
                sub.add(self.comp_cost(body.group(1)))
            if cond:
                sub.add(self.comp_cost(cond.group(1)))
            c.add(sub, mult=trip)
            self._scope(ins, c.flops, c.bytes)
            return c
        if op == "fusion":
            callee = _CALLS_RE.search(ins.line)
            root_op = None
            touch: Dict[int, Optional[int]] = {}
            if callee:
                cname = callee.group(1)
                inner = self.comp_cost(cname)
                c.flops += inner.flops          # compute executes
                c.coll_bytes += inner.coll_bytes
                c.dcn_bytes += inner.dcn_bytes
                root = self.roots.get(cname)
                root_op = root.op if root else None
                touch = self._param_touch(cname)
            opnd = [sizes.get(o, 0) for o in ins.operands]
            res = shape_bytes(ins.rtype)
            # operand j consumed ONLY through dynamic-slice inside the callee
            # touches slice-sized windows, not the whole buffer (stacked
            # scan inputs / stacked layer weights)
            eff = []
            for j, b in enumerate(opnd):
                t = touch.get(j, None)
                eff.append(min(b, t) if t is not None else b)
            if root_op == "dynamic-update-slice" and opnd:
                # in-place loop-carried buffer update: the result aliases the
                # largest operand; traffic = small operands + update write
                big = max(eff) if eff else 0
                c.bytes += 2 * (sum(eff) - big)
            else:
                c.bytes += sum(eff) + res
            self._scope(ins, c.flops, c.bytes)
            return c
        if op in ("call", "async-start", "async-done"):
            callee = _CALLS_RE.search(ins.line) or re.search(r"to_apply=(%[\w.\-]+)", ins.line)
            if callee:
                c.add(self.comp_cost(callee.group(1)))
            return c
        if op == "conditional":
            branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"(?:true|false)_computation=(%[\w.\-]+))", ins.line)
            names: List[str] = []
            for a, b in branches:
                if a:
                    names += re.findall(r"(%[\w.\-]+)", a)
                if b:
                    names.append(b)
            if names:
                worst = max((self.comp_cost(n) for n in names),
                            key=lambda x: x.flops + x.bytes, default=Cost())
                c.add(worst)
            return c

        # In-place buffer ops: XLA updates loop-carried buffers in place, so
        # a dynamic-update-slice moves only the update slice (NOT the whole
        # stacked residual buffer — counting that is O(trip²) for scans), and
        # a dynamic-slice reads only the slice it produces.
        if op == "dynamic-update-slice":
            upd = sizes.get(ins.operands[1], 0) if len(ins.operands) > 1 else 0
            c.bytes += 2 * upd
            return c
        if op == "dynamic-slice":
            c.bytes += 2 * shape_bytes(ins.rtype)
            return c
        if op == "gather":
            # touched bytes ≈ gathered rows + indices, not the whole table
            idx = sizes.get(ins.operands[1], 0) if len(ins.operands) > 1 else 0
            c.bytes += 2 * shape_bytes(ins.rtype) + idx
            return c
        if op == "scatter":
            # in-place: read+write updates + indices; result aliases target
            small = sum(sizes.get(o, 0) for o in ins.operands[1:])
            c.bytes += 2 * small
            return c

        op_bytes = sum(sizes.get(o, 0) for o in ins.operands) + shape_bytes(ins.rtype)
        kind = next((k for k in COLLECTIVES
                     if op == k or op == k + "-start" or op == k + "-done"), None)
        if kind is not None:
            if op.endswith("-done"):
                return c
            in_bytes = sum(sizes.get(o, 0) for o in ins.operands) or shape_bytes(ins.rtype)
            c.bytes += op_bytes
            c.coll_bytes += in_bytes
            c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + in_bytes
            if self.dcn_stride and _groups_span_dcn(ins.line, self.dcn_stride):
                c.dcn_bytes += in_bytes
            return c
        c.bytes += op_bytes
        if op == "dot":
            contract = 1
            mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
            if mm and ins.operands:
                lhs_dims = self._operand_dims(ins.operands[0], sizes)
                for di in mm.group(1).split(","):
                    if di != "" and lhs_dims and int(di) < len(lhs_dims):
                        contract *= lhs_dims[int(di)]
            c.flops += 2.0 * shape_elems(ins.rtype) * contract
            self._scope(ins, c.flops)
            return c
        if op == "convolution":
            kd = self._operand_dims(ins.operands[1], sizes) if len(ins.operands) > 1 else []
            kelems = int(np.prod(kd)) if kd else 1
            out = shape_elems(ins.rtype)
            ofeat = kd[-1] if kd else 1
            c.flops += 2.0 * out * max(kelems // max(ofeat, 1), 1)
            self._scope(ins, c.flops)
            return c
        if op in ("reduce", "reduce-window"):
            c.flops += sum(sizes.get(o, 0) for o in ins.operands) / 4.0
            return c
        if op in ("custom-call",):
            # e.g. Pallas kernels / oneDNN matmul: FLOPs not inferable from
            # the call site — documented undercount (DESIGN.md §6).
            return c
        # default: elementwise-ish, 1 flop per output element
        c.flops += shape_elems(ins.rtype)
        return c

    _dims_cache: Dict[Tuple[str, int], List[int]] = {}

    def _operand_dims(self, name: str, sizes: Dict[str, int]) -> List[int]:
        # find the instruction line that defined `name` in any computation
        # (names are unique module-wide in optimized HLO)
        dims = self._dims_lookup.get(name)
        return dims or []

    @property
    def _dims_lookup(self) -> Dict[str, List[int]]:
        if not hasattr(self, "_dims_lookup_cache"):
            lut: Dict[str, List[int]] = {}
            for instrs in self.comps.values():
                for i in instrs:
                    lut[i.name] = _first_shape_dims(i.rtype)
            self._dims_lookup_cache = lut
        return self._dims_lookup_cache

    _touch_memo: Dict[str, Dict[int, Optional[int]]]

    def _param_touch(self, comp: str) -> Dict[int, Optional[int]]:
        """Per fusion-parameter: bytes actually touched, or None = all.

        A parameter whose only consumers are dynamic-slice ops is read
        slice-by-slice; its effective traffic is the sum of slice sizes.
        """
        if not hasattr(self, "_touch_memo_d"):
            self._touch_memo_d = {}
        if comp in self._touch_memo_d:
            return self._touch_memo_d[comp]
        out: Dict[int, Optional[int]] = {}
        instrs = self.comps.get(comp, [])
        params = []
        for i in instrs:
            if i.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", i.line)
                if m:
                    params.append((int(m.group(1)), i))
        for idx, p in params:
            consumers = [i for i in instrs if p.name in i.operands]
            if consumers and all(i.op == "dynamic-slice" and i.operands
                                 and i.operands[0] == p.name
                                 for i in consumers):
                out[idx] = sum(shape_bytes(i.rtype) for i in consumers)
            else:
                out[idx] = None
        self._touch_memo_d[comp] = out
        return out

    def _scope(self, ins: Instr, flops: float, byts: float = 0.0):
        m = _META_RE.search(ins.line)
        if m:
            parts = [p for p in m.group(1).split("/") if p and not p.startswith("jit(")]
            key = "/".join(parts[-3:]) if parts else "(root)"
        else:
            key = "(no-meta)"
        if flops > 0:
            self.by_scope[key] += flops
        if byts > 0:
            self.bytes_by_scope[key] += byts

    # -- public -------------------------------------------------------------
    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry)

    def top_scopes(self, n: int = 12) -> List[Tuple[str, float]]:
        return sorted(self.by_scope.items(), key=lambda kv: -kv[1])[:n]

    def top_bytes_scopes(self, n: int = 12) -> List[Tuple[str, float]]:
        return sorted(self.bytes_by_scope.items(), key=lambda kv: -kv[1])[:n]


def analyze(hlo_text: str, dcn_stride: Optional[int] = None) -> Dict:
    model = HloCostModel(hlo_text, dcn_stride=dcn_stride)
    t = model.total()
    return {
        "flops": t.flops, "bytes": t.bytes,
        "coll_bytes": t.coll_bytes, "dcn_bytes": t.dcn_bytes,
        "coll_by_kind": dict(t.coll_by_kind),
        "top_scopes": model.top_scopes(),
        "top_bytes_scopes": model.top_bytes_scopes(),
    }
