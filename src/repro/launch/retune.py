"""Tuning-fleet daemon: service durable tuning jobs from the shared store.

    PYTHONPATH=src python -m repro.launch.retune --store results/tune_store \
        [--once] [--budget 40] [--strategy ei] [--poll-every 30] \
        [--worker daemon-a]

The other half of the serve-side control plane (DESIGN.md §13): servers
running ``repro.launch.serve --online`` enqueue ``kind="job"`` control
records into the store when observed latency drifts off the stored roofline
— this process tails the same store, claims each open job exactly once
under a fenced lease (``TuningJobQueue.claim``), and services it with a
warm-started tuning run (``repro.core.engine.run_retune``) journaled back
into the store, which the serving fleet then hot-reloads. Submitters,
daemons, and servers share nothing but the store path: a request survives
the death of the process that raised it, and a daemon crash mid-run re-arms
after the claim TTL.

Run as MANY of these as you like against one store — claims are
exactly-once across the fleet (fencing tokens, ``repro.store.fence``), and
a daemon that pauses past its TTL finds its ``done`` refused
(``FencedClaimError``, counted in ``self.fenced``) instead of corrupting
the job its peer re-claimed. Every journaled record of a serviced run
carries the claim's token in ``meta["fence"]``, so hot-reload consumers
drop a fenced-out daemon's late observations too.

A cell key ``dryrun[arch×shape×mesh]`` maps back to its tuning problem by
parsing the id the resolver minted (``repro.store.resolve.cell_objective``);
``kernel[name×shape×device]`` keys (repro.kernels.tuning) map to in-process
kernel-tuning objectives the same way, so one daemon services both the
sharding and the kernel halves of a serving cell. Tests inject
``objective_for`` to service simulated cells instead.
"""
from __future__ import annotations

import argparse
import re
import time
from typing import Callable, Optional

from repro.core.engine import RetuneRequest, run_retune
from repro.store.fence import FencedClaimError
from repro.store.queue import TuningJobQueue
from repro.store.records import TuningRecordStore

_CELL_RE = re.compile(r"^dryrun\[(?P<arch>.+?)×(?P<shape>.+?)×(?P<mesh>.+?)\]$")
_KERNEL_RE = re.compile(
    r"^kernel\[(?P<name>.+?)×(?P<sig>.+?)×(?P<device>.+?)\]$")
#: shape-signature grammars of the kernel cell factories (kernels/tuning.py)
_GEMM_SIG = re.compile(r"^(?P<M>\d+)x(?P<N>\d+)x(?P<K>\d+)$")
_FLASH_SIG = re.compile(r"^B(?P<B>\d+)_S(?P<S>\d+)_H(?P<H>\d+)_hd(?P<hd>\d+)$")
_DECODE_SIG = re.compile(r"^B(?P<B>\d+)_S(?P<S>\d+)_H(?P<H>\d+)"
                         r"_KV(?P<KV>\d+)_hd(?P<hd>\d+)$")
_GP_SIG = re.compile(r"^N(?P<N>\d+)_T(?P<T>\d+)_d(?P<d>\d+)$")


def dryrun_objective_for(key: str):
    """The real tuning objective of a serving cell key — a dry-run compile
    objective over the cell's sharding space. Raises on keys this daemon
    does not know how to tune (a deliberate loud failure: an unserviceable
    request should page, not rot in the queue)."""
    m = _CELL_RE.match(key)
    if m is None:
        raise ValueError(f"unrecognized retune cell key {key!r} — expected "
                         "a dryrun[arch×shape×mesh] tuning objective id")
    from repro.core.tuning_targets import DryRunObjective
    return DryRunObjective(m.group("arch"), m.group("shape"),
                           m.group("mesh"))


def kernel_objective_for(key: str):
    """A ``kernel[name×shape×device]`` cell key back to its in-process
    tuning objective: the shape signature is the cell factory's own format,
    so the daemon reconstructs the exact cell the server resolved blocks
    for. Raises on malformed keys/signatures (same loud-failure policy as
    ``dryrun_objective_for``)."""
    m = _KERNEL_RE.match(key)
    if m is None:
        raise ValueError(f"unrecognized retune cell key {key!r} — expected "
                         "a kernel[name×shape×device] tuning objective id")
    from repro.kernels import tuning as KT
    name, sig, device = m.group("name"), m.group("sig"), m.group("device")
    if name == "gemm":
        sm = _GEMM_SIG.match(sig)
        if sm:
            cell = KT.gemm_cell(int(sm.group("M")), int(sm.group("N")),
                                int(sm.group("K")))
            return KT.KernelObjective(cell, device=device)
    elif name == "flash":
        sm = _FLASH_SIG.match(sig)
        if sm:
            cell = KT.flash_cell(int(sm.group("B")), int(sm.group("S")),
                                 int(sm.group("H")), int(sm.group("hd")))
            return KT.KernelObjective(cell, device=device)
    elif name == "decode":
        sm = _DECODE_SIG.match(sig)
        if sm:
            cell = KT.decode_cell(int(sm.group("B")), int(sm.group("S")),
                                  int(sm.group("H")), int(sm.group("KV")),
                                  int(sm.group("hd")))
            return KT.KernelObjective(cell, device=device)
    elif name == "gp":
        sm = _GP_SIG.match(sig)
        if sm:
            cell = KT.gp_cell(int(sm.group("N")), int(sm.group("T")),
                              int(sm.group("d")))
            return KT.KernelObjective(cell, device=device)
    raise ValueError(f"unrecognized kernel cell signature in {key!r}")


def cell_objective_for(key: str):
    """Dispatch a retune cell key to its tuning objective — sharding cells
    (``dryrun[...]``) and kernel cells (``kernel[...]``) through one
    daemon."""
    if key.startswith("kernel["):
        return kernel_objective_for(key)
    return dryrun_objective_for(key)


class RetuneDaemon:
    """Claim-and-service loop over a store's durable tuning-job queue —
    one worker of a fleet of N."""

    def __init__(self, store_path: str, *,
                 objective_for: Callable = cell_objective_for,
                 strategy_factory: Optional[Callable] = None,
                 budget: int = 40, seed: int = 0,
                 worker: Optional[str] = None, claim_ttl: float = 3600.0,
                 clock=time.time, verbose: bool = False, store=None,
                 quarantine_after: int = 0):
        if strategy_factory is None:
            from repro.core.strategies import make_strategy
            strategy_factory = lambda: make_strategy("ei")  # noqa: E731
        self.store_path = store_path
        self.objective_for = objective_for
        self.strategy_factory = strategy_factory
        self.budget = int(budget)
        self.seed = int(seed)
        self.clock = clock
        self.verbose = verbose
        # ONE store instance for everything this process appends (queue
        # claims/dones AND the retune runs' journals): compaction judges
        # "sealed" per pid, so a second live append segment would be at
        # risk of being folded under us. Lazy: O(hot set) open, and
        # re-snapshotted per serviced request so warm starts see the
        # latest telemetry. In-process fleet simulations pass ``store=``
        # so every simulated daemon shares the ONE live appender the
        # sealed-per-pid rule allows.
        self.store = (store if store is not None
                      else TuningRecordStore(store_path, lazy=True))
        self.queue = TuningJobQueue(store_path, worker=worker,
                                    claim_ttl=claim_ttl, clock=clock,
                                    appender=self.store,
                                    quarantine_after=quarantine_after)
        self.worker = self.queue.worker
        self.serviced = 0
        #: ``done`` attempts refused because this daemon's lease was
        #: superseded while it serviced (paused past claim_ttl)
        self.fenced = 0

    @property
    def quarantined(self) -> int:
        """Jobs this daemon's queue fold saw quarantined: groups that
        burned ``quarantine_after`` consecutive claimants and were closed
        terminally instead of re-arming forever."""
        return self.queue.quarantined

    def step(self):
        """Claim and service at most one job; returns the TuneResult, or
        None when nothing was claimable (or our lease was fenced out
        mid-service — the work is journaled, the job stays with the
        claimant that superseded us)."""
        ticket = self.queue.claim()
        if ticket is None:
            return None
        if self.verbose:
            print(f"[retune] {self.worker} claimed {ticket.id} "
                  f"({ticket.job_type}, token {ticket.token})")
        req = RetuneRequest(key=ticket.key, objective=ticket.objective,
                            observed=ticket.observed,
                            predicted=ticket.predicted,
                            reason=ticket.reason, t=ticket.t)
        self.store.refresh()           # warm-start from the latest records
        result = run_retune(req, self.objective_for(ticket.key),
                            self.strategy_factory(),
                            store=self.store,
                            budget=ticket.budget or self.budget,
                            seed=self.seed, job_type=ticket.job_type,
                            run_meta={"fence": {"key": ticket.key,
                                                "token": ticket.token}})
        try:
            self.queue.done(ticket)
        except FencedClaimError:
            self.fenced += 1
            if self.verbose:
                print(f"[retune] {self.worker} fenced out of {ticket.id}: "
                      "another daemon re-claimed it; done refused")
            return None
        self.serviced += 1
        if self.verbose:
            print(f"[retune] {self.worker} serviced {ticket.key}: best "
                  f"{result.best_value:.4g} in {result.unique_evals} "
                  "unique evals — journaled to the store")
        return result

    def run(self, *, poll_every_s: float = 30.0,
            max_requests: Optional[int] = None) -> int:
        """Service requests until ``max_requests`` (None = forever)."""
        while max_requests is None or self.serviced < max_requests:
            if self.step() is None:
                if max_requests is not None:
                    break
                time.sleep(poll_every_s)
        return self.serviced


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", required=True,
                    help="shared tuning-record store (directory) holding the "
                         "durable retune queue")
    ap.add_argument("--budget", type=int, default=40,
                    help="unique-evaluation budget per serviced request")
    ap.add_argument("--strategy", default="ei")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--once", action="store_true",
                    help="drain the currently open requests and exit")
    ap.add_argument("--poll-every", type=float, default=30.0,
                    help="seconds between queue polls when idle")
    ap.add_argument("--claim-ttl", type=float, default=3600.0,
                    help="seconds before an unfinished claim re-arms")
    ap.add_argument("--quarantine-after", type=int, default=5,
                    help="quarantine a job after this many consecutive "
                         "claimants die on it (terminal state instead of "
                         "re-arming forever; 0 disables)")
    ap.add_argument("--worker", default=None,
                    help="worker name in claim/done records (default: "
                         "proc-<pid>); name each daemon of a fleet")
    args = ap.parse_args()
    from repro.core.strategies import make_strategy
    daemon = RetuneDaemon(args.store,
                          strategy_factory=lambda: make_strategy(
                              args.strategy),
                          budget=args.budget, seed=args.seed,
                          worker=args.worker,
                          claim_ttl=args.claim_ttl,
                          quarantine_after=args.quarantine_after,
                          verbose=True)
    if args.once:
        n = daemon.run(max_requests=len(daemon.queue))
        print(f"[retune] drained: {n} request(s) serviced")
    else:
        daemon.run(poll_every_s=args.poll_every)


if __name__ == "__main__":
    main()
