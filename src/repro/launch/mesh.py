"""Production mesh construction.

A FUNCTION (never module-level) so importing this module never touches jax
device state. Single pod: v5e-256 as (data=16, model=16). Multi-pod: 2 pods
= 512 chips as (pod=2, data=16, model=16); the `pod` axis crosses DCN.
"""
from __future__ import annotations

import jax


import math


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            "(the dry-run launcher forces XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over locally available (or forced-host) devices, for tests."""
    axes, shape = [], []
    if pod > 1:
        axes.append("pod"); shape.append(pod)
    axes += ["data", "model"]
    shape += [data, model]
    n = math.prod(shape)
    import numpy as np
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), tuple(axes))
