"""ShapeDtypeStruct input stand-ins for every (arch × shape) dry-run cell.

No device allocation: shardings are attached to the structs so
``jax.jit(...).lower(**specs)`` sees the production layout.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.arch import ArchConfig, ShapeConfig
from repro.models.model import cache_specs
from repro.models.params import ParamSpec, abstract_params, is_spec, model_specs
from repro.parallel.sharding import ParallelConfig, param_shardings, resolve_spec


def _sds(shape, dtype, mesh: Optional[Mesh], logical, pcfg: ParallelConfig):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    spec = resolve_spec(shape, logical, pcfg.act_rules, mesh)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Optional[Mesh],
                pcfg: ParallelConfig) -> Dict[str, Any]:
    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend == "embeddings":
        out["frame_embeddings"] = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype), mesh,
                                       ("act_batch", "act_seq", "act_embed"), pcfg)
        if shape.kind == "train":
            out["labels"] = _sds((B, S), jnp.int32, mesh, ("act_batch", "act_seq"), pcfg)
        if cfg.cross_attention and shape.kind != "decode":
            out["cond"] = _sds((B, cfg.cross_seq, cfg.d_model), jnp.dtype(cfg.dtype),
                               mesh, ("act_batch", None, "act_embed"), pcfg)
    else:
        out["tokens"] = _sds((B, S), jnp.int32, mesh, ("act_batch", "act_seq"), pcfg)
    return out


def abstract_params_sharded(cfg: ArchConfig, mesh: Optional[Mesh], pcfg: ParallelConfig):
    if mesh is None:
        return abstract_params(cfg)
    sh = param_shardings(model_specs(cfg), mesh, pcfg)
    return abstract_params(cfg, sh)


def abstract_cache_sharded(cfg: ArchConfig, batch: int, cap: int,
                           mesh: Optional[Mesh], pcfg: ParallelConfig):
    specs = cache_specs(cfg, batch, cap)

    def mk(spec: ParamSpec):
        dt = jnp.dtype(spec.dtype or cfg.dtype)
        if mesh is None:
            return jax.ShapeDtypeStruct(spec.shape, dt)
        ps = resolve_spec(spec.shape, spec.logical, pcfg.act_rules, mesh)
        return jax.ShapeDtypeStruct(spec.shape, dt, sharding=NamedSharding(mesh, ps))

    return jax.tree.map(mk, specs, is_leaf=is_spec)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Optional[Mesh],
                pcfg: ParallelConfig, optimizer=None) -> Dict[str, Any]:
    """Everything the step function for this cell takes, as sharded structs."""
    params = abstract_params_sharded(cfg, mesh, pcfg)
    batch = batch_specs(cfg, shape, mesh, pcfg)
    if shape.kind == "train":
        assert optimizer is not None
        opt_state = optimizer.abstract_state(params)
        return {"params": params, "opt_state": opt_state, "batch": batch,
                "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if shape.kind == "prefill":
        return {"params": params, "batch": batch}
    cache = abstract_cache_sharded(cfg, shape.global_batch, shape.seq_len, mesh, pcfg)
    return {"params": params, "cache": cache, "batch": batch,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
