"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt

--smoke uses the reduced same-family config (CPU-runnable); the full configs
are for real accelerators (and are exercised via the dry-run here). The
~100M example model lives in examples/train_lm.py.
"""
from __future__ import annotations

import argparse

from repro.configs.registry import get_arch, smoke_config
from repro.data.pipeline import DataConfig
from repro.parallel.sharding import ParallelConfig
from repro.runtime.train import LoopConfig, TrainLoop, run_with_restarts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a failure (demonstrates restart)")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.global_batch, seed=args.seed)
    if cfg.frontend == "embeddings":
        raise SystemExit(f"{cfg.name} takes frontend embeddings; use "
                         "examples/train_lm.py for token-LM training demos")

    def make_loop(attempt: int) -> TrainLoop:
        lc = LoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir, seed=args.seed,
                        fail_at_step=args.fail_at_step if attempt == 0 else None,
                        peak_lr=args.peak_lr)
        return TrainLoop(cfg, data_cfg, lc)

    metrics = run_with_restarts(make_loop, max_restarts=args.max_restarts)
    print(f"[train] done: {len(metrics.losses)} steps this process, "
          f"final loss {metrics.losses[-1]:.4f}, "
          f"stragglers {metrics.straggler_events}, "
          f"restored_from={metrics.restored_from}")


if __name__ == "__main__":
    main()
