"""Serving launcher: batched prefill + decode over a smoke config.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --prompt-len 32 --decode-steps 16

Distribution/performance knobs come from the tuning-record store when one is
given (``--store``): the best prior tuning result for this (arch, shape,
mesh) cell overrides the built-in defaults, so serving inherits every past
tuning run's work. No record -> defaults, loudly.

``--online`` closes the loop (DESIGN.md §12): the server tail-follows the
store between decode steps and atomically swaps in a strictly better config
when one lands (no restart — params and KV cache survive, only the step
functions are re-derived), writes measured per-step latencies back as
``context="prod"`` records that warm-start future tuning runs, and submits
a durable re-tune request into the store when observed latency drifts off
the stored roofline prediction by ``--drift-factor`` (statistic selected by
``--drift-stat``) — serviced by a separate ``repro.launch.retune`` daemon
even after this server dies. ``--swap-margin`` adds hot-reload hysteresis:
improvements smaller than the re-jit cost are not worth a swap.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch, smoke_config
from repro.kernels.cache import CompiledKernelCache, config_key
from repro.models.params import init_params
from repro.models.stepfn import make_decode_step, make_prefill_step
from repro.parallel.sharding import ParallelConfig, ShardCtx
from repro.store import (DriftMonitor, HotConfigSource, OnlineServeLoop,
                         ProdRecorder, apply_kernel_config,
                         apply_sharding_config, best_sharding_config)


def resolve_pcfg(pcfg: ParallelConfig, store: str, arch: str, shape: str,
                 mesh: str = "single") -> ParallelConfig:
    """Best stored tuning config for this serving cell, else defaults."""
    hit = best_sharding_config(store, arch, shape, mesh=mesh)
    if hit is None:
        print(f"[serve] no tuning record for ({arch}, {shape}, {mesh}) in "
              f"{store} — using built-in defaults")
        return pcfg
    cfg, step_time = hit
    print(f"[serve] tuned config from store ({step_time:.3f}s roofline): "
          f"{cfg}")
    return apply_sharding_config(pcfg, cfg)


class DecodeServer:
    """Data plane of one serving process: params, KV cache, decode state,
    and jitted step functions derived from the current ParallelConfig.

    ``apply_config`` is the hot-reload point the online loop calls between
    decode batches: it overlays a stored tuning config and re-derives the
    step functions — params, cache, and generated tokens all survive, so a
    swap never costs a restart (only the first step's re-jit).
    ``apply_kernel_config`` is the same hot-reload point for tuned Pallas
    block configs (DESIGN.md §14); derived step-fn bundles are memoized in a
    ``CompiledKernelCache`` keyed by the tunable fields, so swapping BACK to
    a previously-deployed config is a cache hit — no re-jit at all.
    """

    def __init__(self, cfg, pcfg: ParallelConfig, *, batch: int,
                 prompt_len: int, decode_steps: int, seed: int = 0):
        self.cfg = cfg
        self.pcfg = pcfg
        self.prompt_len = prompt_len
        self.cache_cap = prompt_len + decode_steps
        self.key = jax.random.PRNGKey(seed)
        self.params = init_params(cfg, self.key)
        self.batch_size = batch
        self.cache = None
        self.toks = None
        self.out = []
        self.pos = 0
        self.swaps = 0
        self.kernel_swaps = 0
        self.kernel_cache = CompiledKernelCache()
        self._derive()

    def _stepfn_key(self):
        """Hashable identity of the derived step functions: every tunable
        ParallelConfig field a store record can overlay, plus the kernel
        block config. Rule tables are excluded — serving never hot-swaps
        them (they change the mesh, which IS a restart)."""
        p = self.pcfg
        kc = p.kernel
        kernel = (() if kc is None else
                  ("flash", kc.use_flash, kc.flash_block_q,
                   kc.flash_block_kv, "decode", kc.use_decode,
                   kc.decode_block_kv, kc.decode_num_splits,
                   kc.decode_combine, kc.interpret))
        return (p.remat, p.microbatches, p.attn_block_q, p.attn_block_kv,
                p.attn_q_chunks, p.capacity_factor, p.logits_chunk,
                p.opt_moment_dtype, p.scan_layers, p.flash_threshold,
                p.mlstm_chunk, p.mlstm_bf16_streams, p.moe_combine, kernel)

    def _derive(self) -> None:
        def build():
            px = ShardCtx(mesh=None, pcfg=self.pcfg)
            prefill = jax.jit(make_prefill_step(self.cfg, px,
                                                cache_cap=self.cache_cap))
            decode = jax.jit(make_decode_step(self.cfg, px))
            return prefill, decode
        self.prefill, self.decode = self.kernel_cache.get(self._stepfn_key(),
                                                          build)

    def apply_config(self, cfg_dict) -> None:
        self.pcfg = apply_sharding_config(self.pcfg, cfg_dict)
        self._derive()
        self.swaps += 1

    def apply_kernel_config(self, cfg_dict) -> None:
        """Hot-swap tuned Pallas kernel blocks between decode steps: params,
        KV cache, and generated tokens survive; only the step-fn bundle is
        re-derived (or re-used from the compiled-kernel cache)."""
        self.pcfg = apply_kernel_config(self.pcfg, cfg_dict)
        self._derive()
        self.kernel_swaps += 1

    @property
    def decode_dispatch(self) -> str:
        """Which implementation the next decode step's attention runs on —
        ``"pallas"`` when the flash-decode dispatch gate is open, ``"jax"``
        otherwise. Surfaced per-step by ``ServeStats``."""
        from repro.models.layers import _pallas_decode_ok
        hd = self.cfg.resolved_head_dim
        return ("pallas" if _pallas_decode_ok(hd, hd, self.pcfg.kernel)
                else "jax")

    def input_batch(self):
        cfg, B = self.cfg, self.batch_size
        if cfg.frontend == "embeddings":
            batch = {"frame_embeddings": jax.random.normal(
                self.key, (B, self.prompt_len, cfg.d_model),
                jnp.dtype(cfg.dtype))}
            if cfg.cross_attention:
                batch["cond"] = jax.random.normal(
                    self.key, (B, cfg.cross_seq, cfg.d_model),
                    jnp.dtype(cfg.dtype))
        else:
            batch = {"tokens": jax.random.randint(
                self.key, (B, self.prompt_len), 0, cfg.vocab_size)}
        return batch

    def prefill_batch(self, batch) -> float:
        t0 = time.time()
        logits, self.cache = self.prefill(self.params, batch)
        logits.block_until_ready()
        self.logits_shape = logits.shape
        self.toks = jnp.argmax(logits, -1)
        self.out = [self.toks]
        self.pos = self.prompt_len
        return time.time() - t0

    def decode_step(self) -> float:
        """One decode step over the held state; returns measured seconds."""
        t0 = time.time()
        pos = jnp.asarray(self.pos, jnp.int32)
        if self.cfg.frontend == "embeddings":
            emb = self.params["lm_head"]["w"][:, self.toks].T[:, None, :] \
                .astype(jnp.dtype(self.cfg.dtype))
            step_batch = {"frame_embeddings": emb}
        else:
            step_batch = {"tokens": self.toks[:, None]}
        logits, self.cache = self.decode(self.params, self.cache, step_batch,
                                         pos)
        toks = jnp.argmax(logits, -1)
        toks.block_until_ready()
        self.toks = toks
        self.out.append(toks)
        self.pos += 1
        return time.time() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default=None,
                    help="tuning-record store (dir or .jsonl) to resolve "
                         "the serving config from")
    ap.add_argument("--tuned-shape", default="decode_32k",
                    help="dry-run shape whose tuning records configure "
                         "this server")
    ap.add_argument("--online", action="store_true",
                    help="tail the store between decode steps (hot config "
                         "reload), write prod-latency records back, flag "
                         "drift re-tunes (requires --store)")
    ap.add_argument("--drift-factor", type=float, default=1.5,
                    help="re-tune when windowed prod latency is off the "
                         "stored roofline by this factor either way")
    ap.add_argument("--drift-stat", default="median",
                    choices=["median", "p50", "p99", "mean"],
                    help="window statistic the drift alarm keys off (p99 "
                         "tracks the tail users feel)")
    ap.add_argument("--kernels", action="store_true",
                    help="resolve tuned Pallas kernel block configs from "
                         "--store and dispatch through them (prefill flash "
                         "attention + per-token flash decode); in --online "
                         "mode also tail the store for kernel hot-swaps")
    ap.add_argument("--swap-margin", type=float, default=0.0,
                    help="hot-reload hysteresis: a same-tier better record "
                         "must improve the roofline step time by MORE than "
                         "this many seconds to be worth the re-jit")
    ap.add_argument("--poll-every", type=int, default=4,
                    help="decode steps between store polls in --online mode")
    args = ap.parse_args()
    if args.online and not args.store:
        ap.error("--online requires --store")

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    pcfg = ParallelConfig(flash_threshold=1 << 30, logits_chunk=0)
    source = None
    if args.online:
        # one code path for startup resolution AND hot reload: the first
        # refresh replays the store; later refreshes see only new records
        source = HotConfigSource(args.store, args.arch, args.tuned_shape,
                                 swap_margin=args.swap_margin)
        hit = source.refresh()
        if hit is None:
            print(f"[serve] no tuning record for ({args.arch}, "
                  f"{args.tuned_shape}, single) in {args.store} — using "
                  "built-in defaults")
        else:
            print(f"[serve] tuned config from store ({hit[1]:.3f}s "
                  f"roofline): {hit[0]}")
            pcfg = apply_sharding_config(pcfg, hit[0])
    elif args.store:
        pcfg = resolve_pcfg(pcfg, args.store, args.arch, args.tuned_shape)

    kernel_sources = []
    if args.kernels and args.store:
        from repro.kernels import tuning as ktuning
        hd = cfg.resolved_head_dim
        cache_cap = args.prompt_len + args.decode_steps
        kv_heads = cfg.num_kv_heads or cfg.num_heads
        kcfg = ktuning.kernel_config_from_store(args.store,
                                                S=args.prompt_len, hd=hd)
        if kcfg is None:
            print("[serve] no usable flash (prefill) kernel record in "
                  "store — pure-JAX prefill attention")
        else:
            print(f"[serve] tuned flash (prefill) blocks from store: {kcfg}")
            pcfg = pcfg.replace(kernel=kcfg)
        dcfg = ktuning.decode_kernel_config_from_store(
            args.store, cache_cap=cache_cap, H=cfg.num_heads, KV=kv_heads,
            hd=hd, base=pcfg.kernel)
        if dcfg is None:
            print("[serve] no usable decode kernel record in store — "
                  "pure-JAX decode attention")
        else:
            print(f"[serve] tuned decode blocks from store: "
                  f"block_kv={dcfg.decode_block_kv} "
                  f"num_splits={dcfg.decode_num_splits} "
                  f"combine={dcfg.decode_combine}")
            pcfg = pcfg.replace(kernel=dcfg)
        if args.online:
            def _cell(mk, *a):
                # a shape the kernel's config space cannot tile at all
                # (e.g. prompt shorter than every flash block) has no cell
                # to watch — skip the source, keep serving
                try:
                    return mk(*a)
                except ValueError as e:
                    print(f"[serve] no tunable kernel cell for this shape "
                          f"({e}) — skipping hot-swap source")
                    return None

            fcell = _cell(ktuning.flash_cell, args.batch, args.prompt_len,
                          cfg.num_heads, hd)
            dcell = _cell(ktuning.decode_cell, args.batch, cache_cap,
                          cfg.num_heads, kv_heads, hd)
            for cell in (fcell, dcell):
                if cell is None:
                    continue
                src = HotConfigSource.for_kernel_cell(
                    args.store, cell, swap_margin=args.swap_margin)
                src.refresh()
                kernel_sources.append(src)

    server = DecodeServer(cfg, pcfg, batch=args.batch,
                          prompt_len=args.prompt_len,
                          decode_steps=args.decode_steps, seed=args.seed)
    batch = server.input_batch()
    dt_prefill = server.prefill_batch(batch)
    print(f"[serve] prefill B={args.batch} S={args.prompt_len}: "
          f"{dt_prefill*1e3:.0f} ms, logits {server.logits_shape}")

    if args.online:
        from repro.store.queue import TuningJobQueue
        recorder = ProdRecorder(args.store, args.arch, args.tuned_shape)
        # prefill latency is telemetry, not a decode-step observation: it
        # includes the prefill jit compile and is in different units than
        # the tuned step time — journaled configless so it never transfers
        recorder.record(None, dt_prefill, phase="prefill")
        monitor = DriftMonitor(source.current[1] if source.current else None,
                               factor=args.drift_factor,
                               stat=args.drift_stat)
        # durable: a drift request survives this server's death and is
        # claimed (exactly once, fleet-wide) by any number of separate
        # `python -m repro.launch.retune` daemons.
        # The queue appends through the recorder's store handle — one live
        # segment per pid, the shape compaction's "sealed" rule assumes
        queue = TuningJobQueue(args.store, appender=recorder.store)
        loop = OnlineServeLoop(server, source, recorder=recorder,
                               monitor=monitor, retune_queue=queue,
                               cell_key=source.objective_id,
                               poll_every=args.poll_every,
                               first_step_warmup=True,
                               kernel_sources=kernel_sources)
        t0 = time.time()
        stats = loop.run(args.decode_steps)
        dt = time.time() - t0
        print(f"[serve] decoded {args.decode_steps} steps x B={args.batch}: "
              f"{dt*1e3:.0f} ms ({dt/args.decode_steps*1e3:.1f} ms/step)")
        for step, cfg_new, value in stats.swaps:
            print(f"[serve] hot-reload at step {step}: {value:.3f}s "
                  f"roofline {cfg_new}")
        for step, cfg_new, value in stats.kernel_swaps:
            print(f"[serve] kernel hot-swap at step {step}: "
                  f"{value*1e3:.2f} ms step {cfg_new} "
                  f"(cache {server.kernel_cache.stats()})")
        print(f"[serve] online: {recorder.count} prod records, "
              f"{len(stats.swaps)} hot reloads, "
              f"{stats.retunes_requested} re-tune requests submitted")
        print(f"[serve] decode dispatch: {stats.decode_steps_pallas} steps "
              f"Pallas flash-decode, {stats.decode_steps_jax} pure-JAX")
        for tk in queue.open_tickets():
            print(f"[serve] drift: observed {tk.observed*1e3:.1f} ms/step "
                  f"vs {tk.predicted*1e3:.1f} ms predicted — durable "
                  f"re-tune request {tk.id} open (service with "
                  f"`python -m repro.launch.retune --store {args.store}`)")
    else:
        t0 = time.time()
        for _ in range(args.decode_steps):
            server.decode_step()
        dt = time.time() - t0
        print(f"[serve] decoded {args.decode_steps} steps x B={args.batch}: "
              f"{dt*1e3:.0f} ms ({dt/args.decode_steps*1e3:.1f} ms/step)")
    print("[serve] sample tokens:", [int(t[0]) for t in server.out][:12])


if __name__ == "__main__":
    main()
