"""Serving launcher: batched prefill + decode over a smoke config.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --prompt-len 32 --decode-steps 16

Distribution/performance knobs come from the tuning-record store when one is
given (``--store``): the best prior tuning result for this (arch, shape,
mesh) cell overrides the built-in defaults, so serving inherits every past
tuning run's work. No record -> defaults, loudly.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch, smoke_config
from repro.models.params import init_params
from repro.models.stepfn import make_decode_step, make_prefill_step
from repro.parallel.sharding import ParallelConfig, ShardCtx
from repro.store import apply_sharding_config, best_sharding_config


def resolve_pcfg(pcfg: ParallelConfig, store: str, arch: str, shape: str,
                 mesh: str = "single") -> ParallelConfig:
    """Best stored tuning config for this serving cell, else defaults."""
    hit = best_sharding_config(store, arch, shape, mesh=mesh)
    if hit is None:
        print(f"[serve] no tuning record for ({arch}, {shape}, {mesh}) in "
              f"{store} — using built-in defaults")
        return pcfg
    cfg, step_time = hit
    print(f"[serve] tuned config from store ({step_time:.3f}s roofline): "
          f"{cfg}")
    return apply_sharding_config(pcfg, cfg)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default=None,
                    help="tuning-record store (dir or .jsonl) to resolve "
                         "the serving config from")
    ap.add_argument("--tuned-shape", default="decode_32k",
                    help="dry-run shape whose tuning records configure "
                         "this server")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    pcfg = ParallelConfig(flash_threshold=1 << 30, logits_chunk=0)
    if args.store:
        pcfg = resolve_pcfg(pcfg, args.store, args.arch, args.tuned_shape)
    px = ShardCtx(mesh=None, pcfg=pcfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)

    cap = args.prompt_len + args.decode_steps
    prefill = jax.jit(make_prefill_step(cfg, px, cache_cap=cap))
    decode = jax.jit(make_decode_step(cfg, px))

    B = args.batch
    if cfg.frontend == "embeddings":
        batch = {"frame_embeddings": jax.random.normal(
            key, (B, args.prompt_len, cfg.d_model), jnp.dtype(cfg.dtype))}
        if cfg.cross_attention:
            batch["cond"] = jax.random.normal(
                key, (B, cfg.cross_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        batch = {"tokens": jax.random.randint(key, (B, args.prompt_len), 0,
                                              cfg.vocab_size)}

    t0 = time.time()
    logits, cache = prefill(params, batch)
    print(f"[serve] prefill B={B} S={args.prompt_len}: "
          f"{(time.time()-t0)*1e3:.0f} ms, logits {logits.shape}")

    toks = jnp.argmax(logits, -1)
    out = [toks]
    t0 = time.time()
    for i in range(args.decode_steps):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        if cfg.frontend == "embeddings":
            emb = params["lm_head"]["w"][:, toks].T[:, None, :].astype(
                jnp.dtype(cfg.dtype))
            step_batch = {"frame_embeddings": emb}
        else:
            step_batch = {"tokens": toks[:, None]}
        logits, cache = decode(params, cache, step_batch, pos)
        toks = jnp.argmax(logits, -1)
        out.append(toks)
    dt = time.time() - t0
    print(f"[serve] decoded {args.decode_steps} steps x B={B}: "
          f"{dt*1e3:.0f} ms ({dt/args.decode_steps*1e3:.1f} ms/step)")
    print("[serve] sample tokens:", [int(t[0]) for t in out][:12])


if __name__ == "__main__":
    main()
