"""HLO text analysis: collective bytes, per-op breakdown, DCN detection.

``cost_analysis()`` gives FLOPs and memory bytes but not collective traffic;
we parse the compiled HLO and sum operand sizes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute),
looking operand shapes up in a symbol table built from instruction results.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")
_OPERAND_RE = re.compile(r"(%[\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, incl. tuple types."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, dcn_stride: Optional[int] = None
                      ) -> Dict[str, Dict[str, float]]:
    """Returns {op_kind: {count, bytes, dcn_bytes}}.

    bytes = operand bytes entering the collective (the traffic the ICI/DCN
    must carry, up to the algorithm's constant factor). A collective whose
    replica group contains ids differing by >= dcn_stride is counted as DCN.
    """
    # pass 1: symbol table of result types
    sizes: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if m:
            sizes[m.group(1)] = shape_bytes(m.group(2))

    out: Dict[str, Dict[str, float]] = defaultdict(lambda: {"count": 0, "bytes": 0.0,
                                                            "dcn_bytes": 0.0})
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, rtype, op = m.groups()
        kind = None
        for c in COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        # operand bytes: look up names inside the parens after the op name
        paren = ln[ln.find(op) + len(op):]
        depth = 0
        arglist = ""
        for ch in paren:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                arglist += ch
        op_bytes = sum(sizes.get(nm, 0) for nm in _OPERAND_RE.findall(arglist))
        if op_bytes == 0:
            op_bytes = shape_bytes(rtype)
        is_dcn = False
        if dcn_stride:
            g = _GROUPS_RE.search(ln)
            if g:
                for grp in g.group(1).split("},{"):
                    ids = [int(t) for t in re.findall(r"\d+", grp)]
                    if ids and max(ids) - min(ids) >= dcn_stride:
                        is_dcn = True
                        break
        rec = out[kind]
        rec["count"] += 1
        rec["bytes"] += op_bytes
        if is_dcn:
            rec["dcn_bytes"] += op_bytes
    return dict(out)


def total_collective_bytes(stats: Dict[str, Dict[str, float]]) -> Tuple[float, float]:
    tot = sum(v["bytes"] for v in stats.values())
    dcn = sum(v["dcn_bytes"] for v in stats.values())
    return tot, dcn


def count_while_trip_counts(hlo_text: str):
    """Extract (trip_count hints) from while loops if annotated."""
    return re.findall(r'known_trip_count\\?["\']?\s*:?\s*\{\\?["\']?n\\?["\']?\s*[:=]\s*\\?["\']?(\d+)', hlo_text)
