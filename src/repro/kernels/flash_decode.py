"""Single-token flash-decode Pallas TPU kernel (split-KV, tunable).

Decode attention runs once per generated token over the whole KV cache, so
at serving scale it dominates cost; unlike prefill there is no q-sequence
to tile, which makes the natural parallel axis the CACHE LENGTH. The kernel
partitions the cache into ``num_splits`` independent ranges, each scanned
in ``block_kv`` tiles with the online-softmax (m, l, acc) state held in
VMEM, then a cross-split combine merges the per-split partials — the
"flash-decode" decomposition. GQA is native: the grid iterates KV heads and
each program holds that head's G = H/KV grouped query rows, so KV tiles are
loaded ONCE per group instead of per query head (the GQA-expansion the
prefill kernel needs would multiply decode HBM traffic by G).

Validity (cache slots never written, slots beyond the current position,
rolling-window eviction) enters as a precomputed additive f32 bias row
(0 or -inf) built by the wrapper in ``repro.kernels.ops`` — the kernel
itself stays a pure softmax-accumulate, and a fully-masked split resolves
to zero weight in the combine rather than NaN.

Tunables (the BO cell's space, DESIGN.md §16): ``block_kv`` (tile length),
``num_splits`` (cache partitions — parallelism vs combine overhead), and
the combine strategy (``"jax"``: merge partials with jnp ops; ``"kernel"``:
a second small Pallas kernel so partials never leave the device path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

COMBINE_STRATEGIES = ("jax", "kernel")


def _decode_split_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_out_ref,
                         l_out_ref, m_ref, l_ref, acc_ref, *, steps: int,
                         scale: float):
    """One (batch, kv_head, split) program: scan this split's KV tiles with
    online softmax, emit unnormalized (acc, m, l) partials for the combine.

    Masked positions carry a -inf bias, so ``exp(s - m_safe)`` is exactly 0
    for them; an all-masked split keeps m = -inf / l = 0 and contributes
    nothing downstream.
    """
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (G, hd)
    k = k_ref[0, :, 0, :]                          # (bkv, hd)
    v = v_ref[0, :, 0, :]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = s + bias_ref[0][None, :]                   # 0 valid / -inf masked

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe[:, None])               # exp(-inf - 0) == 0
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(j == steps - 1)
    def _done():
        o_ref[0, 0, 0] = acc_ref[...]
        m_out_ref[0, 0, 0] = m_ref[:, 0]
        l_out_ref[0, 0, 0] = l_ref[:, 0]


def _combine_partials_jnp(o_part, m_part, l_part):
    """Merge per-split (acc, m, l) into normalized attention output.

    o_part (B,KV,S,G,hd) f32, m/l (B,KV,S,G) — the flash cross-block
    correction applied once across splits: weight each split by
    exp(m_i - max_i m_i), then normalize by the merged l.
    """
    m_tot = m_part.max(axis=2)                                 # (B,KV,G)
    m_safe = jnp.where(jnp.isfinite(m_tot), m_tot, 0.0)
    w = jnp.where(jnp.isfinite(m_part),
                  jnp.exp(m_part - m_safe[:, :, None, :]), 0.0)
    l_tot = jnp.sum(w * l_part, axis=2)                        # (B,KV,G)
    o = jnp.sum(w[..., None] * o_part, axis=2)                 # (B,KV,G,hd)
    return o / jnp.maximum(l_tot, 1e-30)[..., None]


def _decode_combine_kernel(o_ref, m_ref, l_ref, out_ref):
    """One (batch, kv_head) program folding all splits of one head group."""
    o = o_ref[0, 0]                                # (S, G, hd) f32
    m = m_ref[0, 0]                                # (S, G)
    l = l_ref[0, 0]
    m_tot = m.max(axis=0)                          # (G,)
    m_safe = jnp.where(jnp.isfinite(m_tot), m_tot, 0.0)
    w = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe[None, :]), 0.0)
    l_tot = jnp.sum(w * l, axis=0)                 # (G,)
    merged = jnp.sum(w[..., None] * o, axis=0)     # (G, hd)
    out_ref[0] = (merged / jnp.maximum(l_tot, 1e-30)[:, None]
                  ).astype(out_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 bias: jax.Array, *, block_kv: int = 512,
                 num_splits: int = 1, combine: str = "jax",
                 interpret: bool = False) -> jax.Array:
    """Single-token cache attention. q (B, H, hd); k/v caches
    (B, S, KV, hd) with S % (num_splits * block_kv) == 0 (the ops wrapper
    pads arbitrary capacities); bias (B, S) f32 additive validity mask
    (0 valid / -inf masked). Returns (B, H, hd) in q's dtype.
    """
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    assert v_cache.shape == k_cache.shape
    assert H % KV == 0, (H, KV)
    assert S % (num_splits * block_kv) == 0, (S, num_splits, block_kv)
    assert combine in COMBINE_STRATEGIES, combine
    G = H // KV
    steps = S // (num_splits * block_kv)
    scale = 1.0 / (hd ** 0.5)
    grid = (B, KV, num_splits, steps)

    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    spec_q = pl.BlockSpec((1, G, hd), lambda b, k, s, j: (b, k, 0))
    spec_kv = pl.BlockSpec((1, block_kv, 1, hd),
                           lambda b, k, s, j: (b, s * steps + j, k, 0))
    spec_bias = pl.BlockSpec((1, block_kv),
                             lambda b, k, s, j: (b, s * steps + j))
    spec_o = pl.BlockSpec((1, 1, 1, G, hd), lambda b, k, s, j: (b, k, s, 0, 0))
    spec_ml = pl.BlockSpec((1, 1, 1, G), lambda b, k, s, j: (b, k, s, 0))
    o_part, m_part, l_part = pl.pallas_call(
        functools.partial(_decode_split_kernel, steps=steps, scale=scale),
        grid=grid,
        in_specs=[spec_q, spec_kv, spec_kv, spec_bias],
        out_specs=[spec_o, spec_ml, spec_ml],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, num_splits, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, num_splits, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, num_splits, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),       # m
            pltpu.VMEM((G, 1), jnp.float32),       # l
            pltpu.VMEM((G, hd), jnp.float32),      # acc
        ],
        interpret=interpret,
        **kw,
    )(q, k_cache, v_cache, bias)

    if combine == "kernel":
        out = pl.pallas_call(
            _decode_combine_kernel,
            grid=(B, KV),
            in_specs=[
                pl.BlockSpec((1, 1, num_splits, G, hd),
                             lambda b, k: (b, k, 0, 0, 0)),
                pl.BlockSpec((1, 1, num_splits, G), lambda b, k: (b, k, 0, 0)),
                pl.BlockSpec((1, 1, num_splits, G), lambda b, k: (b, k, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, G, hd), lambda b, k: (b, k, 0)),
            out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
            interpret=interpret,
        )(o_part, m_part, l_part)
        return out
    merged = _combine_partials_jnp(o_part, m_part, l_part)     # (B,KV,G,hd)
    return merged.reshape(B, H, hd).astype(q.dtype)


def decode_vmem_bytes(block_kv: int, G: int, hd: int,
                      dtype_bytes: int = 2) -> int:
    """Split-kernel VMEM working set: K/V tiles + the head group's q rows,
    f32 scores, (m, l, acc) state, bias row, and the partial outputs."""
    kv = 2 * block_kv * hd * dtype_bytes
    qrows = G * hd * dtype_bytes
    scores = G * block_kv * 4
    state = G * (hd + 2) * 4
    bias = block_kv * 4
    partials = G * (hd + 2) * 4
    return kv + qrows + scores + state + bias + partials
