"""Jit'd wrappers + tunable config spaces for the Pallas kernels.

On non-TPU backends the kernels run in interpret mode (the kernel body
executes in Python on CPU) — the TPU is the TARGET, interpret is the
validation path. Each kernel exposes a SearchSpace whose invalid region is
the TPU resource model (VMEM capacity, MXU alignment): the exact structure
the paper tunes on GPUs, re-parameterized for TPU (DESIGN.md §2).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.searchspace import Param, SearchSpace
from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import gemm as _gemm
from repro.kernels import matern_gp as _mgp
from repro.launch.roofline import VMEM_BYTES


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# -- GEMM ---------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def gemm(a, b, block_m=256, block_n=256, block_k=256, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _gemm.gemm(a, b, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret)


def gemm_config_space(M: int = 1024, N: int = 1024, K: int = 1024) -> SearchSpace:
    """BO target: MXU tile shapes. Invalid = VMEM overflow / misalignment
    (checked by the objective, not the constraints — runtime invalids)."""
    vals = (64, 128, 256, 512, 1024)
    params = [Param("block_m", vals), Param("block_n", vals),
              Param("block_k", vals)]
    cons = [lambda c: M % c["block_m"] == 0,
            lambda c: N % c["block_n"] == 0,
            lambda c: K % c["block_k"] == 0]
    return SearchSpace(params, cons, name="pallas_gemm")


def gemm_valid(cfg: Dict, dtype_bytes: int = 2,
               vmem_bytes: int = VMEM_BYTES) -> bool:
    return _gemm.gemm_vmem_bytes(cfg["block_m"], cfg["block_n"],
                                 cfg["block_k"], dtype_bytes) <= vmem_bytes


# -- flash attention -----------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "causal",
                                             "interpret"))
def flash_attention(q, k, v, block_q=512, block_kv=512, causal=True,
                    interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _fa.flash_attention(q, k, v, block_q=block_q, block_kv=block_kv,
                               causal=causal, interpret=interpret)


def flash_config_space(S: int = 4096) -> SearchSpace:
    vals = (128, 256, 512, 1024, 2048)
    params = [Param("block_q", vals), Param("block_kv", vals)]
    cons = [lambda c: S % c["block_q"] == 0, lambda c: S % c["block_kv"] == 0]
    return SearchSpace(params, cons, name="pallas_flash")


def flash_valid(cfg: Dict, hd: int = 128, dtype_bytes: int = 2,
                vmem_bytes: int = VMEM_BYTES) -> bool:
    return _fa.flash_vmem_bytes(cfg["block_q"], cfg["block_kv"], hd,
                                dtype_bytes) <= vmem_bytes


# -- flash decode (single-token cache attention) --------------------------


@functools.partial(jax.jit, static_argnames=("window", "block_kv",
                                             "num_splits", "combine",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, cache_pos, cur_pos, window=None,
                     block_kv=512, num_splits=1, combine="jax",
                     interpret=None):
    """Split-KV flash decode over the cache, semantics-matched to
    ``models.layers._decode_attention``: q (B, 1, H, hd), caches
    (B, S, KV, hd), ``cache_pos`` (B, S) absolute positions (-1 = empty
    slot), ``cur_pos`` (B,) the position being decoded. Slot validity —
    empty, future, or evicted by a rolling ``window`` — becomes an additive
    f32 bias row (0 / -inf), and caches whose capacity doesn't tile into
    ``num_splits × block_kv`` are padded with masked slots, so any capacity
    and occupancy runs. Returns (B, 1, H, hd).
    """
    if interpret is None:
        interpret = _interpret_default()
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    valid = (cache_pos >= 0) & (cache_pos <= cur_pos[:, None])
    if window is not None:
        valid &= cache_pos > cur_pos[:, None] - window
    bias = jnp.where(valid, 0.0, -jnp.inf).astype(jnp.float32)
    tile = num_splits * block_kv
    pad = (-S) % tile
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    out = _fd.flash_decode(q[:, 0], k_cache, v_cache, bias,
                           block_kv=block_kv, num_splits=num_splits,
                           combine=combine, interpret=interpret)
    return out[:, None]


def decode_config_space(S: int = 2048) -> SearchSpace:
    """BO target for the decode cell: KV tile length, split count, and the
    cross-split combine strategy. ``S`` is the cache capacity; splits whose
    leading tiles already cover the whole cache are pure overhead and
    constrained out (padding makes any remaining combination runnable)."""
    params = [Param("block_kv", (128, 256, 512, 1024)),
              Param("num_splits", (1, 2, 4, 8)),
              Param("combine", _fd.COMBINE_STRATEGIES)]
    cons = [lambda c: c["block_kv"] * (c["num_splits"] - 1) < S]
    return SearchSpace(params, cons, name="pallas_flash_decode")


def decode_valid(cfg: Dict, G: int = 1, hd: int = 128, dtype_bytes: int = 2,
                 vmem_bytes: int = VMEM_BYTES) -> bool:
    return _fd.decode_vmem_bytes(cfg["block_kv"], G, hd,
                                 dtype_bytes) <= vmem_bytes


# -- Matérn GP posterior ---------------------------------------------------


@functools.partial(jax.jit, static_argnames=("ell", "nu", "block_n", "interpret"))
def gp_posterior(x_cand, x_obs, vinv_rows, w, mask, ell=2.0, nu="matern32",
                 block_n=512, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _mgp.gp_posterior(x_cand, x_obs, vinv_rows, w, mask, ell=ell,
                             nu=nu, block_n=block_n, interpret=interpret)


def gp_inputs_from_incremental(gp, pad_T: Optional[int] = None):
    """Package an IncrementalGP state as padded kernel inputs."""
    from repro.core.gp_fast import forward_substitute

    t = gp.t
    T = pad_T or max(128, 1 << (t - 1).bit_length())
    d = gp.dim
    x_obs = np.zeros((T, d), np.float32)
    x_obs[:t] = gp.X[:t]
    # invert the Cholesky factor in float64 — GP kernel matrices are
    # ill-conditioned and an fp32 inverse loses ~1% of the posterior mean.
    # Triangular solve against identity (O(t²) per rhs column), NOT
    # np.linalg.inv of the full padded factor: the generic inverse is O(T³)
    # on every packaging call and ignores the triangular structure.
    vinv = np.zeros((T, T), np.float32)
    vinv[:t, :t] = forward_substitute(
        gp.L[:t, :t], np.eye(t, dtype=np.float64)).astype(np.float32)
    yv = gp.y[:t]
    y_mean, y_std = float(yv.mean()), max(float(yv.std()), 1e-12)
    w = np.zeros(T, np.float32)
    w[:t] = forward_substitute(gp.L[:t, :t], (yv - y_mean) / y_std)
    mask = np.zeros(T, np.float32)
    mask[:t] = 1.0
    return x_obs, vinv, w, mask, y_mean, y_std


def gp_config_space(N: int = 16384) -> SearchSpace:
    vals = (128, 256, 512, 1024, 2048, 4096)
    params = [Param("block_n", vals)]
    return SearchSpace(params, [lambda c: N % c["block_n"] == 0],
                       name="pallas_matern_gp")


def gp_valid(cfg: Dict, T: int = 256, d: int = 16,
             vmem_bytes: int = VMEM_BYTES) -> bool:
    """VMEM check for the GP-posterior cell (gemm/flash had theirs from the
    start; ``gp_vmem_bytes`` existed but nothing consumed it)."""
    return _mgp.gp_vmem_bytes(cfg["block_n"], T, d) <= vmem_bytes
