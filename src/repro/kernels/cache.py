"""Per-device compiled-kernel cache (DESIGN.md §14).

Hot-swapping a tuned kernel config between decode steps means re-deriving
jit'd step functions. jax's own compilation cache keys on traced HLO, but a
serve process also wants (a) an explicit hit/miss ledger so the loop-sim
can pin "re-applying a previously-seen config does not re-jit", and (b)
eviction keyed on *our* terms — store fingerprint digest + block config —
so a store compaction or retune invalidates exactly the entries it should.

The cache is deliberately dumb: ``get(key, build)`` memoizes ``build()``
under a hashable key. DecodeServer keys derived step-fn bundles by
``(arch_digest, kernel-config tuple)``; the kernel-tuning benchmark keys
compiled kernels by ``(fingerprint, config items)``.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional, Tuple


def config_key(cfg: Optional[Dict[str, Any]]) -> Tuple:
    """Canonical hashable form of a (possibly-None) config dict."""
    if cfg is None:
        return ()
    return tuple(sorted(cfg.items()))


class CompiledKernelCache:
    """Thread-safe memo of compiled artifacts with LRU eviction + stats."""

    def __init__(self, max_entries: int = 32):
        self.max_entries = int(max_entries)
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Hashable, build: Callable[[], Any]) -> Any:
        with self._lock:
            if key in self._entries:
                self.hits += 1
                self._entries.move_to_end(key)
                return self._entries[key]
        # Build OUTSIDE the lock: jit compilation can take seconds and must
        # not block concurrent lookups of already-cached configs.
        value = build()
        with self._lock:
            if key in self._entries:          # lost a build race: keep first
                self.hits += 1
                self._entries.move_to_end(key)
                return self._entries[key]
            self.misses += 1
            self._entries[key] = value
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.evictions += 1
            return value

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def invalidate(self, predicate: Optional[Callable[[Hashable], bool]] = None) -> int:
        """Drop entries whose key matches ``predicate`` (all when None).
        Returns the number dropped. Used when a store compaction/retune
        changes the fingerprint an entry was keyed under."""
        with self._lock:
            if predicate is None:
                n = len(self._entries)
                self._entries.clear()
                return n
            doomed = [k for k in self._entries if predicate(k)]
            for k in doomed:
                del self._entries[k]
            return len(doomed)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "entries": len(self._entries)}
