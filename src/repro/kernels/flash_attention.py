"""Causal flash attention Pallas TPU kernel (tunable block_q / block_kv).

Online-softmax over KV blocks with the running (m, l, acc) state in VMEM —
the accumulator NEVER touches HBM, which is precisely what the pure-JAX
blockwise attention in repro.models.layers cannot express (its fp32
accumulator is an HLO tensor; see EXPERIMENTS.md §Perf hillclimb #3).
Grid: (batch, heads, q_blocks, kv_blocks), kv innermost/arbitrary.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  kv_steps: int, block_q: int, block_kv: int, scale: float,
                  causal: bool):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :]                       # (bq, hd)
    k = k_ref[0, :, 0, :]                       # (bkv, hd)
    v = v_ref[0, :, 0, :]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        i = pl.program_id(2)
        q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(j == kv_steps - 1)
    def _done():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    block_q: int = 512, block_kv: int = 512,
                    causal: bool = True, interpret: bool = False) -> jax.Array:
    """q,k,v (B, S, H, hd) — MHA core (GQA: expand kv before the call)."""
    B, S, H, hd = q.shape
    assert k.shape == v.shape == q.shape
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    kv_steps = S // block_kv
    grid = (B, H, S // block_q, kv_steps)
    scale = 1.0 / math.sqrt(hd)

    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))
    spec_q = pl.BlockSpec((1, block_q, 1, hd), lambda b, h, i, j: (b, i, h, 0))
    spec_kv = pl.BlockSpec((1, block_kv, 1, hd), lambda b, h, i, j: (b, j, h, 0))
    return pl.pallas_call(
        functools.partial(_flash_kernel, kv_steps=kv_steps, block_q=block_q,
                          block_kv=block_kv, scale=scale, causal=causal),
        grid=grid,
        in_specs=[spec_q, spec_kv, spec_kv],
        out_specs=spec_q,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # m
            pltpu.VMEM((block_q, 1), jnp.float32),     # l
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
        **kw,
    )(q, k, v)


def flash_vmem_bytes(block_q: int, block_kv: int, hd: int,
                     dtype_bytes: int = 2) -> int:
    qkv = (block_q + 2 * block_kv) * hd * dtype_bytes
    scores = block_q * block_kv * 4
    state = block_q * (hd + 2) * 4
    out = block_q * hd * dtype_bytes
    return qkv + scores + state + out
