"""In-process Pallas kernel autotuning cells (DESIGN.md §14).

This is the source paper's literal problem — tune GPU *kernel* parameters
(thread-block/tile shapes) with BO against measured runtimes — brought
in-process and re-parameterized for TPU: the tunable cells are the repo's
own Pallas kernels (flash_attention ``block_q``/``block_kv``, gemm
``block_m/n/k``, matern_gp ``block_n``), the objective is real kernel step
time (interpret-mode timing off-TPU — the validation path — real device
timing on TPU), and VMEM overflow / tile misalignment are the paper's
invalid configurations: journaled as NaN records, never fed to the
surrogate, never raised as exceptions.

Everything reuses the existing machinery unchanged: a ``KernelCell`` is an
``Objective`` over a ``SearchSpace``, runs journal into the
``TuningRecordStore`` under ``kernel[name×shape×device]`` fingerprints
(so warm-start, resume, and the durable retune queue all apply), and
serving resolves tuned block configs from the same store it resolves
sharding configs from (``best_kernel_config`` → ``KernelConfig`` →
``DecodeServer.apply_kernel_config``). The matern_gp cell closes the
self-hosting loop: its tuned ``block_n`` feeds the tuner's own §III-G
exhaustive-prediction hot loop (``IncrementalGP(backend="pallas")``).
"""
from __future__ import annotations

import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.objectives import Objective
from repro.core.searchspace import SearchSpace
from repro.kernels import ops
from repro.launch.roofline import VMEM_BYTES

KERNEL_NAMES = ("gemm", "flash", "decode", "gp")


def device_kind() -> str:
    """Device context kernel timings are keyed under — a cpu-interpret
    record must never resolve for a tpu deployment (and vice versa)."""
    return jax.default_backend()


def kernel_cell_objective(kernel: str, shape_sig: str,
                          device: Optional[str] = None) -> str:
    """Objective id of one kernel-tuning cell, mirroring the sharding cells'
    ``dryrun[arch×shape×mesh]`` convention: ``kernel[name×shape×device]``."""
    return f"kernel[{kernel}×{shape_sig}×{device or device_kind()}]"


@dataclass
class KernelCell:
    """One tunable kernel at one problem shape on one device.

    ``run(cfg)`` executes the kernel under a block config and returns the
    output (callers block on it); ``valid(cfg, vmem_bytes)`` is the static
    TPU resource model (VMEM capacity + alignment). ``default`` is the
    kernel's built-in block config — the thing tuning must beat.
    """

    kernel: str
    shape_sig: str
    space: SearchSpace
    run: Callable[[Dict[str, Any]], Any]
    valid: Callable[[Dict[str, Any], int], bool]
    default: Dict[str, Any]
    meta: Dict[str, Any] = field(default_factory=dict)

    def objective_id(self, device: Optional[str] = None) -> str:
        return kernel_cell_objective(self.kernel, self.shape_sig, device)


# -- cell factories ----------------------------------------------------------


def gemm_cell(M: int = 512, N: int = 512, K: int = 512,
              dtype=jnp.float32, interpret: Optional[bool] = None,
              seed: int = 0) -> KernelCell:
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(M, K)), dtype)
    b = jnp.asarray(rng.normal(size=(K, N)), dtype)
    dtype_bytes = jnp.dtype(dtype).itemsize

    def run(cfg):
        return ops.gemm(a, b, block_m=cfg["block_m"], block_n=cfg["block_n"],
                        block_k=cfg["block_k"], interpret=interpret)

    def valid(cfg, vmem_bytes):
        aligned = (M % cfg["block_m"] == 0 and N % cfg["block_n"] == 0
                   and K % cfg["block_k"] == 0)
        return aligned and ops.gemm_valid(cfg, dtype_bytes, vmem_bytes)

    return KernelCell(
        kernel="gemm", shape_sig=f"{M}x{N}x{K}",
        space=ops.gemm_config_space(M, N, K), run=run, valid=valid,
        default={"block_m": 256, "block_n": 256, "block_k": 256},
        meta={"M": M, "N": N, "K": K, "dtype_bytes": dtype_bytes})


def flash_cell(B: int = 1, S: int = 1024, H: int = 4, hd: int = 64,
               dtype=jnp.float32, causal: bool = True,
               interpret: Optional[bool] = None, seed: int = 0) -> KernelCell:
    rng = np.random.default_rng(seed)
    q, k, v = (jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
               for _ in range(3))
    dtype_bytes = jnp.dtype(dtype).itemsize

    def run(cfg):
        return ops.flash_attention(q, k, v, block_q=cfg["block_q"],
                                   block_kv=cfg["block_kv"], causal=causal,
                                   interpret=interpret)

    def valid(cfg, vmem_bytes):
        aligned = S % cfg["block_q"] == 0 and S % cfg["block_kv"] == 0
        return aligned and ops.flash_valid(cfg, hd, dtype_bytes, vmem_bytes)

    return KernelCell(
        kernel="flash", shape_sig=f"B{B}_S{S}_H{H}_hd{hd}",
        space=ops.flash_config_space(S), run=run, valid=valid,
        default={"block_q": 512, "block_kv": 512},
        meta={"B": B, "S": S, "H": H, "hd": hd, "dtype_bytes": dtype_bytes})


def decode_cell(B: int = 4, S: int = 2048, H: int = 8, KV: int = 2,
                hd: int = 64, fill: float = 0.95, window: Optional[int] = None,
                dtype=jnp.float32, interpret: Optional[bool] = None,
                seed: int = 0) -> KernelCell:
    """The per-token serve hot path: split-KV flash decode over a KV cache
    of capacity ``S`` at ``fill`` occupancy (empty slots carry
    ``cache_pos = -1`` exactly like a live server's cache). Shape key =
    cache capacity × heads × KV heads × head dim × batch."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    cur = max(int(S * fill) - 1, 0)
    pos = np.where(np.arange(S) <= cur, np.arange(S), -1)
    cache_pos = jnp.asarray(np.broadcast_to(pos, (B, S)).copy(), jnp.int32)
    cur_pos = jnp.full((B,), cur, jnp.int32)
    dtype_bytes = jnp.dtype(dtype).itemsize
    G = H // max(KV, 1)

    def run(cfg):
        return ops.decode_attention(q, k, v, cache_pos, cur_pos,
                                    window=window,
                                    block_kv=cfg["block_kv"],
                                    num_splits=cfg["num_splits"],
                                    combine=cfg["combine"],
                                    interpret=interpret)

    def valid(cfg, vmem_bytes):
        # padding tiles any capacity, but splits past the cache are pure
        # combine overhead — the alignment face of the resource model
        covered = cfg["block_kv"] * (cfg["num_splits"] - 1) < S
        return covered and ops.decode_valid(cfg, G, hd, dtype_bytes,
                                            vmem_bytes)

    return KernelCell(
        kernel="decode", shape_sig=f"B{B}_S{S}_H{H}_KV{KV}_hd{hd}",
        space=ops.decode_config_space(S), run=run, valid=valid,
        default={"block_kv": 512, "num_splits": 1, "combine": "jax"},
        meta={"B": B, "S": S, "H": H, "KV": KV, "hd": hd, "fill": fill,
              "window": window, "dtype_bytes": dtype_bytes})


def gp_cell(N: int = 4096, T: int = 128, d: int = 15, t_obs: int = 37,
            nu: str = "matern32", ell: float = 2.0,
            interpret: Optional[bool] = None, seed: int = 0) -> KernelCell:
    """The self-hosting cell: the tuner's own §III-G exhaustive-prediction
    hot loop, as a tuning target. Inputs are a real packaged IncrementalGP
    state (t_obs observations over an N-candidate panel)."""
    from repro.core.gp_fast import IncrementalGP
    rng = np.random.default_rng(seed)
    Xc = rng.random((N, d)).astype(np.float32)
    g = IncrementalGP(Xc, max_obs=max(t_obs, 1), kernel=nu, ell=ell)
    for _ in range(t_obs):
        g.add(Xc[rng.integers(N)], float(rng.normal(10, 2)))
    x_obs, vinv, w, mask, _, _ = ops.gp_inputs_from_incremental(g, pad_T=T)
    args = (jnp.asarray(Xc), jnp.asarray(x_obs), jnp.asarray(vinv),
            jnp.asarray(w), jnp.asarray(mask))

    def run(cfg):
        return ops.gp_posterior(*args, ell=ell, nu=nu,
                                block_n=cfg["block_n"], interpret=interpret)

    def valid(cfg, vmem_bytes):
        return (N % cfg["block_n"] == 0
                and ops.gp_valid(cfg, T, d, vmem_bytes))

    return KernelCell(
        kernel="gp", shape_sig=f"N{N}_T{T}_d{d}",
        space=ops.gp_config_space(N), run=run, valid=valid,
        default={"block_n": 512},
        meta={"N": N, "T": T, "d": d, "t_obs": t_obs, "nu": nu})


def default_cells(smoke: bool = False) -> Tuple[KernelCell, ...]:
    """The standard four-cell matrix ``benchmarks/kernel_tuning.py`` runs.
    Smoke shapes keep interpret-mode timing tractable on CPU CI."""
    if smoke:
        return (gemm_cell(256, 256, 256), flash_cell(1, 512, 2, 64),
                decode_cell(1, 512, 4, 2, 64), gp_cell(2048, 128, 15))
    return (gemm_cell(512, 512, 512), flash_cell(1, 1024, 4, 64),
            decode_cell(4, 2048, 8, 2, 64), gp_cell(4096, 128, 15))


# -- the measured objective --------------------------------------------------


class KernelObjective(Objective):
    """Measured kernel step time (seconds, lower better).

    The TPU resource model is checked FIRST: a config that would overflow
    VMEM or mis-tile the problem returns NaN — the paper's invalid
    configuration, journaled by the runner, skipped by the surrogate —
    instead of crashing the run. A config that passes the model but fails
    at execution (compiler rejection, interpret-mode assert) is likewise
    caught and journaled invalid. ``vmem_bytes`` is injectable so tests can
    shrink the budget and pin the invalid path without 16 MiB tiles.
    """

    def __init__(self, cell: KernelCell, *, reps: int = 3, warmup: int = 1,
                 vmem_bytes: int = VMEM_BYTES,
                 device: Optional[str] = None, verbose: bool = False):
        self.cell = cell
        self.space = cell.space
        self.name = cell.objective_id(device)
        self.reps = max(int(reps), 1)
        self.warmup = max(int(warmup), 1)
        self.vmem_bytes = int(vmem_bytes)
        self.verbose = verbose

    def __call__(self, idx: int) -> float:
        cfg = self.space.config(int(idx))
        if not self.cell.valid(cfg, self.vmem_bytes):
            if self.verbose:
                print(f"  [kernel-tune] {cfg} -> INVALID (resource model)")
            return math.nan
        try:
            for _ in range(self.warmup):          # compile + cache warm
                jax.block_until_ready(self.cell.run(cfg))
            best = math.inf
            for _ in range(self.reps):
                t0 = time.perf_counter()
                jax.block_until_ready(self.cell.run(cfg))
                best = min(best, time.perf_counter() - t0)
        except Exception as e:                    # runtime-discovered invalid
            if self.verbose:
                print(f"  [kernel-tune] {cfg} -> INVALID ({type(e).__name__})")
            return math.nan
        if self.verbose:
            print(f"  [kernel-tune] {cfg} -> {best*1e3:.3f} ms")
        return best


# -- store integration -------------------------------------------------------


def run_kernel_tuning(cell: KernelCell, store=None, *, budget: int = 12,
                      init: int = 4, seed: int = 0, reps: int = 3,
                      vmem_bytes: int = VMEM_BYTES, warm_start: bool = True,
                      device: Optional[str] = None, verbose: bool = False):
    """Tune one kernel cell with the standard BO engine, journaling into the
    shared store under the cell's ``kernel[...]`` fingerprint. Returns the
    engine's TuneResult."""
    from repro.core.runner import run_strategy
    from repro.core.strategies.bo import BOConfig, BOStrategy
    obj = KernelObjective(cell, reps=reps, vmem_bytes=vmem_bytes,
                          device=device, verbose=verbose)
    n_init = min(init, budget)
    strat = BOStrategy(BOConfig(initial_samples=n_init))
    run_id = f"kernel_{cell.kernel}_{cell.shape_sig}-s{seed}"
    return run_strategy(strat, obj, budget=budget, seed=seed, store=store,
                        run_id=run_id, warm_start=warm_start)


def best_kernel_config(store, kernel: str, shape_sig: Optional[str] = None,
                       device: Optional[str] = None
                       ) -> Optional[Tuple[Dict[str, Any], float]]:
    """Best stored (block config, measured step time) for a kernel cell.

    ``shape_sig=None`` relaxes to any tuned shape of this kernel on this
    device (minimum over cells) — how a server picks blocks for a problem
    shape that was never tuned exactly. Returns None on a cold store."""
    from repro.store.records import TuningRecordStore
    if isinstance(store, str):
        if not os.path.exists(store):
            return None
        store = TuningRecordStore(store, lazy=True)
    device = device or device_kind()
    want = (kernel_cell_objective(kernel, shape_sig, device)
            if shape_sig is not None else None)
    prefix = f"kernel[{kernel}×"
    suffix = f"×{device}]"
    best: Optional[Tuple[Dict[str, Any], float]] = None
    for digest, desc in store.fingerprints().items():
        obj = desc.objective
        if want is not None:
            if obj != want:
                continue
        elif not (obj.startswith(prefix) and obj.endswith(suffix)):
            continue
        hit = store.best_config(digest)
        if hit is not None and (best is None or hit[1] < best[1]):
            best = hit
    return best


def tuned_gp_block_n(store, N: Optional[int] = None,
                     device: Optional[str] = None,
                     default: int = 512) -> int:
    """Tuned matern_gp ``block_n`` for the self-hosted GP backend; falls
    back to the kernel default on a cold store. ``N`` (candidate count)
    only filters to block sizes that could tile it."""
    hit = best_kernel_config(store, "gp", None, device)
    if hit is None:
        return default
    bn = int(hit[0]["block_n"])
    if N is not None and bn > N:
        return default
    return bn


def kernel_config_from_store(store, *, S: int, hd: int,
                             device: Optional[str] = None):
    """Resolve a ``KernelConfig`` for a serving cell's prefill problem
    (sequence length ``S``, head dim ``hd``) from stored flash-cell tunings.
    None when the store has no usable record (caller keeps pure-JAX)."""
    from repro.parallel.sharding import KernelConfig
    hit = best_kernel_config(store, "flash", None, device)
    if hit is None:
        return None
    cfg = hit[0]
    bq, bkv = int(cfg["block_q"]), int(cfg["block_kv"])
    if S % bq != 0 or S % bkv != 0:
        return None             # tuned blocks don't tile this server's S
    if not ops.flash_valid({"block_q": bq, "block_kv": bkv}, hd):
        return None
    return KernelConfig(use_flash=True, flash_block_q=bq, flash_block_kv=bkv)


def decode_kernel_config_from_store(store, *, cache_cap: int, H: int, KV: int,
                                    hd: int, device: Optional[str] = None,
                                    base=None):
    """Resolve tuned decode blocks for a serving cell's cache shape from
    stored decode-cell tunings, overlaid on ``base`` (so a server can carry
    both tuned flash AND tuned decode blocks in one ``KernelConfig``).
    None when no stored record is usable for this cache (caller keeps the
    pure-JAX decode path)."""
    from repro.parallel.sharding import KernelConfig
    hit = best_kernel_config(store, "decode", None, device)
    if hit is None:
        return None
    cfg = hit[0]
    bkv, ns = int(cfg["block_kv"]), int(cfg["num_splits"])
    if bkv * (ns - 1) >= cache_cap:
        return None             # tuned splits overhang this server's cache
    G = H // max(KV, 1)
    if not ops.decode_valid({"block_kv": bkv}, G, hd):
        return None
    base = base if base is not None else KernelConfig()
    return base.replace(use_decode=True, decode_block_kv=bkv,
                        decode_num_splits=ns,
                        decode_combine=str(cfg["combine"]))
