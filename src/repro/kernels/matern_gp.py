"""Fused Matérn GP posterior Pallas TPU kernel.

The paper's §III-G hot loop: "we exhaustively predict every discrete point in
the model" — posterior mean/variance over ALL candidate configs, every
iteration. This kernel fuses, per candidate tile resident in VMEM:

    pairwise distance (obs × cand)  →  Matérn ν covariance  →
    V = L⁻¹K (triangular matmul against preloaded L⁻¹ rows)  →
    mean = Vᵀw  and  var = 1 − Σ V²

Observations (t ≤ 256 padded, masked) stay resident; candidates stream in
`block_n` tiles. Both matmuls are MXU-shaped (T×d @ d×bn and T×T @ T×bn).
Tunable: block_n (VMEM capacity trade-off). Oracle: repro.kernels.ref.gp_posterior.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SQRT3 = math.sqrt(3.0)
SQRT5 = math.sqrt(5.0)


def _matern(r, ell: float, nu: str):
    s = r / ell
    if nu == "matern12":
        return jnp.exp(-s)
    if nu == "matern32":
        t = SQRT3 * s
        return (1.0 + t) * jnp.exp(-t)
    if nu == "matern52":
        t = SQRT5 * s
        return (1.0 + t + (5.0 / 3.0) * jnp.square(s)) * jnp.exp(-t)
    if nu == "rbf":
        return jnp.exp(-0.5 * jnp.square(s))
    raise ValueError(nu)


def _gp_kernel(xc_ref, xo_ref, vinv_ref, w_ref, mask_ref,
               mean_ref, var_ref, *, ell: float, nu: str):
    xc = xc_ref[...]                                  # (bn, d)
    xo = xo_ref[...]                                  # (T, d)
    mask = mask_ref[...]                              # (T, 1) 1.0/0.0
    d2 = (jnp.sum(xo * xo, axis=1, keepdims=True)
          + jnp.sum(xc * xc, axis=1)[None, :]
          - 2.0 * jnp.dot(xo, xc.T, preferred_element_type=jnp.float32))
    r = jnp.sqrt(jnp.maximum(d2, 0.0))
    K = _matern(r, ell, nu) * mask                    # (T, bn), padded rows 0
    V = jnp.dot(vinv_ref[...], K, preferred_element_type=jnp.float32)
    mean_ref[...] = (w_ref[...] * V).sum(axis=0, keepdims=True)   # (1, bn)
    var_ref[...] = jnp.maximum(1.0 - jnp.sum(V * V, axis=0, keepdims=True),
                               1e-12)


def gp_posterior(x_cand: jax.Array, x_obs: jax.Array, vinv_rows: jax.Array,
                 w: jax.Array, mask: jax.Array, *, ell: float = 2.0,
                 nu: str = "matern32", block_n: int = 512,
                 interpret: bool = False):
    """x_cand (N,d); x_obs (T,d) padded; vinv_rows = L⁻¹ (T,T) with identity
    on padded rows; w (T,) = L⁻¹ỹ zero-padded; mask (T,) 1 for real obs.
    Returns (mean (N,), var (N,))."""
    N, d = x_cand.shape
    T = x_obs.shape[0]
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",))
    mean, var = pl.pallas_call(
        functools.partial(_gp_kernel, ell=ell, nu=nu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((T, d), lambda i: (0, 0)),
            pl.BlockSpec((T, T), lambda i: (0, 0)),
            pl.BlockSpec((T, 1), lambda i: (0, 0)),
            pl.BlockSpec((T, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, N), jnp.float32),
            jax.ShapeDtypeStruct((1, N), jnp.float32),
        ],
        interpret=interpret,
        **kw,
    )(x_cand, x_obs, vinv_rows, w[:, None], mask[:, None])
    return mean[0], var[0]


def gp_vmem_bytes(block_n: int, T: int, d: int) -> int:
    return 4 * (block_n * d + T * d + T * T + 2 * T + block_n * (T + 2))
