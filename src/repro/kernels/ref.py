"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

SQRT3 = math.sqrt(3.0)
SQRT5 = math.sqrt(5.0)


# -- tiled GEMM -------------------------------------------------------------

def gemm(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


# -- flash attention ---------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True) -> jax.Array:
    """q,k,v (B,S,H,hd) same head counts (MHA core). fp32 softmax."""
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


# -- Matérn GP posterior (the paper's exhaustive-prediction hot loop) --------

def matern_cov(r: jax.Array, ell: float, nu: str = "matern32") -> jax.Array:
    s = r / ell
    if nu == "matern12":
        return jnp.exp(-s)
    if nu == "matern32":
        t = SQRT3 * s
        return (1.0 + t) * jnp.exp(-t)
    if nu == "matern52":
        t = SQRT5 * s
        return (1.0 + t + (5.0 / 3.0) * jnp.square(s)) * jnp.exp(-t)
    if nu == "rbf":
        return jnp.exp(-0.5 * jnp.square(s))
    raise ValueError(nu)


def gp_posterior(x_cand: jax.Array, x_obs: jax.Array, vinv_rows: jax.Array,
                 w: jax.Array, ell: float, nu: str = "matern32"
                 ) -> Tuple[jax.Array, jax.Array]:
    """Posterior over candidates given precomputed L^-1 rows.

    x_cand (N,d), x_obs (t,d), vinv_rows = L^{-1} (t,t) lower, w = L^{-1}y (t,)
    mean = (L^{-1}K_oc)^T w ; var = 1 - colsum((L^{-1}K_oc)^2)
    """
    d2 = (jnp.sum(x_obs * x_obs, 1)[:, None] + jnp.sum(x_cand * x_cand, 1)[None, :]
          - 2.0 * (x_obs @ x_cand.T))
    r = jnp.sqrt(jnp.maximum(d2, 0.0))
    K = matern_cov(r, ell, nu)               # (t, N)
    V = vinv_rows @ K                         # (t, N)
    mean = V.T @ w
    var = jnp.maximum(1.0 - jnp.sum(V * V, axis=0), 1e-12)
    return mean, var
