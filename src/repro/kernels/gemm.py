"""Tiled GEMM Pallas TPU kernel — the paper's GEMM tuning target, TPU-native.

The CLBlast OpenCL GEMM the paper tunes exposes thread-block/vector-width
parameters; the TPU re-parameterization (DESIGN.md §2) is MXU tile shapes:
(block_m, block_n, block_k) must satisfy VMEM capacity and 128-alignment —
misconfigured tiles are the TPU analogue of the paper's invalid
configurations. `repro.kernels.ops.gemm_config_space()` exposes this as a
BO search space.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm(a: jax.Array, b: jax.Array, *, block_m: int = 256,
         block_n: int = 256, block_k: int = 256,
         interpret: bool = False) -> jax.Array:
    """C = A @ B with explicit VMEM tiling. A (M,K), B (K,N)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        f"dims ({M},{N},{K}) not divisible by blocks "
        f"({block_m},{block_n},{block_k})")
    k_steps = K // block_k
    grid = (M // block_m, N // block_n, k_steps)

    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
        **kw,
    )(a, b)


def gemm_vmem_bytes(block_m: int, block_n: int, block_k: int,
                    dtype_bytes: int = 2) -> int:
    """VMEM working set: A+B tiles (dtype) + fp32 accumulator + C tile."""
    return (block_m * block_k + block_k * block_n) * dtype_bytes \
        + block_m * block_n * 4 + block_m * block_n * dtype_bytes
