"""Initial sampling (paper §III-E): maximin Latin Hypercube + random repair.

LHS spreads the initial samples evenly; invalid/duplicate draws are replaced
by random valid samples so the initial sample is never skewed by invalidity.
"""
from __future__ import annotations

from typing import List, Optional, Set

import numpy as np

from repro.core.searchspace import SearchSpace


def lhs_unit(n: int, d: int, rng: np.random.Generator,
             maximin_tries: int = 10) -> np.ndarray:
    """Maximin LHS in [0,1]^d: best of `maximin_tries` by min pairwise dist."""
    best, best_score = None, -1.0
    for _ in range(max(maximin_tries, 1)):
        pts = np.empty((n, d), np.float32)
        for j in range(d):
            perm = rng.permutation(n)
            pts[:, j] = (perm + rng.random(n)) / n
        if n > 1:
            diff = pts[:, None, :] - pts[None, :, :]
            d2 = np.sum(diff * diff, axis=-1)
            np.fill_diagonal(d2, np.inf)
            score = float(d2.min())
        else:
            score = 0.0
        if score > best_score:
            best, best_score = pts, score
    return best


#: Above this many configs, snapping falls back to one chunked batch pass
#: (duplicate snaps are dropped and repaired randomly, like invalid draws)
#: instead of n per-point full-space scans with exclusion. Set to the
#: pre-refactor max_enumeration cap: every space that was constructible
#: before the vectorized layer keeps its exact per-point path (and so its
#: seeded initial sample); only newly-reachable larger spaces batch-snap.
BATCH_SNAP_MIN_SIZE = 2_000_000


def initial_sample(space: SearchSpace, n: int, rng: np.random.Generator,
                   is_valid=None, maximin: bool = True) -> List[int]:
    """n distinct config indices: LHS-snapped, invalid repaired randomly."""
    pts = lhs_unit(n, space.dim, rng, maximin_tries=10 if maximin else 1)
    chosen: List[int] = []
    seen: Set[int] = set()
    if space.size > BATCH_SNAP_MIN_SIZE:
        for idx in space.nearest_indices(pts):
            idx = int(idx)
            if idx in seen or (is_valid is not None and not is_valid(idx)):
                continue
            seen.add(idx)
            chosen.append(idx)
    else:
        for row in pts:
            idx = space.nearest_index(row, exclude=seen)
            if idx in seen or (is_valid is not None and not is_valid(idx)):
                idx = None
            if idx is not None:
                seen.add(idx)
                chosen.append(idx)
    # random repair (paper: replace invalid samples with random samples
    # until all initial samples are valid)
    guard = 0
    while len(chosen) < n and guard < 100 * n:
        guard += 1
        idx = space.random_index(rng)
        if idx in seen:
            continue
        if is_valid is not None and not is_valid(idx):
            continue
        seen.add(idx)
        chosen.append(idx)
    return chosen
