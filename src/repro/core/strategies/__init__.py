"""Search strategies: the paper's BO + Kernel Tuner baselines + framework
analogues. All implement the ask/tell protocol (base.Strategy); they are
driven by repro.core.engine.ParallelTuningEngine, never run standalone."""
from repro.core.strategies.base import (GeneratorStrategy, Proposal, Strategy,
                                        StrategyContext)
from repro.core.strategies.baselines import (GeneticAlgorithm,
                                             MultiStartLocalSearch,
                                             RandomSearch, SimulatedAnnealing)
from repro.core.strategies.bo import BOConfig, BOStrategy
from repro.core.strategies.frameworks import GPHedgeSnapBO, UCBSnapBO


def make_strategy(name: str, **kw):
    """Factory used by benchmarks/examples/CLI."""
    if name in ("ei", "poi", "lcb", "multi", "advanced_multi"):
        return BOStrategy(BOConfig(acquisition=name, **kw))
    table = {
        "random": RandomSearch,
        "simulated_annealing": SimulatedAnnealing,
        "mls": MultiStartLocalSearch,
        "genetic_algorithm": GeneticAlgorithm,
        "bayesopt_ucb": UCBSnapBO,
        "skopt_gphedge": GPHedgeSnapBO,
    }
    if name not in table:
        raise KeyError(f"unknown strategy {name!r}")
    return table[name](**kw)


ALL_BO = ("ei", "multi", "advanced_multi")
ALL_BASELINES = ("random", "simulated_annealing", "mls", "genetic_algorithm")
ALL_FRAMEWORKS = ("bayesopt_ucb", "skopt_gphedge")
