"""Ask/tell strategy protocol (DESIGN.md §2).

The seed implementation inverted control the wrong way round: every strategy
owned a blocking ``run(run, rng)`` loop that called ``run.evaluate`` and was
terminated by a ``BudgetExhausted`` exception. That couples strategies to a
strictly sequential evaluator — one compile-and-run per iteration — which the
paper's own conclusion names as the bottleneck.

Here the evaluator drives the strategy instead:

    strategy.reset(ctx)                  # space, budget, rng, replayed journal
    while not done:
        props = strategy.suggest(n)      # <= n proposals, [] = exhausted
        ... evaluate (possibly in parallel, see repro.core.engine) ...
        strategy.observe(prop, value)    # one tell per accepted proposal,
                                         # in acceptance order

Proposals carry either a config index into the restricted space or a raw
config dict (constraint-unaware framework baselines). Observations arrive in
the exact order proposals were accepted, so a strategy that suggests one
config at a time under ``batch_size=1`` sees the identical interaction
sequence the old blocking loop produced — the golden-trace parity tests pin
this down bit-for-bit.

Two idioms are supported:

  * class-based (subclass ``Strategy``): needed for true batch suggestion
    (BO's constant-liar fantasies, GA generations, random permutations);
  * generator-based (subclass ``GeneratorStrategy``): a mechanical port of a
    sequential loop — ``v = run.evaluate(idx, af)`` becomes
    ``v = yield Proposal(idx, af)``. Inherently suggests one config per tell.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Generator, List, Optional, Sequence

import numpy as np

from repro.core.searchspace import SearchSpace


@dataclass(frozen=True)
class Proposal:
    """One requested evaluation: a space index OR a raw config dict."""
    idx: Optional[int] = None
    af: Optional[str] = None
    config: Optional[Dict[str, Any]] = None

    def __post_init__(self):
        if (self.idx is None) == (self.config is None):
            raise ValueError("Proposal needs exactly one of idx/config")


@dataclass
class StrategyContext:
    """Everything a strategy may read at reset time."""
    space: SearchSpace
    budget: int
    rng: np.random.Generator
    # journal replayed from a checkpoint: (idx-or-None, value) pairs, in order
    replayed: Sequence = field(default_factory=tuple)


@dataclass(frozen=True)
class WarmObservation:
    """One prior observation mapped into the current space (store layer).

    Exact-fingerprint records carry their original config index; cross-size
    records were nearest-neighbor matched into this space and carry the extra
    GP ``noise`` discounting the mapping (repro.store.transfer).
    """
    x: np.ndarray                # normalized position in the current space
    value: float                 # finite prior observation
    idx: Optional[int]           # matched config index in the current space
    exact: bool                  # same fingerprint: no mapping, no discount
    noise: float = 0.0           # extra GP noise (transfer discount)
    config: Optional[Dict[str, Any]] = None


class Strategy:
    """Ask/tell strategy ABC. Stateful; ``reset`` starts a fresh run."""

    name: str = "strategy"

    def reset(self, ctx: StrategyContext) -> None:
        raise NotImplementedError

    def suggest(self, n: int) -> List[Proposal]:
        """Up to ``n`` proposals. Empty list = strategy exhausted (the engine
        stops once nothing is in flight). Proposals may duplicate earlier
        evaluations — the evaluator serves those from cache."""
        raise NotImplementedError

    def observe(self, proposal: Proposal, value: float) -> None:
        """One tell per accepted proposal, in acceptance order. ``value`` is
        NaN for invalid configurations (they still consumed budget)."""
        raise NotImplementedError

    def warm_start(self, warm: Sequence[WarmObservation]) -> None:
        """Transfer-aware warm start: prior observations matched from the
        tuning-record store, mapped into the current space. Called at most
        once per run, after ``reset`` and before the first ``suggest`` —
        and only when matches exist, so cold-store runs never enter here
        (bit-for-bit identical to no-store runs). Default: ignore priors."""
        return None


class GeneratorStrategy(Strategy):
    """Port of a sequential blocking loop: override ``proposals`` with a
    generator that yields ``Proposal``s and receives observed values.

    ``suggest`` can only ever hand out the single proposal the generator is
    blocked on — the next one does not exist until the value is sent back —
    so these strategies parallelize across *runs*, not within one. That is
    exactly the contract the old ``run(run, rng)`` loops had.
    """

    def proposals(self, ctx: StrategyContext) -> Generator[Proposal, float, None]:
        raise NotImplementedError

    def reset(self, ctx: StrategyContext) -> None:
        self._gen = self.proposals(ctx)
        self._pending: Optional[Proposal] = None
        self._exhausted = False
        self._advance(first=True)

    def _advance(self, first: bool = False, value: float = math.nan):
        try:
            self._pending = (next(self._gen) if first
                             else self._gen.send(value))
        except StopIteration:
            self._pending, self._exhausted = None, True

    def suggest(self, n: int) -> List[Proposal]:
        if self._exhausted or self._pending is None:
            return []
        p, self._pending = self._pending, None
        return [p]

    def observe(self, proposal: Proposal, value: float) -> None:
        if not self._exhausted:
            self._advance(value=value)
