"""Other-framework BO analogues (paper §IV-D), ask/tell generator ports.

The paper compares against the BayesianOptimization and scikit-optimize
packages, whose defaults (a) cannot express search-space constraints — they
model the full Cartesian box — and (b) optimize the acquisition over a
continuous relaxation and SNAP to the grid, exactly the failure mode §III-D1
warns about (duplicate suggestions, distorted surrogate). Invalid/infeasible
evaluations are imputed with a large penalty — distorting the surrogate
(§III-D2) — because these frameworks must fit *something*.

  * UCBSnapBO  ≈ BayesianOptimization defaults: UCB(κ=2.576)
  * GPHedgeSnapBO ≈ scikit-optimize defaults: GP-Hedge over (EI ξ=0.01,
    PI ξ=0.01, LCB κ=1.96), softmax gains

These propose raw config dicts (``Proposal(config=...)``): the evaluator maps
them back into the restricted space where possible and records NaN otherwise,
so infeasible proposals waste budget — the paper's explanation for these
frameworks' poor showing.
"""
from __future__ import annotations

import math
from typing import Generator, List

import numpy as np

from repro.core import acquisition as A
from repro.core.gp import GP
from repro.core.searchspace import SearchSpace
from repro.core.strategies.base import (GeneratorStrategy, Proposal,
                                        StrategyContext)


def _unrestricted(space: SearchSpace) -> SearchSpace:
    """The Cartesian box (restrictions dropped), as these frameworks see it."""
    return SearchSpace(space.params, (), name=space.name + "_box")


class _SnapBOBase(GeneratorStrategy):
    n_init: int = 20
    penalty_quantile: float = 0.99

    def __init__(self):
        self.name = "framework_bo"

    def _propose(self, gp: GP, box: SearchSpace, evaluated: np.ndarray,
                 f_best: float, rng: np.random.Generator, it: int) -> int:
        raise NotImplementedError

    def proposals(self, ctx: StrategyContext) -> Generator[Proposal, float, None]:
        rng = ctx.rng
        box = _unrestricted(ctx.space)
        # continuous-snap duplicates make the kernel matrix singular — the
        # frameworks survive via jitter, so use a larger noise term here
        gp = GP(box.dim, max_obs=ctx.budget + 8, kernel="matern52", ell=1.0,
                noise=1e-4)
        evaluated = np.zeros(box.size, dtype=bool)
        values: List[float] = []

        def observe(bidx: int, v: float):
            evaluated[bidx] = True
            if math.isfinite(v):
                values.append(v)
                gp.add(box.X_norm[bidx], v)
            else:
                # constraint-unaware frameworks impute a penalty — the
                # surrogate distortion the paper describes
                pen = (np.quantile(values, self.penalty_quantile) * 2.0
                       if values else 1e6)
                gp.add(box.X_norm[bidx], float(pen))

        for _ in range(self.n_init):
            bidx = box.random_index(rng)
            if evaluated[bidx]:
                continue
            v = yield Proposal(config=box.config(bidx), af=self.name)
            observe(bidx, v)

        it = 0
        while True:
            it += 1
            gp.fit()
            f_best = min(values) if values else 1e6
            bidx = self._propose(gp, box, evaluated, f_best, rng, it)
            v = yield Proposal(config=box.config(bidx), af=self.name)
            observe(bidx, v)


class UCBSnapBO(_SnapBOBase):
    """BayesianOptimization-like: UCB κ=2.576, continuous argmax + snap."""

    def __init__(self, kappa: float = 2.576):
        self.kappa = kappa
        self.name = "bayesopt_ucb"

    def _propose(self, gp, box, evaluated, f_best, rng, it):
        # continuous optimization emulated by dense random restarts + local
        # refinement, then SNAP to the grid (duplicates possible -> they
        # repeatedly hit the cache, wasting their iteration, like the paper
        # observes for these frameworks)
        cand = rng.random((2048, box.dim)).astype(np.float32)
        mu, sigma = gp.predict(cand)
        scores = np.asarray(mu) - self.kappa * np.asarray(sigma)
        x = cand[int(np.argmin(scores))]
        return box.nearest_index(x)


class GPHedgeSnapBO(_SnapBOBase):
    """scikit-optimize-like GP-Hedge portfolio with softmax gains."""

    def __init__(self, eta: float = 1.0):
        self.eta = eta
        self.gains = np.zeros(3)
        self.name = "skopt_gphedge"

    def reset(self, ctx: StrategyContext) -> None:
        self.gains = np.zeros(3)   # fresh hedge state per run
        super().reset(ctx)

    def _propose(self, gp, box, evaluated, f_best, rng, it):
        cand = rng.random((2048, box.dim)).astype(np.float32)
        mu, sigma = gp.predict(cand)
        mu = np.asarray(mu); sigma = np.asarray(sigma)
        y_std = float(gp.state.y_std) if gp.state is not None else 1.0
        props = [
            int(np.argmax(A.ei_scores(mu, sigma, f_best, 0.01, y_std))),
            int(np.argmax(A.poi_scores(mu, sigma, f_best, 0.01, y_std))),
            int(np.argmin(mu - 1.96 * sigma)),
        ]
        self.gains = np.nan_to_num(self.gains, nan=0.0, posinf=0.0, neginf=0.0)
        p = np.exp(self.eta * (self.gains - self.gains.max()))
        s = p.sum()
        p = p / s if np.isfinite(s) and s > 0 else np.full(3, 1 / 3)
        k = int(rng.choice(3, p=p))
        x = cand[props[k]]
        # hedge gain update: negative posterior mean at the chosen point
        mu_k, _ = gp.predict(x[None, :])
        g = -float(np.asarray(mu_k)[0])
        if np.isfinite(g):
            self.gains[k] += g
        return box.nearest_index(x)
