"""The paper's Bayesian Optimization search strategy (§III).

Structure (paper's contributions all present):
  * discrete normalized search space; acquisition optimized ONLY over
    not-yet-evaluated configs by exhaustive prediction (no BFGS);
  * invalid observations consume budget but are never fitted to the GP;
  * maximin-LHS initial sample with random repair of invalid draws;
  * Matérn-3/2 GP, fixed lengthscale 2.0 (1.5 under contextual variance);
  * exploration factor: constant or Contextual Variance;
  * acquisition: ei | poi | lcb | multi | advanced_multi (Table I defaults).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import acquisition as A
from repro.core.gp import GP
from repro.core.gp_fast import IncrementalGP
from repro.core.lhs import initial_sample
from repro.core.runner import BudgetExhausted, TuningRun


@dataclass(frozen=True)
class BOConfig:
    acquisition: str = "advanced_multi"   # ei|poi|lcb|multi|advanced_multi
    kernel: str = "matern32"
    lengthscale: float = 2.0
    lengthscale_cv: float = 1.5
    exploration: object = "cv"            # "cv" or a float
    initial_samples: int = 20
    maximin: bool = True
    skip_threshold: int = 5
    improvement_factor: float = 0.1
    discount: Optional[float] = None      # None -> per-mode Table I default
    af_order: Sequence[str] = ("ei", "poi", "lcb")
    noise: float = 1e-6
    # "fast": incremental-Cholesky exact GP (beyond-paper, ~100x less work);
    # "jax": padded jit GP (the oracle; also what the Pallas kernel mirrors)
    engine: str = "fast"


class _EngineAdapter:
    """Uniform .add / .predict_all / .y_std over both GP engines."""

    def __init__(self, cfg: BOConfig, X_cand: np.ndarray, max_obs: int, ell: float):
        self.jax_mode = cfg.engine == "jax"
        self.X_cand = X_cand
        if self.jax_mode:
            self.gp = GP(X_cand.shape[1], max_obs=max_obs, kernel=cfg.kernel,
                         ell=ell, noise=cfg.noise)
        else:
            self.gp = IncrementalGP(X_cand, max_obs=max_obs, kernel=cfg.kernel,
                                    ell=ell, noise=cfg.noise)

    def add(self, x, y):
        self.gp.add(x, y)

    def predict_all(self):
        if self.jax_mode:
            mu, sigma = self.gp.predict(self.X_cand)
            return np.asarray(mu, np.float64), np.asarray(sigma, np.float64)
        return self.gp.predict()

    @property
    def y_std(self) -> float:
        if self.jax_mode:
            self.gp.fit() if self.gp.state is None else None
            return float(self.gp.state.y_std)
        return self.gp.y_std


class BOStrategy:
    def __init__(self, cfg: BOConfig = BOConfig(), name: Optional[str] = None):
        self.cfg = cfg
        self.name = name or f"bo_{cfg.acquisition}"

    # -----------------------------------------------------------------
    def run(self, run: TuningRun, rng: np.random.Generator):
        cfg = self.cfg
        space = run.space
        ell = (cfg.lengthscale_cv if cfg.exploration == "cv"
               else cfg.lengthscale)
        gp = _EngineAdapter(cfg, space.X_norm, max_obs=run.budget, ell=ell)
        evaluated = np.zeros(space.size, dtype=bool)

        def observe(idx: int, value: float):
            evaluated[idx] = True
            if math.isfinite(value):
                gp.add(space.X_norm[idx], value)

        # resume support: absorb any journal replayed into the run
        for o in run.journal:
            if o.idx is not None:
                observe(o.idx, o.value)

        # ---- initial sample (LHS maximin + random repair) ----
        n_init = max(cfg.initial_samples - int(evaluated.sum()), 0)
        init_vals = []
        if n_init > 0:
            for idx in initial_sample(space, n_init, rng, maximin=cfg.maximin):
                v = run.evaluate(idx, af="init")
                observe(idx, v)
                if math.isfinite(v):
                    init_vals.append(v)
            # paper: replace invalid draws with random samples until all valid
            guard = 0
            while len(init_vals) < n_init and guard < 20 * n_init:
                guard += 1
                idx = space.random_index(rng)
                if evaluated[idx]:
                    continue
                v = run.evaluate(idx, af="init")
                observe(idx, v)
                if math.isfinite(v):
                    init_vals.append(v)
        else:
            init_vals = [o.value for o in run.journal if math.isfinite(o.value)]
        if not init_vals:  # pathological space: no valid init found
            init_vals = [1.0]
        mu_s = float(np.mean(init_vals))

        _, sigma0 = gp.predict_all()
        var_s = float(np.mean(np.square(np.asarray(sigma0))))

        # ---- acquisition controller ----
        mode = cfg.acquisition
        controller = None
        if mode in ("multi", "advanced_multi"):
            controller = A.MultiAcquisition(
                mode="advanced" if mode == "advanced_multi" else "multi",
                order=cfg.af_order, skip_threshold=cfg.skip_threshold,
                improvement_factor=cfg.improvement_factor,
                discount=cfg.discount)

        # ---- optimization loop ----
        while True:
            mu, sigma = gp.predict_all()
            _, f_best = run.best()
            if not math.isfinite(f_best):
                f_best = mu_s
            y_std = gp.y_std

            if cfg.exploration == "cv":
                explore = A.contextual_variance(sigma[~evaluated], f_best,
                                                mu_s, var_s)
            else:
                explore = float(cfg.exploration)

            def pick(af_name: str) -> int:
                scores = A.af_scores(af_name, mu, sigma, f_best, explore, y_std)
                scores = np.where(evaluated, -np.inf, scores)
                return int(np.argmax(scores))

            if controller is None:
                idx = pick(mode)
                v = run.evaluate(idx, af=mode)
                observe(idx, v)
            elif controller.mode == "multi":
                noms = {a.name: pick(a.name) for a in controller.active_afs()}
                controller.register_duplicates(noms)
                af = controller.next_af()
                idx = noms.get(af.name, pick(af.name))
                v = run.evaluate(idx, af=af.name)
                observe(idx, v)
                controller.record(af, v, math.isfinite(v))
            else:  # advanced multi: only the evaluating AF predicts
                af = controller.next_af()
                idx = pick(af.name)
                v = run.evaluate(idx, af=af.name)
                observe(idx, v)
                controller.record(af, v, math.isfinite(v))

            if bool(evaluated.all()):
                raise BudgetExhausted
