"""The paper's Bayesian Optimization search strategy (§III), ask/tell form.

Structure (paper's contributions all present):
  * discrete normalized search space; acquisition optimized ONLY over
    not-yet-evaluated configs by exhaustive prediction (no BFGS);
  * invalid observations consume budget but are never fitted to the GP;
  * maximin-LHS initial sample with random repair of invalid draws;
  * Matérn-3/2 GP, fixed lengthscale 2.0 (1.5 under contextual variance);
  * exploration factor: constant or Contextual Variance;
  * acquisition: ei | poi | lcb | multi | advanced_multi (Table I defaults).

Beyond the paper (DESIGN.md §3–4): ``suggest(n)`` with n > 1 builds a batch
by kriging-believer fantasies — each pick is speculatively added to the GP at
its posterior mean, the acquisition is re-scored, and the speculative
observations are rolled back once the batch is out the door. In-flight
configs (suggested earlier, not yet observed) are fantasized the same way, so
asynchronous engines never get duplicate suggestions and the batch spreads
out instead of piling onto one optimum. At ``batch_size=1`` no speculation
happens and the interaction sequence is bit-for-bit the sequential paper
loop (pinned by the golden-trace tests).

Candidate-pool mode (DESIGN.md §10): above ``pool_threshold`` configs the
exhaustive per-iteration prediction is replaced by scoring a pool of
incumbent neighborhoods + stratified random draws + a periodic LHS refresh,
with the GP predicting only at pool points (chunked, no (max_obs, N)
panel). Small spaces keep the full-space path untouched, so paper-parity
results are unchanged.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import acquisition as A
from repro.core.gp import GP
from repro.core.gp_fast import IncrementalGP
from repro.core.lhs import initial_sample, lhs_unit
from repro.core.strategies.base import Proposal, Strategy, StrategyContext


@dataclass(frozen=True)
class BOConfig:
    acquisition: str = "advanced_multi"   # ei|poi|lcb|multi|advanced_multi
    kernel: str = "matern32"
    lengthscale: float = 2.0
    lengthscale_cv: float = 1.5
    exploration: object = "cv"            # "cv" or a float
    initial_samples: int = 20
    maximin: bool = True
    skip_threshold: int = 5
    improvement_factor: float = 0.1
    discount: Optional[float] = None      # None -> per-mode Table I default
    af_order: Sequence[str] = ("ei", "poi", "lcb")
    noise: float = 1e-6
    # "fast": incremental-Cholesky exact GP (beyond-paper, ~100x less work);
    # "jax": padded jit GP (the oracle; also what the Pallas kernel mirrors)
    engine: str = "fast"
    # -- self-hosted posterior scoring (DESIGN.md §14) -----------------------
    # "numpy" | "pallas": fast-engine backend for the §III-G exhaustive
    # prediction loop; "pallas" runs it through the fused matern_gp kernel,
    # block_n ideally from the kernel-tuning store (tuned_gp_block_n)
    gp_backend: str = "numpy"
    gp_block_n: int = 512
    # -- candidate-pool acquisition (DESIGN.md §10) --------------------------
    pool_mode: str = "auto"               # "auto" | "full" | "pool"
    pool_threshold: int = 100_000         # auto: pool above this many configs
    pool_size: int = 2048                 # stratified random draws per round
    pool_incumbents: int = 3              # best-k whose neighborhoods join
    pool_lhs_every: int = 16              # LHS refresh cadence (rounds)
    pool_lhs_points: int = 64
    # -- surrogate-guided pool seeding (DESIGN.md §15) -----------------------
    # after warmup, a slice of each round's pool comes from coordinate-
    # exchange refinement of the GP's top-k posterior-mean incumbents; each
    # exchange step is validated by the space's per-dimension pruner
    # (axis_exchange), never by rejection draws
    pool_refine_topk: int = 3             # posterior-mean incumbents refined
    pool_refine_steps: int = 2            # exchange sweeps per incumbent
    pool_refine_max: int = 256            # refined-candidate cap per round
    predict_chunk: int = 8192             # jax-engine pool prediction chunk
    # -- transfer-aware warm start (DESIGN.md §11) ---------------------------
    warm_topk: int = 5                    # prior best configs re-evaluated first
    warm_min_init: int = 3                # LHS floor kept under warm priors

    def pool_active(self, space_size: int) -> bool:
        return (self.pool_mode == "pool"
                or (self.pool_mode == "auto"
                    and space_size > self.pool_threshold))


def _stratified_indices(n: int, m: int, rng: np.random.Generator) -> np.ndarray:
    """m draws, one uniform per equal-width stratum of [0, n) — spreads
    coverage over the enumeration order (and so over the leading params)."""
    m = min(m, n)
    edges = np.linspace(0, n, m + 1).astype(np.int64)
    return rng.integers(edges[:-1], np.maximum(edges[1:], edges[:-1] + 1))


class _SparseFlags:
    """Set-backed stand-in for a dense boolean flag array.

    The generative backend keys configs by mixed-radix code over grids with
    10^9+ cells; ``np.zeros(space.size, bool)`` would be gigabytes for a
    handful of set flags. Supports exactly the access patterns BOStrategy
    uses — scalar get/set, fancy-index get, ``sum()``, and enumeration of
    the set indices (sorted, matching ``np.flatnonzero`` semantics).
    """

    __slots__ = ("_set",)

    def __init__(self):
        self._set: set = set()

    def __getitem__(self, key):
        if isinstance(key, (int, np.integer)):
            return int(key) in self._set
        key = np.asarray(key)
        if not self._set:
            return np.zeros(key.shape, bool)
        return np.isin(key, np.fromiter(self._set, np.int64,
                                        count=len(self._set)))

    def __setitem__(self, key, value):
        if value:
            self._set.add(int(key))
        else:
            self._set.discard(int(key))

    def sum(self) -> int:
        return len(self._set)

    def indices(self) -> np.ndarray:
        if not self._set:
            return np.zeros(0, np.int64)
        return np.sort(np.fromiter(self._set, np.int64, count=len(self._set)))


def _flag_indices(flags) -> np.ndarray:
    """Set indices of a dense bool array or a _SparseFlags, sorted."""
    if isinstance(flags, _SparseFlags):
        return flags.indices()
    return np.flatnonzero(flags)


class _EngineAdapter:
    """Uniform .add / .predict_all / .predict_at / .y_std / .mark /
    .rollback over both GP engines. ``X_cand=None`` selects candidate-pool
    mode: no fixed candidate panel, prediction only at requested points."""

    def __init__(self, cfg: BOConfig, X_cand: Optional[np.ndarray],
                 max_obs: int, ell: float, dim: Optional[int] = None):
        self.jax_mode = cfg.engine == "jax"
        self.X_cand = X_cand
        self._chunk = cfg.predict_chunk
        if self.jax_mode:
            d = X_cand.shape[1] if X_cand is not None else dim
            self.gp = GP(d, max_obs=max_obs, kernel=cfg.kernel,
                         ell=ell, noise=cfg.noise)
        else:
            self.gp = IncrementalGP(X_cand, max_obs=max_obs, kernel=cfg.kernel,
                                    ell=ell, noise=cfg.noise, dim=dim,
                                    backend=cfg.gp_backend,
                                    block_n=cfg.gp_block_n)

    def add(self, x, y, extra_noise: float = 0.0):
        self.gp.add(x, y, extra_noise)

    def mark(self):
        self.gp.mark()

    def rollback(self):
        self.gp.rollback()

    def predict_all(self):
        if self.jax_mode:
            mu, sigma = self.gp.predict(self.X_cand)
            return np.asarray(mu, np.float64), np.asarray(sigma, np.float64)
        return self.gp.predict()

    def predict_at(self, X: np.ndarray):
        if self.jax_mode:
            return self.gp.predict_chunked(X, chunk=self._chunk)
        return self.gp.predict_at(X)

    @property
    def y_std(self) -> float:
        if self.jax_mode:
            if self.gp.state is None:
                self.gp.fit()
            return float(self.gp.state.y_std)
        return self.gp.y_std


class BOStrategy(Strategy):
    def __init__(self, cfg: BOConfig = BOConfig(), name: Optional[str] = None):
        self.cfg = cfg
        self.name = name or f"bo_{cfg.acquisition}"

    # -- lifecycle ----------------------------------------------------------
    def reset(self, ctx: StrategyContext) -> None:
        cfg = self.cfg
        self.space = ctx.space
        self.rng = ctx.rng
        self._budget = ctx.budget
        ell = (cfg.lengthscale_cv if cfg.exploration == "cv"
               else cfg.lengthscale)
        # the generative backend has no dense candidate panel at all, so it
        # is always pool-mode regardless of the configured threshold
        self.pool_on = cfg.pool_active(ctx.space.size) or ctx.space.generative
        if self.pool_on:
            # no fixed candidate panel: an (max_obs, N) V matrix over a
            # multi-million-config space would not fit in memory
            self.gp = _EngineAdapter(cfg, None, max_obs=ctx.budget, ell=ell,
                                     dim=ctx.space.dim)
        else:
            self.gp = _EngineAdapter(cfg, ctx.space.X_norm, max_obs=ctx.budget,
                                     ell=ell)
        if ctx.space.generative:
            self.evaluated = _SparseFlags()
            self.pending = _SparseFlags()                    # in flight
        else:
            self.evaluated = np.zeros(ctx.space.size, dtype=bool)
            self.pending = np.zeros(ctx.space.size, dtype=bool)  # in flight
        self.f_best = math.inf
        self.controller: Optional[A.MultiAcquisition] = None
        self.mu_s = 0.0
        self.var_s = 0.0
        self._finite_obs: List[Tuple[float, int]] = []   # (value, idx)
        self._round = 0

        # resume support: absorb any journal replayed into the run
        replayed_vals: List[float] = []
        for idx, value in ctx.replayed:
            if idx is not None:
                self._absorb(int(idx), value)
            if math.isfinite(value):
                replayed_vals.append(value)

        self.n_init = max(cfg.initial_samples - int(self.evaluated.sum()), 0)
        self.init_vals: List[float] = []
        self._repair_guard = 0
        self._init_outstanding = 0
        if self.n_init > 0:
            self._phase = "init"
            self._init_queue = deque(
                initial_sample(ctx.space, self.n_init, ctx.rng,
                               maximin=cfg.maximin))
        else:
            self._phase = "init"      # finalized on first suggest()
            self._init_queue = deque()
            self.init_vals = replayed_vals

    def _absorb(self, idx: int, value: float):
        self.evaluated[idx] = True
        self.pending[idx] = False
        if math.isfinite(value):
            self.gp.add(self.space.X_norm[idx], value)
            self._finite_obs.append((value, idx))
            if value < self.f_best:
                self.f_best = value

    # -- transfer-aware warm start (DESIGN.md §11) --------------------------
    def warm_start(self, warm) -> None:
        """Prior store records into the surrogate + prior top-k into the
        initial sample.

        The GP is rebuilt with capacity for the priors and told every warm
        observation at its matched position — exact-fingerprint records at
        full weight, cross-size records with their transfer-discount noise —
        so the first acquisition round already knows the prior landscape.
        The best ``warm_topk`` prior configs are evaluated first (replacing
        LHS draws), and the budget-free priors shrink the LHS phase down to
        ``warm_min_init``: that is where the measured 30%+ evaluation saving
        on unseen scenarios comes from (benchmarks/warm_start.py)."""
        cfg = self.cfg
        warm = [w for w in warm
                if w.idx is not None and not self.evaluated[w.idx]]
        if not warm:
            return
        ell = (cfg.lengthscale_cv if cfg.exploration == "cv"
               else cfg.lengthscale)
        max_obs = self._budget + len(warm)
        if self.pool_on:
            self.gp = _EngineAdapter(cfg, None, max_obs=max_obs, ell=ell,
                                     dim=self.space.dim)
        else:
            self.gp = _EngineAdapter(cfg, self.space.X_norm, max_obs=max_obs,
                                     ell=ell)
        for w in warm:
            self.gp.add(w.x, float(w.value), extra_noise=float(w.noise))
        # re-absorb replayed real observations into the rebuilt surrogate
        for v, i in self._finite_obs:
            self.gp.add(self.space.X_norm[i], v)
        if self._phase == "init" and self._init_queue:
            seeds: List[int] = []
            for w in sorted(warm, key=lambda w: (not w.exact, w.value)):
                if w.idx not in seeds:
                    seeds.append(w.idx)
                if len(seeds) >= cfg.warm_topk:
                    break
            lhs_keep = max(
                max(cfg.warm_min_init, self.n_init - len(warm)) - len(seeds),
                0)
            kept = [i for i in list(self._init_queue)
                    if i not in seeds][:lhs_keep]
            self._init_queue = deque(seeds + kept)
            self.n_init = len(self._init_queue)

    def _finalize_init(self):
        """Initial sample complete: fix μ_s, σ̄²_s, build the AF controller."""
        cfg = self.cfg
        if not self.init_vals:  # pathological space: no valid init found
            self.init_vals = [1.0]
        self.mu_s = float(np.mean(self.init_vals))
        if self.pool_on:
            # σ̄²_s estimated on a stratified draw — the same estimator every
            # later pool round uses, so the contextual-variance ratio is
            # like-for-like (acquisition.pool_contextual_variance)
            probe = self._pool_strata(max(self.cfg.pool_size, 256))
            _, sigma0 = self.gp.predict_at(self.space.X_norm[probe])
        else:
            _, sigma0 = self.gp.predict_all()
        self.var_s = float(np.mean(np.square(np.asarray(sigma0))))
        if cfg.acquisition in ("multi", "advanced_multi"):
            self.controller = A.MultiAcquisition(
                mode="advanced" if cfg.acquisition == "advanced_multi"
                else "multi",
                order=cfg.af_order, skip_threshold=cfg.skip_threshold,
                improvement_factor=cfg.improvement_factor,
                discount=cfg.discount)
        self._phase = "bo"

    # -- ask ----------------------------------------------------------------
    def suggest(self, n: int) -> List[Proposal]:
        if self._phase == "init":
            props = self._suggest_init(n)
            if props or self._phase == "init":
                return props
            # fell through to bo on this very call
        if self.pool_on:
            return self._suggest_bo_pool(n)
        return self._suggest_bo(n)

    def _suggest_init(self, n: int) -> List[Proposal]:
        out: List[Proposal] = []
        while len(out) < n and self._init_queue:
            idx = int(self._init_queue.popleft())
            self.pending[idx] = True
            self._init_outstanding += 1
            out.append(Proposal(idx, af="init"))
        # paper: replace invalid draws with random samples until all valid.
        # Only once every earlier init proposal is observed do we know how
        # many repairs are still owed (invalid draws in flight may yet fail).
        if not out and self._init_outstanding == 0:
            need = self.n_init - len(self.init_vals)
            while (len(out) < min(n, max(need, 0))
                   and self._repair_guard < 20 * self.n_init):
                self._repair_guard += 1
                idx = self.space.random_index(self.rng)
                if self.evaluated[idx] or self.pending[idx]:
                    continue
                self.pending[idx] = True
                self._init_outstanding += 1
                out.append(Proposal(int(idx), af="init"))
            if not out:  # init done (or guard exhausted) -> switch phase
                self._finalize_init()
        return out

    def _suggest_bo(self, n: int) -> List[Proposal]:
        cfg = self.cfg
        out: List[Proposal] = []
        in_flight = np.flatnonzero(self.pending)
        speculate = n > 1 or in_flight.size > 0
        if speculate:
            self.gp.mark()
            if in_flight.size:
                # fantasize in-flight configs at their posterior mean so an
                # async engine never gets the same suggestion twice
                mu0, _ = self.gp.predict_all()
                for i in in_flight:
                    self.gp.add(self.space.X_norm[i], float(mu0[i]))
        try:
            for j in range(n):
                blocked = self.evaluated | self.pending
                if blocked.all():
                    break
                mu, sigma = self.gp.predict_all()
                f_best = self.f_best if math.isfinite(self.f_best) else self.mu_s
                y_std = self.gp.y_std

                if cfg.exploration == "cv":
                    if speculate:
                        explore = A.batch_contextual_variance(
                            np.asarray(sigma), self.evaluated, self.pending,
                            f_best, self.mu_s, self.var_s)
                    else:
                        explore = A.contextual_variance(
                            sigma[~self.evaluated], f_best, self.mu_s,
                            self.var_s)
                else:
                    explore = float(cfg.exploration)

                def pick(af_name: str) -> int:
                    scores = A.af_scores(af_name, mu, sigma, f_best, explore,
                                         y_std)
                    scores = np.where(blocked, -np.inf, scores)
                    return int(np.argmax(scores))

                controller = self.controller
                if controller is None:
                    af_name = cfg.acquisition
                    idx = pick(af_name)
                elif controller.mode == "multi":
                    noms = {a.name: pick(a.name)
                            for a in controller.active_afs()}
                    controller.register_duplicates(noms)
                    af = controller.next_af()
                    af_name = af.name
                    idx = noms.get(af.name, pick(af.name))
                else:  # advanced multi: only the evaluating AF predicts
                    af = controller.next_af()
                    af_name = af.name
                    idx = pick(af.name)

                self.pending[idx] = True
                out.append(Proposal(idx, af=af_name))
                if j < n - 1:
                    # kriging-believer fantasy for the remaining picks
                    self.gp.add(self.space.X_norm[idx], float(mu[idx]))
        finally:
            if speculate:
                self.gp.rollback()
        return out

    # -- ask, candidate-pool mode (DESIGN.md §10) ---------------------------
    def _pool_strata(self, m: int) -> np.ndarray:
        """Stratified coverage draws: dense positions on the enumerated
        backend, feasible codes (rejection-sampled per stratum) on the
        generative one."""
        if self.space.generative:
            return self.space.stratified_feasible(self.rng, m)
        return _stratified_indices(self.space.size, m, self.rng)

    def _refine_pool(self) -> Optional[np.ndarray]:
        """Coordinate-exchange refinement of the GP's top-k posterior-mean
        incumbents (ROADMAP "interaction-aware seed"). Each incumbent is
        walked one axis at a time: the move set comes from the space's
        ``axis_exchange`` — on the generative backend that is the
        constraint-propagating per-dimension pruner, so no rejection draws
        happen even on tightly-constrained grids — and the walk steps to
        the candidate with the best posterior mean. Every candidate the GP
        scored joins the pool (the interaction-aware slice), capped at
        ``pool_refine_max``."""
        cfg, space = self.cfg, self.space
        if (cfg.pool_refine_topk <= 0 or self._phase != "bo"
                or not self._finite_obs):
            return None
        obs = sorted({int(i) for _, i in self._finite_obs})
        mu_obs, _ = self.gp.predict_at(space.X_norm[np.asarray(obs, np.int64)])
        order = np.argsort(mu_obs)[:cfg.pool_refine_topk]
        out: List[int] = []
        seen: set = set()
        for k in order:
            idx, cur_mu = obs[int(k)], float(mu_obs[int(k)])
            for _ in range(max(cfg.pool_refine_steps, 1)):
                moved = False
                for j in self.rng.permutation(space.dim):
                    cands = space.axis_exchange(idx, int(j))
                    if not cands:
                        continue
                    mu_c, _ = self.gp.predict_at(
                        space.X_norm[np.asarray(cands, np.int64)])
                    for c in cands:
                        if c not in seen and len(out) < cfg.pool_refine_max:
                            seen.add(c)
                            out.append(int(c))
                    b = int(np.argmin(mu_c))
                    if float(mu_c[b]) < cur_mu:
                        idx, cur_mu = int(cands[b]), float(mu_c[b])
                        moved = True
                if not moved or len(out) >= cfg.pool_refine_max:
                    break
            if len(out) >= cfg.pool_refine_max:
                break
        return np.asarray(out, np.int64) if out else None

    def _build_pool(self) -> np.ndarray:
        """Pool = incumbent Hamming neighborhoods + coordinate-exchange
        refinement of the GP's top posterior-mean incumbents + stratified
        random draws (+ periodic LHS refresh), minus evaluated/pending
        configs."""
        cfg, space, rng = self.cfg, self.space, self.rng
        parts: List[np.ndarray] = []
        if self._finite_obs and cfg.pool_incumbents > 0:
            for _, i in heapq.nsmallest(cfg.pool_incumbents, self._finite_obs):
                nbrs = space.hamming_neighbors(int(i))
                if nbrs:
                    parts.append(np.asarray(nbrs, np.int64))
        refined = self._refine_pool()
        if refined is not None and refined.size:
            parts.append(refined)
        parts.append(self._pool_strata(cfg.pool_size))
        if (cfg.pool_lhs_points > 0
                and self._round % max(cfg.pool_lhs_every, 1) == 0):
            pts = lhs_unit(cfg.pool_lhs_points, space.dim, rng,
                           maximin_tries=1)
            parts.append(space.nearest_indices(pts))
        pool = np.unique(np.concatenate(parts))
        pool = pool[~(self.evaluated[pool] | self.pending[pool])]
        if pool.size == 0:
            if space.generative:
                # no dense free-set to fall back on: draw fresh feasible
                # codes and keep whatever is not already tried/in flight
                cand = np.unique(space.sample_feasible(rng, cfg.pool_size))
                pool = cand[~(self.evaluated[cand] | self.pending[cand])]
            else:
                free = np.flatnonzero(~(self.evaluated | self.pending))
                if free.size:
                    pool = rng.choice(free,
                                      size=min(cfg.pool_size, free.size),
                                      replace=False)
        return pool

    def _suggest_bo_pool(self, n: int) -> List[Proposal]:
        """Mirror of ``_suggest_bo`` that scores a candidate pool instead of
        the whole space. All indices below are pool-local until mapped."""
        cfg = self.cfg
        out: List[Proposal] = []
        self._round += 1
        pool = self._build_pool()
        if pool.size == 0:
            return out
        Xp = self.space.X_norm[pool]
        in_flight = _flag_indices(self.pending)
        speculate = n > 1 or in_flight.size > 0
        if speculate:
            self.gp.mark()
            if in_flight.size:
                mu0, _ = self.gp.predict_at(self.space.X_norm[in_flight])
                for k, i in enumerate(in_flight):
                    self.gp.add(self.space.X_norm[i], float(mu0[k]))
        try:
            alive = np.ones(pool.size, dtype=bool)
            for j in range(n):
                if not alive.any():
                    break
                mu, sigma = self.gp.predict_at(Xp)
                f_best = self.f_best if math.isfinite(self.f_best) else self.mu_s
                y_std = self.gp.y_std

                if cfg.exploration == "cv":
                    explore = A.pool_contextual_variance(
                        sigma[alive], f_best, self.mu_s, self.var_s)
                else:
                    explore = float(cfg.exploration)

                def pick(af_name: str) -> int:
                    scores = A.af_scores(af_name, mu, sigma, f_best, explore,
                                         y_std)
                    scores = np.where(alive, scores, -np.inf)
                    return int(np.argmax(scores))

                controller = self.controller
                if controller is None:
                    af_name = cfg.acquisition
                    k = pick(af_name)
                elif controller.mode == "multi":
                    noms = {a.name: pick(a.name)
                            for a in controller.active_afs()}
                    controller.register_duplicates(
                        {name: int(pool[k2]) for name, k2 in noms.items()})
                    af = controller.next_af()
                    af_name = af.name
                    k = noms.get(af.name, pick(af.name))
                else:  # advanced multi: only the evaluating AF predicts
                    af = controller.next_af()
                    af_name = af.name
                    k = pick(af.name)

                idx = int(pool[k])
                self.pending[idx] = True
                alive[k] = False
                out.append(Proposal(idx, af=af_name))
                if j < n - 1:
                    # kriging-believer fantasy for the remaining picks
                    self.gp.add(self.space.X_norm[idx], float(mu[k]))
        finally:
            if speculate:
                self.gp.rollback()
        return out

    # -- tell ---------------------------------------------------------------
    def observe(self, proposal: Proposal, value: float) -> None:
        idx = proposal.idx
        if idx is None:
            return
        self._absorb(idx, value)
        if proposal.af == "init":
            self._init_outstanding = max(self._init_outstanding - 1, 0)
            if math.isfinite(value):
                self.init_vals.append(value)
        elif self.controller is not None:
            af = next((a for a in self.controller.afs
                       if a.name == proposal.af), None)
            if af is not None:
                self.controller.record(af, value, math.isfinite(value))
