"""The paper's Bayesian Optimization search strategy (§III), ask/tell form.

Structure (paper's contributions all present):
  * discrete normalized search space; acquisition optimized ONLY over
    not-yet-evaluated configs by exhaustive prediction (no BFGS);
  * invalid observations consume budget but are never fitted to the GP;
  * maximin-LHS initial sample with random repair of invalid draws;
  * Matérn-3/2 GP, fixed lengthscale 2.0 (1.5 under contextual variance);
  * exploration factor: constant or Contextual Variance;
  * acquisition: ei | poi | lcb | multi | advanced_multi (Table I defaults).

Beyond the paper (DESIGN.md §3–4): ``suggest(n)`` with n > 1 builds a batch
by kriging-believer fantasies — each pick is speculatively added to the GP at
its posterior mean, the acquisition is re-scored, and the speculative
observations are rolled back once the batch is out the door. In-flight
configs (suggested earlier, not yet observed) are fantasized the same way, so
asynchronous engines never get duplicate suggestions and the batch spreads
out instead of piling onto one optimum. At ``batch_size=1`` no speculation
happens and the interaction sequence is bit-for-bit the sequential paper
loop (pinned by the golden-trace tests).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core import acquisition as A
from repro.core.gp import GP
from repro.core.gp_fast import IncrementalGP
from repro.core.lhs import initial_sample
from repro.core.strategies.base import Proposal, Strategy, StrategyContext


@dataclass(frozen=True)
class BOConfig:
    acquisition: str = "advanced_multi"   # ei|poi|lcb|multi|advanced_multi
    kernel: str = "matern32"
    lengthscale: float = 2.0
    lengthscale_cv: float = 1.5
    exploration: object = "cv"            # "cv" or a float
    initial_samples: int = 20
    maximin: bool = True
    skip_threshold: int = 5
    improvement_factor: float = 0.1
    discount: Optional[float] = None      # None -> per-mode Table I default
    af_order: Sequence[str] = ("ei", "poi", "lcb")
    noise: float = 1e-6
    # "fast": incremental-Cholesky exact GP (beyond-paper, ~100x less work);
    # "jax": padded jit GP (the oracle; also what the Pallas kernel mirrors)
    engine: str = "fast"


class _EngineAdapter:
    """Uniform .add / .predict_all / .y_std / .mark / .rollback over both
    GP engines."""

    def __init__(self, cfg: BOConfig, X_cand: np.ndarray, max_obs: int, ell: float):
        self.jax_mode = cfg.engine == "jax"
        self.X_cand = X_cand
        if self.jax_mode:
            self.gp = GP(X_cand.shape[1], max_obs=max_obs, kernel=cfg.kernel,
                         ell=ell, noise=cfg.noise)
        else:
            self.gp = IncrementalGP(X_cand, max_obs=max_obs, kernel=cfg.kernel,
                                    ell=ell, noise=cfg.noise)

    def add(self, x, y):
        self.gp.add(x, y)

    def mark(self):
        self.gp.mark()

    def rollback(self):
        self.gp.rollback()

    def predict_all(self):
        if self.jax_mode:
            mu, sigma = self.gp.predict(self.X_cand)
            return np.asarray(mu, np.float64), np.asarray(sigma, np.float64)
        return self.gp.predict()

    @property
    def y_std(self) -> float:
        if self.jax_mode:
            if self.gp.state is None:
                self.gp.fit()
            return float(self.gp.state.y_std)
        return self.gp.y_std


class BOStrategy(Strategy):
    def __init__(self, cfg: BOConfig = BOConfig(), name: Optional[str] = None):
        self.cfg = cfg
        self.name = name or f"bo_{cfg.acquisition}"

    # -- lifecycle ----------------------------------------------------------
    def reset(self, ctx: StrategyContext) -> None:
        cfg = self.cfg
        self.space = ctx.space
        self.rng = ctx.rng
        ell = (cfg.lengthscale_cv if cfg.exploration == "cv"
               else cfg.lengthscale)
        self.gp = _EngineAdapter(cfg, ctx.space.X_norm, max_obs=ctx.budget,
                                 ell=ell)
        self.evaluated = np.zeros(ctx.space.size, dtype=bool)
        self.pending = np.zeros(ctx.space.size, dtype=bool)  # in flight
        self.f_best = math.inf
        self.controller: Optional[A.MultiAcquisition] = None
        self.mu_s = 0.0
        self.var_s = 0.0

        # resume support: absorb any journal replayed into the run
        replayed_vals: List[float] = []
        for idx, value in ctx.replayed:
            if idx is not None:
                self._absorb(int(idx), value)
            if math.isfinite(value):
                replayed_vals.append(value)

        self.n_init = max(cfg.initial_samples - int(self.evaluated.sum()), 0)
        self.init_vals: List[float] = []
        self._repair_guard = 0
        self._init_outstanding = 0
        if self.n_init > 0:
            self._phase = "init"
            self._init_queue = deque(
                initial_sample(ctx.space, self.n_init, ctx.rng,
                               maximin=cfg.maximin))
        else:
            self._phase = "init"      # finalized on first suggest()
            self._init_queue = deque()
            self.init_vals = replayed_vals

    def _absorb(self, idx: int, value: float):
        self.evaluated[idx] = True
        self.pending[idx] = False
        if math.isfinite(value):
            self.gp.add(self.space.X_norm[idx], value)
            if value < self.f_best:
                self.f_best = value

    def _finalize_init(self):
        """Initial sample complete: fix μ_s, σ̄²_s, build the AF controller."""
        cfg = self.cfg
        if not self.init_vals:  # pathological space: no valid init found
            self.init_vals = [1.0]
        self.mu_s = float(np.mean(self.init_vals))
        _, sigma0 = self.gp.predict_all()
        self.var_s = float(np.mean(np.square(np.asarray(sigma0))))
        if cfg.acquisition in ("multi", "advanced_multi"):
            self.controller = A.MultiAcquisition(
                mode="advanced" if cfg.acquisition == "advanced_multi"
                else "multi",
                order=cfg.af_order, skip_threshold=cfg.skip_threshold,
                improvement_factor=cfg.improvement_factor,
                discount=cfg.discount)
        self._phase = "bo"

    # -- ask ----------------------------------------------------------------
    def suggest(self, n: int) -> List[Proposal]:
        if self._phase == "init":
            props = self._suggest_init(n)
            if props or self._phase == "init":
                return props
            # fell through to bo on this very call
        return self._suggest_bo(n)

    def _suggest_init(self, n: int) -> List[Proposal]:
        out: List[Proposal] = []
        while len(out) < n and self._init_queue:
            idx = int(self._init_queue.popleft())
            self.pending[idx] = True
            self._init_outstanding += 1
            out.append(Proposal(idx, af="init"))
        # paper: replace invalid draws with random samples until all valid.
        # Only once every earlier init proposal is observed do we know how
        # many repairs are still owed (invalid draws in flight may yet fail).
        if not out and self._init_outstanding == 0:
            need = self.n_init - len(self.init_vals)
            while (len(out) < min(n, max(need, 0))
                   and self._repair_guard < 20 * self.n_init):
                self._repair_guard += 1
                idx = self.space.random_index(self.rng)
                if self.evaluated[idx] or self.pending[idx]:
                    continue
                self.pending[idx] = True
                self._init_outstanding += 1
                out.append(Proposal(int(idx), af="init"))
            if not out:  # init done (or guard exhausted) -> switch phase
                self._finalize_init()
        return out

    def _suggest_bo(self, n: int) -> List[Proposal]:
        cfg = self.cfg
        out: List[Proposal] = []
        in_flight = np.flatnonzero(self.pending)
        speculate = n > 1 or in_flight.size > 0
        if speculate:
            self.gp.mark()
            if in_flight.size:
                # fantasize in-flight configs at their posterior mean so an
                # async engine never gets the same suggestion twice
                mu0, _ = self.gp.predict_all()
                for i in in_flight:
                    self.gp.add(self.space.X_norm[i], float(mu0[i]))
        try:
            for j in range(n):
                blocked = self.evaluated | self.pending
                if blocked.all():
                    break
                mu, sigma = self.gp.predict_all()
                f_best = self.f_best if math.isfinite(self.f_best) else self.mu_s
                y_std = self.gp.y_std

                if cfg.exploration == "cv":
                    if speculate:
                        explore = A.batch_contextual_variance(
                            np.asarray(sigma), self.evaluated, self.pending,
                            f_best, self.mu_s, self.var_s)
                    else:
                        explore = A.contextual_variance(
                            sigma[~self.evaluated], f_best, self.mu_s,
                            self.var_s)
                else:
                    explore = float(cfg.exploration)

                def pick(af_name: str) -> int:
                    scores = A.af_scores(af_name, mu, sigma, f_best, explore,
                                         y_std)
                    scores = np.where(blocked, -np.inf, scores)
                    return int(np.argmax(scores))

                controller = self.controller
                if controller is None:
                    af_name = cfg.acquisition
                    idx = pick(af_name)
                elif controller.mode == "multi":
                    noms = {a.name: pick(a.name)
                            for a in controller.active_afs()}
                    controller.register_duplicates(noms)
                    af = controller.next_af()
                    af_name = af.name
                    idx = noms.get(af.name, pick(af.name))
                else:  # advanced multi: only the evaluating AF predicts
                    af = controller.next_af()
                    af_name = af.name
                    idx = pick(af.name)

                self.pending[idx] = True
                out.append(Proposal(idx, af=af_name))
                if j < n - 1:
                    # kriging-believer fantasy for the remaining picks
                    self.gp.add(self.space.X_norm[idx], float(mu[idx]))
        finally:
            if speculate:
                self.gp.rollback()
        return out

    # -- tell ---------------------------------------------------------------
    def observe(self, proposal: Proposal, value: float) -> None:
        idx = proposal.idx
        if idx is None:
            return
        self._absorb(idx, value)
        if proposal.af == "init":
            self._init_outstanding = max(self._init_outstanding - 1, 0)
            if math.isfinite(value):
                self.init_vals.append(value)
        elif self.controller is not None:
            af = next((a for a in self.controller.afs
                       if a.name == proposal.af), None)
            if af is not None:
                self.controller.record(af, value, math.isfinite(value))
