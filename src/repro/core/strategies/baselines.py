"""Kernel Tuner baseline strategies the paper compares against (§IV-B).

Random Search, Simulated Annealing, Multi-start Local Search, and a Genetic
Algorithm — the best-performing non-BO strategies in Kernel Tuner on the test
kernels. All operate on Hamming neighborhoods of the restricted space and see
invalid configurations as failed evaluations (consuming budget).

Ask/tell ports (DESIGN.md §2): Random Search and the GA are naturally
batchable — a random permutation is embarrassingly parallel, and a GA
generation's fitness evaluations are independent — so they subclass
``Strategy`` directly and hand the engine up to ``n`` configs at once. SA and
MLS are inherently sequential chains (each move depends on the previous
observation), so they are mechanical generator ports: ``run.evaluate`` became
``yield Proposal`` and nothing else changed.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Generator, List

import numpy as np

from repro.core.strategies.base import (GeneratorStrategy, Proposal, Strategy,
                                        StrategyContext)


class RandomSearch(Strategy):
    name = "random"

    def reset(self, ctx: StrategyContext) -> None:
        self._order = ctx.rng.permutation(ctx.space.size)
        self._pos = 0

    def suggest(self, n: int) -> List[Proposal]:
        out = [Proposal(int(idx), af="random")
               for idx in self._order[self._pos:self._pos + n]]
        self._pos += len(out)
        return out

    def observe(self, proposal: Proposal, value: float) -> None:
        pass


@dataclass
class SimulatedAnnealing(GeneratorStrategy):
    """Kernel Tuner-style SA: Hamming neighbor moves, geometric cooling."""

    t0: float = 1.0
    t_min: float = 1e-3
    alpha: float = 0.985
    name: str = "simulated_annealing"

    def proposals(self, ctx: StrategyContext) -> Generator[Proposal, float, None]:
        space, rng = ctx.space, ctx.rng
        cur = space.random_index(rng)
        cur_v = yield Proposal(cur, af="sa")
        guard_restarts = 0
        while not math.isfinite(cur_v) and guard_restarts < 1000:
            guard_restarts += 1
            cur = space.random_index(rng)
            cur_v = yield Proposal(cur, af="sa")
        T = self.t0
        scale = max(abs(cur_v), 1e-9) if math.isfinite(cur_v) else 1.0
        while True:
            nbrs = space.hamming_neighbors(cur)
            if not nbrs:
                cur = space.random_index(rng)
                cur_v = yield Proposal(cur, af="sa")
                continue
            cand = int(nbrs[rng.integers(len(nbrs))])
            cand_v = yield Proposal(cand, af="sa")
            accept = False
            if math.isfinite(cand_v):
                if not math.isfinite(cur_v) or cand_v < cur_v:
                    accept = True
                else:
                    delta = (cand_v - cur_v) / scale
                    accept = rng.random() < math.exp(-delta / max(T, 1e-9))
            if accept:
                cur, cur_v = cand, cand_v
            T = max(T * self.alpha, self.t_min)


@dataclass
class MultiStartLocalSearch(GeneratorStrategy):
    """Greedy best-improvement hill-climbing on Hamming neighborhoods,
    restarted from random configs until the budget runs out."""

    name: str = "mls"

    def proposals(self, ctx: StrategyContext) -> Generator[Proposal, float, None]:
        space, rng = ctx.space, ctx.rng
        while True:
            cur = space.random_index(rng)
            cur_v = yield Proposal(cur, af="mls")
            if not math.isfinite(cur_v):
                continue
            improved = True
            while improved:
                improved = False
                best_n, best_v = None, cur_v
                for n in space.hamming_neighbors(cur):
                    v = yield Proposal(int(n), af="mls")
                    if math.isfinite(v) and v < best_v:
                        best_n, best_v = int(n), v
                if best_n is not None:
                    cur, cur_v = best_n, best_v
                    improved = True


@dataclass
class GeneticAlgorithm(Strategy):
    """Tournament GA with uniform crossover and per-gene mutation.

    One generation's fitness evaluations are independent, so ``suggest``
    hands out the whole current population; breeding happens in ``observe``
    once the last fitness of the generation lands (observation order is the
    engine's acceptance order, so the rng stream matches the sequential
    implementation exactly).
    """

    pop_size: int = 20
    mutation_rate: float = 0.1
    tournament: int = 3
    elitism: int = 2
    name: str = "genetic_algorithm"

    def reset(self, ctx: StrategyContext) -> None:
        self.space, self.rng = ctx.space, ctx.rng
        self.nvals = [len(p.values) for p in ctx.space.params]
        self.pop: List[int] = [ctx.space.random_index(ctx.rng)
                               for _ in range(self.pop_size)]
        self.fit: List[float] = []
        self._queued = 0

    def suggest(self, n: int) -> List[Proposal]:
        out: List[Proposal] = []
        while len(out) < n and self._queued < len(self.pop):
            out.append(Proposal(self.pop[self._queued], af="ga"))
            self._queued += 1
        return out

    def observe(self, proposal: Proposal, value: float) -> None:
        self.fit.append(value if math.isfinite(value) else math.inf)
        if len(self.fit) == self.pop_size:
            self._breed()

    def _tournament_pick(self) -> int:
        best, best_f = None, math.inf
        for _ in range(self.tournament):
            j = int(self.rng.integers(self.pop_size))
            if self.fit[j] <= best_f:
                best, best_f = self.pop[j], self.fit[j]
        return best if best is not None else self.pop[0]

    def _breed(self) -> None:
        space, rng = self.space, self.rng
        order = np.argsort(self.fit)
        new_pop = [self.pop[i] for i in order[:self.elitism]]
        while len(new_pop) < self.pop_size:
            p1 = space.value_indices[self._tournament_pick()]
            p2 = space.value_indices[self._tournament_pick()]
            mask = rng.random(space.dim) < 0.5
            child = np.where(mask, p1, p2).astype(np.int64)
            for g in range(space.dim):
                if rng.random() < self.mutation_rate:
                    child[g] = rng.integers(self.nvals[g])
            idx = space.index_of_value_indices(child)
            if idx is None:
                # repair: nearest valid config to the infeasible child
                x = child / np.array([max(n - 1, 1) for n in self.nvals])
                idx = space.nearest_index(x.astype(np.float32))
            new_pop.append(int(idx))
        self.pop = new_pop
        self.fit = []
        self._queued = 0
