"""Kernel Tuner baseline strategies the paper compares against (§IV-B).

Random Search, Simulated Annealing, Multi-start Local Search, and a Genetic
Algorithm — the best-performing non-BO strategies in Kernel Tuner on the test
kernels. All operate on Hamming neighborhoods of the restricted space and see
invalid configurations as failed evaluations (consuming budget).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.runner import BudgetExhausted, TuningRun


class RandomSearch:
    name = "random"

    def run(self, run: TuningRun, rng: np.random.Generator):
        order = rng.permutation(run.space.size)
        for idx in order:
            run.evaluate(int(idx), af="random")
        raise BudgetExhausted


@dataclass
class SimulatedAnnealing:
    """Kernel Tuner-style SA: Hamming neighbor moves, geometric cooling."""

    t0: float = 1.0
    t_min: float = 1e-3
    alpha: float = 0.985
    name: str = "simulated_annealing"

    def run(self, run: TuningRun, rng: np.random.Generator):
        space = run.space
        cur = space.random_index(rng)
        cur_v = run.evaluate(cur, af="sa")
        guard_restarts = 0
        while not math.isfinite(cur_v) and guard_restarts < 1000:
            guard_restarts += 1
            cur = space.random_index(rng)
            cur_v = run.evaluate(cur, af="sa")
        T = self.t0
        scale = max(abs(cur_v), 1e-9) if math.isfinite(cur_v) else 1.0
        while True:
            nbrs = space.hamming_neighbors(cur)
            if not nbrs:
                cur = space.random_index(rng)
                cur_v = run.evaluate(cur, af="sa")
                continue
            cand = int(nbrs[rng.integers(len(nbrs))])
            cand_v = run.evaluate(cand, af="sa")
            accept = False
            if math.isfinite(cand_v):
                if not math.isfinite(cur_v) or cand_v < cur_v:
                    accept = True
                else:
                    delta = (cand_v - cur_v) / scale
                    accept = rng.random() < math.exp(-delta / max(T, 1e-9))
            if accept:
                cur, cur_v = cand, cand_v
            T = max(T * self.alpha, self.t_min)


@dataclass
class MultiStartLocalSearch:
    """Greedy best-improvement hill-climbing on Hamming neighborhoods,
    restarted from random configs until the budget runs out."""

    name: str = "mls"

    def run(self, run: TuningRun, rng: np.random.Generator):
        space = run.space
        while True:
            cur = space.random_index(rng)
            cur_v = run.evaluate(cur, af="mls")
            if not math.isfinite(cur_v):
                continue
            improved = True
            while improved:
                improved = False
                best_n, best_v = None, cur_v
                for n in space.hamming_neighbors(cur):
                    v = run.evaluate(int(n), af="mls")
                    if math.isfinite(v) and v < best_v:
                        best_n, best_v = int(n), v
                if best_n is not None:
                    cur, cur_v = best_n, best_v
                    improved = True


@dataclass
class GeneticAlgorithm:
    """Tournament GA with uniform crossover and per-gene mutation."""

    pop_size: int = 20
    mutation_rate: float = 0.1
    tournament: int = 3
    elitism: int = 2
    name: str = "genetic_algorithm"

    def run(self, run: TuningRun, rng: np.random.Generator):
        space = run.space
        nvals = [len(p.values) for p in space.params]

        def fitness_of(idx: int) -> float:
            v = run.evaluate(idx, af="ga")
            return v if math.isfinite(v) else math.inf

        pop: List[int] = [space.random_index(rng) for _ in range(self.pop_size)]
        fit = [fitness_of(i) for i in pop]

        def tournament_pick() -> int:
            best, best_f = None, math.inf
            for _ in range(self.tournament):
                j = int(rng.integers(self.pop_size))
                if fit[j] <= best_f:
                    best, best_f = pop[j], fit[j]
            return best if best is not None else pop[0]

        while True:
            order = np.argsort(fit)
            new_pop = [pop[i] for i in order[:self.elitism]]
            while len(new_pop) < self.pop_size:
                p1 = space.value_indices[tournament_pick()]
                p2 = space.value_indices[tournament_pick()]
                mask = rng.random(space.dim) < 0.5
                child = np.where(mask, p1, p2).astype(np.int64)
                for g in range(space.dim):
                    if rng.random() < self.mutation_rate:
                        child[g] = rng.integers(nvals[g])
                idx = space._lookup.get(tuple(int(c) for c in child))
                if idx is None:
                    # repair: nearest valid config to the infeasible child
                    x = child / np.array([max(n - 1, 1) for n in nvals])
                    idx = space.nearest_index(x.astype(np.float32))
                new_pop.append(int(idx))
            pop = new_pop
            fit = [fitness_of(i) for i in pop]
