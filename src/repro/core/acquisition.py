"""Acquisition functions + the paper's novel selection mechanisms (§III-C/F/G).

Basic AFs (minimization variants): EI, POI, LCB. All return scores where
HIGHER = more desirable; the suggestion is argmax over *unevaluated* configs.

Contextual Variance (§III-F): scale-independent dynamic exploration factor for
minimization,  λ = (σ̄² / (μ_s / f(x⁺))) / σ̄²_s  — proportional to the current
mean posterior variance, inversely proportional to the achieved improvement
over the initial-sample mean, normalized by the post-initial-sample variance.

`multi` / `advanced multi` (§III-G): round-robin portfolios that skip or
promote AFs based on a discounted-observation score
    dos_t = Σ_i o_i · γ^(t-i)
(we use the recency-weighted *mean* — normalized by Σ γ^(t-i) — so AFs with
different usage counts stay comparable; the paper is ambiguous here, see
DESIGN.md §7). Invalid observations contribute the median of valid
observations to the dos (advanced multi, per the paper).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_SQRT2 = math.sqrt(2.0)


def _phi(z):   # standard normal pdf
    return np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)


def _Phi(z):   # standard normal cdf (vectorized erf; no scipy in this env)
    return 0.5 * (1.0 + _np_erf(z / _SQRT2))


def _np_erf(x):
    # Abramowitz & Stegun 7.1.26, max abs err ~1.5e-7 — fine for acquisition
    sign = np.sign(x)
    x = np.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * x)
    y = 1.0 - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
                - 0.284496736) * t + 0.254829592) * t * np.exp(-x * x)
    return sign * y


def ei_scores(mu, sigma, f_best, xi: float, y_std: float = 1.0):
    """Expected improvement (minimization), standardized for scale freedom."""
    s = np.maximum(sigma / max(y_std, 1e-12), 1e-12)
    imp = (f_best - mu) / max(y_std, 1e-12) - xi
    z = imp / s
    return imp * _Phi(z) + s * _phi(z)


def poi_scores(mu, sigma, f_best, xi: float, y_std: float = 1.0):
    s = np.maximum(sigma / max(y_std, 1e-12), 1e-12)
    imp = (f_best - mu) / max(y_std, 1e-12) - xi
    return _Phi(imp / s)


def lcb_scores(mu, sigma, lam: float, y_std: float = 1.0):
    """Lower confidence bound; higher score = lower bound (minimization)."""
    return -(mu - lam * sigma)


AF_ORDER_DEFAULT = ("ei", "poi", "lcb")


def af_scores(name: str, mu, sigma, f_best, explore: float, y_std: float = 1.0):
    if name == "ei":
        return ei_scores(mu, sigma, f_best, explore, y_std)
    if name == "poi":
        return poi_scores(mu, sigma, f_best, explore, y_std)
    if name == "lcb":
        return lcb_scores(mu, sigma, max(explore, 0.0) if explore else 1.0, y_std)
    raise ValueError(name)


def contextual_variance(sigma: np.ndarray, f_best: float, mu_s: float,
                        var_s: float) -> float:
    """λ per §III-F (minimization form). All quantities in raw y units."""
    mean_var = float(np.mean(np.square(sigma)))
    if var_s <= 0 or f_best == 0:
        return 0.01
    ratio = mu_s / f_best if f_best > 0 else 1.0
    if ratio <= 0:
        ratio = 1.0
    lam = (mean_var / ratio) / var_s
    return float(max(lam, 0.0))


def batch_contextual_variance(sigma: np.ndarray, evaluated: np.ndarray,
                              pending: np.ndarray, f_best: float, mu_s: float,
                              var_s: float) -> float:
    """Contextual Variance for batch/async suggestion (DESIGN.md §4).

    During constant-liar batch construction, configs already holding a fantasy
    observation (``pending``) are no longer exploration targets: their
    posterior variance has been collapsed by the speculative GP update, and
    counting them in the mean posterior variance would bias λ downward —
    every fantasy would make the remaining batch members greedier. Exclude
    both evaluated and pending configs, exactly as the sequential path
    excludes evaluated ones; ``sigma`` must come from the fantasy-updated GP
    so λ reflects the variance that actually remains on the table.
    """
    free = ~(np.asarray(evaluated, bool) | np.asarray(pending, bool))
    if not np.any(free):
        return 0.01
    return contextual_variance(sigma[free], f_best, mu_s, var_s)


def pool_contextual_variance(sigma_pool: np.ndarray, f_best: float,
                             mu_s: float, var_s: float) -> float:
    """Contextual Variance from a candidate pool (DESIGN.md §10).

    In pool mode the full-space posterior is never computed, so the mean
    posterior variance in §III-F is *estimated* from the pool. The pool's
    stratified-random component keeps the estimate representative of the
    unevaluated space; incumbent-neighborhood members bias σ̄² slightly
    downward (they sit near observations), which only makes λ a little more
    conservative. ``sigma_pool`` must already exclude evaluated/pending
    configs — pools are built that way — matching the sequential path's
    exclusion of evaluated ones. ``var_s`` must come from the same estimator
    at initial-sample time (a stratified draw scored once) so the ratio
    λ = (σ̄²/ratio)/σ̄²_s compares like with like."""
    if sigma_pool.size == 0:
        return 0.01
    return contextual_variance(sigma_pool, f_best, mu_s, var_s)


@dataclass
class AFStats:
    name: str
    observations: List[float] = field(default_factory=list)
    dup_count: int = 0
    worse_count: int = 0
    better_count: int = 0
    active: bool = True

    def dos(self, discount: float, median_valid: float) -> float:
        """Recency-weighted mean of this AF's observations (lower = better)."""
        if not self.observations:
            return math.inf
        num = den = 0.0
        t = len(self.observations)
        for i, o in enumerate(self.observations, start=1):
            w = discount ** (t - i)
            v = median_valid if (o is None or not math.isfinite(o)) else o
            num += v * w
            den += w
        return num / den if den > 0 else math.inf


class MultiAcquisition:
    """The paper's `multi` and `advanced multi` controllers.

    mode="multi": one shared GP prediction per iteration; every active AF
    nominates its argmax; duplicate nominations increment dup counters; past
    `skip_threshold`, conflicting AFs are pitted and only the best-dos one
    survives. The evaluating AF rotates round-robin.

    mode="advanced": no duplicate-avoidance predictions — AFs are judged
    directly on dos. An AF whose dos is `improvement_factor` worse than the
    mean for `skip_threshold` consecutive judgments is skipped (others'
    counters reset); one that is `improvement_factor` better is PROMOTED to
    sole AF for the rest of the run.
    """

    def __init__(self, mode: str = "advanced",
                 order: Sequence[str] = AF_ORDER_DEFAULT,
                 skip_threshold: int = 5,
                 improvement_factor: float = 0.1,
                 discount: Optional[float] = None):
        assert mode in ("multi", "advanced")
        self.mode = mode
        self.afs = [AFStats(n) for n in order]
        self.skip_threshold = skip_threshold
        self.improvement_factor = improvement_factor
        self.discount = discount if discount is not None else (
            0.75 if mode == "advanced" else 0.65)
        self._rr = 0
        self.valid_observations: List[float] = []

    # -- round robin --------------------------------------------------------
    def active_afs(self) -> List[AFStats]:
        return [a for a in self.afs if a.active]

    def next_af(self) -> AFStats:
        act = self.active_afs()
        af = act[self._rr % len(act)]
        self._rr += 1
        return af

    # -- recording ----------------------------------------------------------
    def _median_valid(self) -> float:
        return float(np.median(self.valid_observations)) if self.valid_observations else 0.0

    def record(self, af: AFStats, value: Optional[float], valid: bool):
        af.observations.append(value if valid else math.nan)
        if valid and value is not None and math.isfinite(value):
            self.valid_observations.append(value)
        if self.mode == "advanced":
            self._judge()

    def register_duplicates(self, nominations: Dict[str, int]):
        """mode="multi": nominations maps AF name -> suggested config index."""
        if self.mode != "multi":
            return
        by_idx: Dict[int, List[str]] = {}
        for name, idx in nominations.items():
            by_idx.setdefault(idx, []).append(name)
        conflict_sets = [names for names in by_idx.values() if len(names) > 1]
        for names in conflict_sets:
            for a in self.afs:
                if a.name in names and a.active:
                    a.dup_count += 1
        # pit AFs whose counter exceeded the threshold
        med = self._median_valid()
        for names in conflict_sets:
            group = [a for a in self.afs
                     if a.name in names and a.active and a.dup_count > self.skip_threshold]
            if len(group) > 1:
                best = min(group, key=lambda a: a.dos(self.discount, med))
                for a in group:
                    if a is not best:
                        a.active = False
        if not self.active_afs():  # never kill everything
            self.afs[0].active = True

    def _judge(self):
        act = self.active_afs()
        if len(act) <= 1:
            return
        med = self._median_valid()
        doses = {a.name: a.dos(self.discount, med) for a in act}
        finite = [v for v in doses.values() if math.isfinite(v)]
        if not finite:
            return
        mean_dos = float(np.mean(finite))
        if mean_dos == 0:
            return
        for a in act:
            d = doses[a.name]
            if not math.isfinite(d):
                continue
            # minimization: dos ABOVE mean by `improvement_factor` = worse
            if d > mean_dos * (1.0 + self.improvement_factor):
                a.worse_count += 1
                a.better_count = 0
            elif d < mean_dos * (1.0 - self.improvement_factor):
                a.better_count += 1
                a.worse_count = 0
            else:
                a.worse_count = 0
                a.better_count = 0
        # skips first: removing a loser resets everyone's counters (paper:
        # "...will be skipped and the counts of others reset"), so a
        # promotion must re-earn its streak against the remaining AFs.
        skipped = False
        for a in act:
            if a.worse_count >= self.skip_threshold and len(self.active_afs()) > 1:
                a.active = False
                skipped = True
        if skipped:
            for b in self.afs:
                b.worse_count = 0
                b.better_count = 0
            return
        for a in act:
            if a.better_count >= self.skip_threshold:
                for b in self.afs:
                    b.active = b is a   # promotion to sole AF
                break
