"""Evaluation metrics from the paper (§IV-A).

MAE: mean absolute error of the best-found value vs the global optimum,
sampled at function evaluations 40, 60, ..., 220 (the first evaluations are
noise/initial-sample dominated):  MAE = (1/10) Σ_{i=2..11} |f(x⁺_{20i}) - f(x')|

MDF (Mean Deviation Factor): per kernel, mean MAE across repeats divided by
the mean of mean-MAEs of all strategies on that kernel — comparable across
kernels with different scales; the paper reports the mean over kernels.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

import numpy as np


def mae(trace: np.ndarray, optimum: float, checkpoints: Sequence[int] = tuple(
        range(40, 221, 20))) -> float:
    """trace[i] = best-so-far after i+1 unique evaluations."""
    errs = []
    for c in checkpoints:
        i = min(c, len(trace)) - 1
        if i < 0:
            continue
        v = trace[i]
        errs.append(abs(v - optimum) if math.isfinite(v) else abs(10 * optimum))
    return float(np.mean(errs)) if errs else math.nan


def mean_mae(traces: List[np.ndarray], optimum: float) -> float:
    return float(np.mean([mae(t, optimum) for t in traces]))


def deviation_factors(mean_maes: Dict[str, float]) -> Dict[str, float]:
    """Per-strategy MAE / mean-over-strategies, for one kernel."""
    vals = [v for v in mean_maes.values() if math.isfinite(v)]
    denom = float(np.mean(vals)) if vals else 1.0
    if denom == 0:
        denom = 1.0
    return {k: v / denom for k, v in mean_maes.items()}


def mdf_table(per_kernel: Dict[str, Dict[str, float]]) -> Dict[str, Dict[str, float]]:
    """per_kernel[kernel][strategy] = mean MAE → MDF mean/std per strategy."""
    strategies = sorted({s for d in per_kernel.values() for s in d})
    factors: Dict[str, List[float]] = {s: [] for s in strategies}
    for kernel, d in per_kernel.items():
        dev = deviation_factors(d)
        for s in strategies:
            if s in dev and math.isfinite(dev[s]):
                factors[s].append(dev[s])
    return {s: {"mdf": float(np.mean(v)) if v else math.nan,
                "std": float(np.std(v)) if v else math.nan,
                "n_kernels": len(v)}
            for s, v in factors.items()}


def evals_to_match(trace: np.ndarray, target: float, max_evals: int) -> int:
    """First unique-evaluation count at which trace <= target (Fig. 4)."""
    for i, v in enumerate(trace[:max_evals]):
        if math.isfinite(v) and v <= target:
            return i + 1
    return max_evals + 1
