"""The paper's five auto-tuning search spaces (Tables II/III), regenerated.

We do not have the paper's recorded GPU measurements, so (per DESIGN.md §7.3)
we reproduce the *shape of the problem*: identical parameter structure where
recoverable, identical search-space cardinality and invalid fraction
(trimmed/marked deterministically), and a seeded synthetic performance
surface with the characteristics the paper describes — multimodal, strong
parameter interactions, discontinuous cliffs, invalids clustered in
high-resource regions, ~1% measurement noise.

Per-GPU variants (gtx_titan_x / rtx_2070_super / a100) differ in seed,
minimum, search-space trimming and invalid fraction, mirroring Table III.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np


def _stable_hash(s: str) -> int:
    """Process-independent string hash (Python's hash() is salted!)."""
    return zlib.crc32(s.encode())

from repro.core.objectives import SimulatedObjective
from repro.core.searchspace import Param, SearchSpace, VectorConstraint

GPUS = ("gtx_titan_x", "rtx_2070_super", "a100")
_GPU_SEED = {"gtx_titan_x": 101, "rtx_2070_super": 202, "a100": 303}
_GPU_SPEED = {"gtx_titan_x": 1.0, "rtx_2070_super": 0.55, "a100": 0.30}


# ---------------------------------------------------------------------------
# space definitions


def gemm_space() -> SearchSpace:
    """CLBlast GEMM: cartesian 82944 -> constrained (paper: 17956)."""
    params = [
        Param("MWG", (16, 32, 64, 128)),
        Param("NWG", (16, 32, 64, 128)),
        Param("KWG", (16, 32)),
        Param("MDIMC", (8, 16, 32)),
        Param("NDIMC", (8, 16, 32)),
        Param("MDIMA", (8, 16, 32)),
        Param("NDIMB", (8, 16, 32)),
        Param("KWI", (2, 8)),
        Param("VWM", (1, 2, 4, 8)),
        Param("VWN", (1, 2, 4, 8)),
        Param("STRM", (0,)),
        Param("STRN", (0,)),
        Param("SA", (1,)),
        Param("SB", (1,)),
        Param("PRECISION", (32,)),
    ]
    # The four CLBlast divisibility restrictions give 21316 configs; the
    # paper's full set lands at 17956 — we trim deterministically to the
    # exact paper size (DESIGN.md §7.3).
    cons = [
        VectorConstraint(lambda c: c["MWG"] % (c["MDIMC"] * c["VWM"]) == 0),
        VectorConstraint(lambda c: c["NWG"] % (c["NDIMC"] * c["VWN"]) == 0),
        VectorConstraint(lambda c: c["MWG"] % (c["MDIMA"] * c["VWM"]) == 0),
        VectorConstraint(lambda c: c["NWG"] % (c["NDIMB"] * c["VWN"]) == 0),
    ]
    return SearchSpace(params, cons, name="gemm")


def convolution_space(gpu: str = "gtx_titan_x") -> SearchSpace:
    """2D convolution: cartesian 18432; constrained 9400 (Titan X) /
    7520 (Turing/Ampere — tighter thread-count limit, Table III)."""
    params = [
        Param("filter_width", (15,)),
        Param("filter_height", (15,)),
        Param("block_size_x", tuple(range(8, 129, 8))),       # 16
        Param("block_size_y", (1, 2, 4, 8, 16, 32)),          # 6
        Param("tile_size_x", (1, 2, 3, 4, 5, 6)),             # 6
        Param("tile_size_y", (1, 2, 3, 4, 5, 6, 7, 8)),       # 8
        Param("use_padding", (0, 1)),
        Param("read_only", (0, 1)),
    ]
    lim = 1024 if gpu == "gtx_titan_x" else 768
    cons = [
        VectorConstraint(lambda c: c["block_size_x"] * c["block_size_y"] <= lim),
        VectorConstraint(lambda c: c["block_size_x"] * c["block_size_y"] >= 32),
        VectorConstraint(lambda c: c["tile_size_x"] * c["tile_size_y"] <= 32),
    ]
    return SearchSpace(params, cons, name="convolution")


def pnpoly_space() -> SearchSpace:
    """Point-in-polygon: no restrictions, cartesian 8184 (31*11*4*2*3)."""
    params = [
        Param("block_size_x", tuple(range(32, 993, 32))),     # 31
        Param("tile_size", tuple(range(1, 12))),              # 11
        Param("between_method", (0, 1, 2, 3)),
        Param("use_precomputed_slopes", (0, 1)),
        Param("use_method", (0, 1, 2)),
    ]
    return SearchSpace(params, (), name="pnpoly")


def expdist_space() -> SearchSpace:
    """ExpDist (unseen kernel, §IV-E): 14400 configs, 50.8% invalid."""
    params = [
        Param("block_size_x", tuple(2 ** i for i in range(5, 11)) + (48, 96, 192, 384)),  # 10
        Param("block_size_y", (1, 2, 4, 8, 16, 32)),          # 6
        Param("tile_size_x", (1, 2, 4, 8)),
        Param("tile_size_y", (1, 2, 4, 8)),
        Param("loop_unroll_factor", (0, 1, 2, 4, 8)),
        Param("n_y_blocks", (1, 4, 16)),
    ]
    return SearchSpace(params, (), name="expdist")


def adding_space() -> SearchSpace:
    """Adding / RTE (unseen kernel, §IV-E): 4654 configs, none invalid.
    Unroll factors = divisors of the 140-iteration loop (paper)."""
    params = [
        Param("block_size_x", tuple(range(16, 513, 16))),     # 32
        Param("block_size_y", (1, 2, 4, 8, 16, 24, 32)),      # 7
        Param("loop_unroll_factor_2", (0, 1, 2, 4, 5, 7, 10, 14, 20, 28, 35, 70, 140)),
        Param("recompute", (0, 1)),
    ]
    # cartesian 5824 -> trimmed to the paper's 4654 (DESIGN.md §7.3)
    return SearchSpace(params, (), name="adding")


# ---------------------------------------------------------------------------
# synthetic performance surfaces


def _log_surface(space: SearchSpace, seed: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Seeded multi-modal log-runtime surface + resource score (un-normalized).

    log_t = Σ per-param effects + Σ pairwise interactions + cliff factor;
    ``res`` is the resource score invalids cluster on (paper §III-D2)."""
    rng = np.random.default_rng(seed)
    idx = space.value_indices.astype(np.float64)           # (N, d)
    nvals = np.array([len(p.values) for p in space.params], np.float64)
    u = idx / np.maximum(nvals - 1, 1)                     # ordinal in [0,1]

    log_t = np.zeros(space.size)
    # per-param effects: smooth bowl + periodic component (multimodal)
    for j in range(space.dim):
        if nvals[j] < 2:
            continue
        c = rng.uniform(0.15, 0.85)
        a = rng.uniform(0.2, 1.2)
        f = rng.integers(1, 4)
        ph = rng.uniform(0, 2 * math.pi)
        b = rng.uniform(0.05, 0.35)
        log_t += a * (u[:, j] - c) ** 2 + b * np.sin(2 * math.pi * f * u[:, j] + ph)
    # pairwise interactions
    n_pairs = max(2, space.dim)
    for _ in range(n_pairs):
        j, k = rng.choice(space.dim, size=2, replace=False)
        w = rng.uniform(-0.6, 0.6)
        log_t += w * (u[:, j] - 0.5) * (u[:, k] - 0.5) * 4.0
    # occupancy cliffs: discontinuous penalty bands on a resource score
    res = u @ rng.uniform(0.2, 1.0, space.dim)
    edges = np.quantile(res, rng.uniform(0.55, 0.9, size=2))
    for e in np.sort(edges):
        log_t += np.where(res > e, rng.uniform(0.15, 0.5), 0.0)
    return log_t, res


def _finish_surface(log_t: np.ndarray, res: np.ndarray, seed: int,
                    base_ms: float, invalid_frac: float,
                    noise: float = 0.01) -> np.ndarray:
    """Log surface -> runtimes: floor at base_ms, measurement noise,
    invalids on the top ``invalid_frac`` of the (noised) resource score."""
    rng = np.random.default_rng(seed + 7)
    log_t = log_t - log_t.min()
    times = base_ms * np.exp(log_t)
    times *= np.exp(rng.normal(0.0, noise, len(times)))
    if invalid_frac > 0:
        n_inv = int(round(invalid_frac * len(times)))
        res_noisy = res + rng.normal(0, 0.05, len(times))
        inv = np.argsort(-res_noisy)[:n_inv]
        times[inv] = math.nan
    return times


def _surface(space: SearchSpace, seed: int, base_ms: float,
             invalid_frac: float, noise: float = 0.01) -> np.ndarray:
    """Seeded multi-modal runtime surface over the whole space.

    runtime = base * Π per-param effects * Π pairwise interactions
                   * occupancy-cliff factor * lognormal(σ=noise)
    invalids: the top `invalid_frac` of a resource score (correlated with
    block/tile products, so invalid configs cluster — paper §III-D2).

    Kept monolithic on purpose: the paper kernels' surfaces are pinned by
    this exact rng draw order (golden traces, Table II/III parity).
    ``_log_surface``/``_finish_surface`` serve the problem-size scenarios,
    which have no historical stream to preserve.
    """
    rng = np.random.default_rng(seed)
    idx = space.value_indices.astype(np.float64)           # (N, d)
    nvals = np.array([len(p.values) for p in space.params], np.float64)
    u = idx / np.maximum(nvals - 1, 1)                     # ordinal in [0,1]

    log_t = np.zeros(space.size)
    for j in range(space.dim):
        if nvals[j] < 2:
            continue
        c = rng.uniform(0.15, 0.85)
        a = rng.uniform(0.2, 1.2)
        f = rng.integers(1, 4)
        ph = rng.uniform(0, 2 * math.pi)
        b = rng.uniform(0.05, 0.35)
        log_t += a * (u[:, j] - c) ** 2 + b * np.sin(2 * math.pi * f * u[:, j] + ph)
    n_pairs = max(2, space.dim)
    for _ in range(n_pairs):
        j, k = rng.choice(space.dim, size=2, replace=False)
        w = rng.uniform(-0.6, 0.6)
        log_t += w * (u[:, j] - 0.5) * (u[:, k] - 0.5) * 4.0
    res = u @ rng.uniform(0.2, 1.0, space.dim)
    edges = np.quantile(res, rng.uniform(0.55, 0.9, size=2))
    for e in np.sort(edges):
        log_t += np.where(res > e, rng.uniform(0.15, 0.5), 0.0)
    log_t -= log_t.min()
    times = base_ms * np.exp(log_t)
    times *= np.exp(rng.normal(0.0, noise, space.size))

    if invalid_frac > 0:
        n_inv = int(round(invalid_frac * space.size))
        res_noisy = res + rng.normal(0, 0.05, space.size)
        inv = np.argsort(-res_noisy)[:n_inv]
        times[inv] = math.nan
    return times


@dataclass(frozen=True)
class PaperKernel:
    name: str
    space_size: Dict[str, int]      # per-GPU expected size (paper tables)
    invalid: Dict[str, float]       # per-GPU invalid fraction
    minimum: Dict[str, float]       # per-GPU minimum (ms), Table II/III


PAPER_KERNELS = {
    "gemm": PaperKernel("gemm",
                        {"gtx_titan_x": 17956, "rtx_2070_super": 17956, "a100": 17956},
                        {g: 0.0 for g in GPUS},
                        {"gtx_titan_x": 28.307, "rtx_2070_super": 17.112, "a100": 8.518}),
    "convolution": PaperKernel("convolution",
                               {"gtx_titan_x": 9400, "rtx_2070_super": 7520, "a100": 7520},
                               {"gtx_titan_x": 0.3855, "rtx_2070_super": 0.232, "a100": 0.232},
                               {"gtx_titan_x": 1.625, "rtx_2070_super": 1.221, "a100": 0.739}),
    "pnpoly": PaperKernel("pnpoly",
                          {g: 8184 for g in GPUS},
                          {"gtx_titan_x": 0.039, "rtx_2070_super": 0.035, "a100": 0.039},
                          {"gtx_titan_x": 26.968, "rtx_2070_super": 12.325, "a100": 13.091}),
    "expdist": PaperKernel("expdist", {g: 14400 for g in GPUS},
                           {g: 0.508 for g in GPUS},
                           {g: 33.878 for g in GPUS}),
    "adding": PaperKernel("adding", {g: 4654 for g in GPUS},
                          {g: 0.0 for g in GPUS},
                          {g: 1.468 for g in GPUS}),
}

_SPACE_FNS = {
    "gemm": lambda gpu: gemm_space(),
    "convolution": lambda gpu: convolution_space(gpu),
    "pnpoly": lambda gpu: pnpoly_space(),
    "expdist": lambda gpu: expdist_space(),
    "adding": lambda gpu: adding_space(),
}

_cache: Dict[Tuple[str, str], SimulatedObjective] = {}


def _trim(space: SearchSpace, target: int, seed: int) -> SearchSpace:
    """Deterministically trim an enumerated space to the paper's exact size."""
    if space.size <= target:
        return space
    rng = np.random.default_rng(seed)
    keep = np.sort(rng.choice(space.size, size=target, replace=False))
    return space.take(keep)


def make_objective(kernel: str, gpu: str = "gtx_titan_x",
                   exact_size: bool = True) -> SimulatedObjective:
    """Simulation-mode objective for one (kernel, GPU) — paper Table II/III."""
    key = (kernel, gpu)
    if key in _cache:
        return _cache[key]
    pk = PAPER_KERNELS[kernel]
    space = _SPACE_FNS[kernel](gpu)
    if exact_size:
        space = _trim(space, pk.space_size[gpu],
                      seed=_stable_hash(kernel + gpu) % 2**31)
    seed = _GPU_SEED[gpu] * 1000 + _stable_hash(kernel) % 997
    times = _surface(space, seed, base_ms=pk.minimum[gpu],
                     invalid_frac=pk.invalid[gpu])
    obj = SimulatedObjective(space, times, name=f"{kernel}@{gpu}")
    _cache[key] = obj
    return obj


#: Share of the log-runtime surface shared across problem sizes of one
#: kernel. Tørring & Elster (2022) observe that optima and cliff structure
#: largely persist across image sizes with size-specific detail on top.
SCENARIO_CORR = 0.75

_scenario_cache: Dict[Tuple[str, str, str], SimulatedObjective] = {}


def make_scenario_objective(kernel: str, gpu: str = "a100",
                            size: str = "base",
                            corr: float = SCENARIO_CORR) -> SimulatedObjective:
    """The fig6/7-style transfer scenario: one kernel family at a different
    PROBLEM SIZE (e.g. a 512-seq vs a 4096-seq GEMM).

    The spaces are *compatible but not identical* — same parameters, a
    size-specific deterministic trim (different kept subsets, different
    config indices) — and the runtime surfaces share ``corr`` of their
    log-runtime structure plus a size-specific remainder. That is exactly
    the shape the record store's cross-size warm start targets: records
    from one size must be nearest-neighbor matched, not index-copied.
    """
    ckey = (kernel, gpu, size)
    if ckey in _scenario_cache:
        return _scenario_cache[ckey]
    pk = PAPER_KERNELS[kernel]
    space = _SPACE_FNS[kernel](gpu)
    h = _stable_hash(f"{kernel}|{gpu}|{size}") % 2**31
    base_seed = _GPU_SEED[gpu] * 1000 + _stable_hash(kernel) % 997

    # shared + size-specific structure, mixed on the FULL enumerated space so
    # every size sees consistent per-config values before its own trim
    log_a, res = _log_surface(space, base_seed)
    log_b, _ = _log_surface(space, h)
    log_mix = corr * log_a + (1.0 - corr) * log_b

    target = min(pk.space_size[gpu], space.size)
    target -= h % max(target // 10, 1)          # sizes differ per scenario
    rng = np.random.default_rng(h)
    keep = np.sort(rng.choice(space.size, size=target, replace=False))
    times = _finish_surface(log_mix[keep], res[keep], h,
                            base_ms=pk.minimum[gpu],
                            invalid_frac=pk.invalid[gpu])
    space = space.take(keep)
    obj = SimulatedObjective(space, times,
                             name=f"{kernel}@{gpu}#{size}")
    _scenario_cache[ckey] = obj
    return obj
