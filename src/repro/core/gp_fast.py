"""Incremental exact GP for exhaustive discrete acquisition (beyond-paper).

The paper optimizes the acquisition function by predicting EVERY discrete
candidate each iteration and notes in its conclusion that reducing this cost
is future work. This module does exactly that, with no approximation:

Keep V = L^{-1} K(X_obs, X_cand) (t × N) and ssq_j = Σ_i V_ij² incrementally.
Adding observation x_{t+1} costs O(t² + t·N) instead of recomputing the full
O(t²·N) triangular solve: one bordered-Cholesky row, one V row.

    posterior mean   μ = y_mean + y_std · Vᵀ w,   w = L^{-1} (y-ȳ)/σ_y
    posterior var    σ² = 1 - ssq                (unit prior variance)

For a 220-evaluation run over a ~18k-config space this is ~100× less work
than the padded-recompute approach (measured in benchmarks/kernel_bench.py).
Numerically identical to ``repro.core.gp.GP`` — asserted in tests — which
remains the jittable JAX oracle; ``repro.kernels.matern_gp`` is the Pallas
TPU kernel for the same V-row update + scoring hot loop.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

try:
    from scipy.linalg import solve_triangular as _scipy_solve_triangular
except ImportError:  # pragma: no cover - scipy is present in the image
    _scipy_solve_triangular = None

SQRT3 = math.sqrt(3.0)
SQRT5 = math.sqrt(5.0)


def forward_substitute(L: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Solve L x = b for lower-triangular L in O(t²) (generic solve is O(t³)).

    The per-iteration delta over ``np.linalg.solve`` is recorded by
    ``benchmarks/kernel_bench.py`` (gp/solve_triangular row).
    """
    if _scipy_solve_triangular is not None:
        return _scipy_solve_triangular(L, b, lower=True, check_finite=False)
    return np.linalg.solve(L, b)


def kernel_np(name: str, r: np.ndarray, ell: float) -> np.ndarray:
    s = r / ell
    if name == "matern12":
        return np.exp(-s)
    if name == "matern32":
        t = SQRT3 * s
        return (1.0 + t) * np.exp(-t)
    if name == "matern52":
        t = SQRT5 * s
        return (1.0 + t + (5.0 / 3.0) * np.square(s)) * np.exp(-t)
    if name == "rbf":
        return np.exp(-0.5 * np.square(s))
    raise ValueError(name)


class IncrementalGP:
    """Exact GP posterior over a FIXED candidate set, incremental in t.

    For candidate-pool mode (DESIGN.md §10) pass ``candidates=None`` and
    ``dim=``: no (max_obs, N) V panel is kept — ``add`` drops to O(t²) — and
    the posterior is served on demand at arbitrary points by ``predict_at``,
    chunked so huge pools never materialize an (m, t, d) tensor.
    """

    def __init__(self, candidates: Optional[np.ndarray], max_obs: int,
                 kernel: str = "matern32", ell: float = 2.0,
                 noise: float = 1e-6, dim: Optional[int] = None,
                 backend: str = "numpy", block_n: int = 512,
                 interpret: Optional[bool] = None):
        if backend not in ("numpy", "pallas"):
            raise ValueError(f"backend must be numpy|pallas, got {backend!r}")
        if candidates is None:
            candidates = np.zeros((0, dim), np.float64)
        self.Xc = np.ascontiguousarray(candidates, np.float64)   # (N, d)
        self.N, self.dim = self.Xc.shape
        self.kernel = kernel
        self.ell = ell
        self.noise = noise
        self.max_obs = max_obs
        #: "pallas" routes full-panel/pool posterior scoring through the
        #: fused repro.kernels.matern_gp TPU kernel — the self-hosting loop
        #: of DESIGN.md §14; ``block_n`` typically comes from the kernel
        #: tuning store (repro.kernels.tuning.tuned_gp_block_n). Incremental
        #: state (add/mark/rollback) is backend-independent.
        self.backend = backend
        self.block_n = int(block_n)
        self.interpret = interpret
        self.L = np.zeros((max_obs, max_obs))
        self.V = np.zeros((max_obs, self.N))
        self.ssq = np.zeros(self.N)
        self.X = np.zeros((max_obs, self.dim))
        self.y = np.zeros(max_obs)
        self.t = 0
        self._mark: Optional[Tuple[int, np.ndarray]] = None

    # -- speculative (fantasy) observations -----------------------------------
    def mark(self) -> int:
        """Checkpoint before constant-liar/fantasy adds (batch suggestion).

        ``rollback`` restores the exact pre-mark state: ssq is snapshotted
        rather than decremented so floating-point round-trip error cannot
        accumulate across repeated speculate/rollback cycles.
        """
        self._mark = (self.t, self.ssq.copy())
        return self.t

    def rollback(self) -> None:
        """Discard every observation added since the last ``mark``."""
        if self._mark is None:
            return
        t0, ssq0 = self._mark
        # rows t0..t-1 of L/V/X/y are dead storage: the next add overwrites
        # row t0 and solves only read the leading t×t / t×N blocks
        self.t = t0
        self.ssq = ssq0
        self._mark = None

    # -- incremental update --------------------------------------------------
    def add(self, x, y_val: float, extra_noise: float = 0.0):
        """Add one observation. ``extra_noise`` inflates THIS observation's
        diagonal term only — the transfer discount for warm-start records
        mapped in from another search space (repro.store.transfer)."""
        if self.t >= self.max_obs:
            return
        x = np.asarray(x, np.float64)
        t = self.t
        if t > 0:
            r = np.sqrt(np.maximum(
                np.sum((self.X[:t] - x[None, :]) ** 2, axis=1), 0.0))
            k_obs = kernel_np(self.kernel, r, self.ell)
            # forward substitution via the stored triangular factor
            l = forward_substitute(self.L[:t, :t], k_obs)
        else:
            l = np.zeros(0)
        d2 = 1.0 + self.noise + float(extra_noise) - float(l @ l)
        d = math.sqrt(max(d2, 1e-12))
        self.L[t, :t] = l
        self.L[t, t] = d

        rc = np.sqrt(np.maximum(
            np.sum((self.Xc - x[None, :]) ** 2, axis=1), 0.0))
        k_cand = kernel_np(self.kernel, rc, self.ell)
        v = (k_cand - l @ self.V[:t]) / d
        self.V[t] = v
        self.ssq += v * v
        self.X[t] = x
        self.y[t] = y_val
        self.t = t + 1

    # -- Pallas-backed posterior scoring (DESIGN.md §14) ----------------------
    def _predict_pallas(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Score arbitrary points through the fused matern_gp kernel: package
        the incremental state once (O(t²) triangular solves), stream
        candidates in ``block_n`` tiles (zero-padded to a tile multiple,
        pad rows sliced off). Interpret-mode on CPU, real on TPU."""
        import jax.numpy as jnp
        from repro.kernels import ops as _kops
        m = len(X)
        bn = self.block_n
        x_obs, vinv, w, mask, y_mean, y_std = \
            _kops.gp_inputs_from_incremental(self)
        Xp = np.zeros((m + ((-m) % bn), self.dim), np.float32)
        Xp[:m] = X
        mean, var = _kops.gp_posterior(
            jnp.asarray(Xp), jnp.asarray(x_obs), jnp.asarray(vinv),
            jnp.asarray(w), jnp.asarray(mask), ell=self.ell, nu=self.kernel,
            block_n=bn, interpret=self.interpret)
        mu = y_mean + y_std * np.asarray(mean, np.float64)[:m]
        sd = np.sqrt(np.asarray(var, np.float64)[:m]) * y_std
        return mu, sd

    # -- posterior over all candidates ----------------------------------------
    def predict(self) -> Tuple[np.ndarray, np.ndarray]:
        t = self.t
        if t == 0:
            return np.zeros(self.N), np.ones(self.N)
        if self.backend == "pallas" and self.N > 0:
            return self._predict_pallas(self.Xc)
        yv = self.y[:t]
        y_mean = float(yv.mean())
        y_std = float(yv.std())
        if y_std < 1e-12:
            y_std = 1.0
        w = forward_substitute(self.L[:t, :t], (yv - y_mean) / y_std)
        mu = y_mean + y_std * (w @ self.V[:t])
        var = np.maximum(1.0 - self.ssq, 1e-12)
        return mu, np.sqrt(var) * y_std

    # -- posterior at arbitrary points (candidate-pool mode) ------------------
    def predict_at(self, X: np.ndarray,
                   chunk: int = 65536) -> Tuple[np.ndarray, np.ndarray]:
        """Chunked posterior mean/std at points ``X`` (m, d), independent of
        the fixed candidate panel. O(t²·m) per call; memory O(t·chunk)."""
        X = np.ascontiguousarray(X, np.float64)
        m = len(X)
        t = self.t
        if t == 0:
            return np.zeros(m), np.ones(m)
        if self.backend == "pallas" and m > 0:
            return self._predict_pallas(X)
        yv = self.y[:t]
        y_mean = float(yv.mean())
        y_std = float(yv.std())
        if y_std < 1e-12:
            y_std = 1.0
        L = self.L[:t, :t]
        w = forward_substitute(L, (yv - y_mean) / y_std)
        Xo = self.X[:t]
        o_sq = np.sum(Xo * Xo, axis=1)
        mu = np.empty(m)
        var = np.empty(m)
        for lo in range(0, m, chunk):
            B = X[lo:lo + chunk]
            d2 = (np.sum(B * B, axis=1)[:, None] + o_sq[None, :]
                  - 2.0 * (B @ Xo.T))
            r = np.sqrt(np.maximum(d2, 0.0))
            K = kernel_np(self.kernel, r, self.ell)          # (mc, t)
            V = forward_substitute(L, K.T)                   # (t, mc)
            mu[lo:lo + chunk] = y_mean + y_std * (w @ V)
            var[lo:lo + chunk] = np.maximum(
                1.0 - np.sum(V * V, axis=0), 1e-12)
        return mu, np.sqrt(var) * y_std

    @property
    def y_std(self) -> float:
        t = self.t
        if t == 0:
            return 1.0
        s = float(self.y[:t].std())
        return s if s > 1e-12 else 1.0
