"""Gaussian-process surrogate in pure JAX (paper §III-B).

Matérn covariance with a FIXED lengthscale (the paper's key choice for
discontinuous spaces: lengthscale fitting is disrupted by discontinuities,
so ν=3/2 with ℓ=2.0 — or ℓ=1.5 under contextual variance — per Table I).

Static-shape design: observations are padded to ``max_obs`` with a mask, so
``fit`` and ``predict`` compile once per tuning run and are re-used for all
~220 iterations. ``predict`` evaluates EVERY candidate — the paper optimizes
the acquisition function by exhaustive prediction over the discrete space,
not by gradient ascent (§III-G). ``repro.kernels.matern_gp`` provides the
Pallas TPU kernel for this exhaustive-prediction hot loop; this module is the
jnp oracle and the CPU execution path.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SQRT3 = math.sqrt(3.0)
SQRT5 = math.sqrt(5.0)


def kernel_fn(name: str, r: jax.Array, ell: float) -> jax.Array:
    """Covariance as a function of Euclidean distance r (outputscale 1)."""
    s = r / ell
    if name == "matern12":
        return jnp.exp(-s)
    if name == "matern32":
        t = SQRT3 * s
        return (1.0 + t) * jnp.exp(-t)
    if name == "matern52":
        t = SQRT5 * s
        return (1.0 + t + (5.0 / 3.0) * jnp.square(s)) * jnp.exp(-t)
    if name == "rbf":
        return jnp.exp(-0.5 * jnp.square(s))
    raise ValueError(f"unknown kernel {name!r}")


def _pairwise_dist(a: jax.Array, b: jax.Array) -> jax.Array:
    """(N,d),(M,d) -> (N,M) Euclidean distances, numerically safe."""
    d2 = (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
          - 2.0 * (a @ b.T))
    return jnp.sqrt(jnp.maximum(d2, 0.0))


class GPState(NamedTuple):
    X: jax.Array        # (max_obs, d) padded observation inputs
    y: jax.Array        # (max_obs,)   padded observations (raw scale)
    mask: jax.Array     # (max_obs,)   True where real
    chol: jax.Array     # (max_obs, max_obs) Cholesky of masked K + noise
    alpha: jax.Array    # (max_obs,)   K^{-1}(y - mean)
    y_mean: jax.Array   # ()
    y_std: jax.Array    # ()
    n: jax.Array        # () int32 — number of real observations


@partial(jax.jit, static_argnames=("kernel", "ell", "noise"))
def gp_fit(X: jax.Array, y: jax.Array, mask: jax.Array,
           extra: Optional[jax.Array] = None, *,
           kernel: str = "matern32", ell: float = 2.0,
           noise: float = 1e-6) -> GPState:
    """Fit on padded observations. Padding rows become unit rows in K.
    ``extra`` (max_obs,) adds per-observation diagonal noise — the
    warm-start transfer discount (None: exact legacy numerics)."""
    mf = mask.astype(jnp.float32)
    n = jnp.maximum(mf.sum(), 1.0)
    y_mean = jnp.sum(y * mf) / n
    var = jnp.sum(jnp.square(y - y_mean) * mf) / n
    y_std = jnp.sqrt(jnp.maximum(var, 1e-12))
    yc = (y - y_mean) / y_std * mf

    r = _pairwise_dist(X, X)
    K = kernel_fn(kernel, r, ell)
    mm = mf[:, None] * mf[None, :]
    eye = jnp.eye(X.shape[0], dtype=K.dtype)
    K = K * mm + (1.0 - mm) * eye * 0.0
    # padding rows/cols -> identity so the Cholesky stays PD
    K = K + eye * (noise + (1.0 - mf))
    if extra is not None:
        K = K + jnp.diag(extra * mf)
    chol = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((chol, True), yc)
    return GPState(X=X, y=y, mask=mask, chol=chol, alpha=alpha,
                   y_mean=y_mean, y_std=y_std, n=mf.sum().astype(jnp.int32))


@partial(jax.jit, static_argnames=("kernel", "ell"))
def gp_predict(state: GPState, Xc: jax.Array, *, kernel: str = "matern32",
               ell: float = 2.0) -> Tuple[jax.Array, jax.Array]:
    """Posterior mean/std over candidates Xc (M,d) — the exhaustive pass."""
    mf = state.mask.astype(jnp.float32)
    r = _pairwise_dist(Xc, state.X)
    Ks = kernel_fn(kernel, r, ell) * mf[None, :]          # (M, max_obs)
    mu = Ks @ state.alpha * state.y_std + state.y_mean
    v = jax.scipy.linalg.solve_triangular(state.chol, Ks.T, lower=True)
    var = 1.0 - jnp.sum(jnp.square(v), axis=0)
    var = jnp.maximum(var, 1e-12)
    return mu, jnp.sqrt(var) * state.y_std


class GP:
    """Stateful wrapper: padded buffers + incremental add + predict."""

    def __init__(self, dim: int, max_obs: int, kernel: str = "matern32",
                 ell: float = 2.0, noise: float = 1e-6):
        self.dim = dim
        self.max_obs = max_obs
        self.kernel = kernel
        self.ell = ell
        self.noise = noise
        self.X = jnp.zeros((max_obs, dim), jnp.float32)
        self.y = jnp.zeros((max_obs,), jnp.float32)
        self.mask = jnp.zeros((max_obs,), bool)
        self._extra: jax.Array | None = None   # per-obs noise (warm start)
        self.n = 0
        self.state: GPState | None = None

    def add(self, x, y_val: float, extra_noise: float = 0.0):
        if self.n >= self.max_obs:
            return  # budget guard; caller controls budgets
        self.X = self.X.at[self.n].set(jnp.asarray(x, jnp.float32))
        self.y = self.y.at[self.n].set(float(y_val))
        self.mask = self.mask.at[self.n].set(True)
        if extra_noise and self._extra is None:
            self._extra = jnp.zeros((self.max_obs,), jnp.float32)
        if self._extra is not None:    # always write: slot may be reused
            self._extra = self._extra.at[self.n].set(float(extra_noise))
        self.n += 1
        self.state = None

    # -- speculative (fantasy) observations --------------------------------
    def mark(self) -> int:
        """Checkpoint before constant-liar/fantasy adds (batch suggestion)."""
        self._mark_n = self.n
        return self.n

    def rollback(self) -> None:
        """Discard every observation added since the last ``mark``. The padded
        buffers keep the stale rows but the mask hides them from fit/predict."""
        n0 = getattr(self, "_mark_n", None)
        self._mark_n = None
        if n0 is None or n0 >= self.n:
            return
        self.mask = self.mask.at[n0:self.n].set(False)
        self.n = n0
        self.state = None

    def fit(self) -> GPState:
        self.state = gp_fit(self.X, self.y, self.mask, self._extra,
                            kernel=self.kernel, ell=self.ell,
                            noise=self.noise)
        return self.state

    def predict(self, Xc) -> Tuple[jax.Array, jax.Array]:
        if self.state is None:
            self.fit()
        return gp_predict(self.state, jnp.asarray(Xc, jnp.float32),
                          kernel=self.kernel, ell=self.ell)

    def predict_chunked(self, Xc, chunk: int = 8192):
        """Posterior at arbitrary points, processed in fixed-size chunks (the
        last one zero-padded) so ``gp_predict`` compiles once per chunk shape
        instead of once per pool size (candidate-pool mode, DESIGN.md §10).
        Returns NumPy arrays."""
        Xc = np.asarray(Xc, np.float32)
        m = Xc.shape[0]
        if m == 0:
            return np.zeros(0), np.zeros(0)
        mus, sigmas = [], []
        for lo in range(0, m, chunk):
            block = Xc[lo:lo + chunk]
            pad = chunk - block.shape[0]
            if pad:
                block = np.vstack(
                    [block, np.zeros((pad, self.dim), np.float32)])
            mu, sigma = self.predict(block)
            mus.append(np.asarray(mu, np.float64))
            sigmas.append(np.asarray(sigma, np.float64))
        return np.concatenate(mus)[:m], np.concatenate(sigmas)[:m]
