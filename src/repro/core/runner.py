"""Tuning runner: budget accounting, caching, checkpoint/resume, metrics.

Budget semantics follow the paper: a budget of UNIQUE function evaluations
(20 initial + 200 optimization by default). Re-visits are served from cache
and don't consume budget (Kernel Tuner reports averages per configuration, so
"there is little practical need to revisit"). Invalid evaluations DO consume
budget — they cost real compile/run time on hardware.

Fault tolerance: the run journal (every observation, in order) is serialized
after each evaluation when a checkpoint path is given; `resume` replays the
journal through the cache so a killed tuning run continues losslessly —
the same property the paper's simulation mode exploits, required here for
cluster-scale objectives (a dry-run compile job can take minutes).
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.objectives import Objective


class BudgetExhausted(Exception):
    """Raised by TuningRun's direct-evaluation API when the budget or the
    total-call cap is hit. The ask/tell engine (repro.core.engine) never
    raises it — it simply stops asking — but the exception remains for code
    that drives a TuningRun by hand."""


@dataclass
class Observation:
    idx: Optional[int]          # None for configs outside the space
    key: str                    # unique key (space idx or config repr)
    value: float                # NaN = invalid
    af: Optional[str] = None    # acquisition function that proposed it
    t: float = 0.0
    worker: str = "main"        # engine worker that ran the evaluation
    dur: float = 0.0            # seconds spent in the objective call


class TuningRun:
    def __init__(self, objective: Objective, budget: int,
                 max_total_calls: Optional[int] = None,
                 checkpoint_path: Optional[str] = None):
        self.objective = objective
        self.space = objective.space
        self.budget = budget
        self.max_total_calls = max_total_calls or budget * 50
        self.checkpoint_path = checkpoint_path
        self.cache: Dict[str, float] = {}
        self.journal: List[Observation] = []
        self.evaluated_idx: Dict[int, float] = {}
        self.total_calls = 0
        self.t0 = time.time()

    # -- core evaluation ----------------------------------------------------
    @property
    def unique_evals(self) -> int:
        return len(self.cache)

    def _record(self, key: str, idx: Optional[int], value: float,
                af: Optional[str]):
        self.cache[key] = value
        if idx is not None:
            self.evaluated_idx[idx] = value
        self.journal.append(Observation(idx, key, value, af,
                                        time.time() - self.t0))
        if self.checkpoint_path:
            self._checkpoint()

    def evaluate(self, idx: int, af: Optional[str] = None) -> float:
        key = str(int(idx))
        self.total_calls += 1
        if key in self.cache:
            if self.total_calls > self.max_total_calls:
                raise BudgetExhausted
            return self.cache[key]
        if self.unique_evals >= self.budget:
            raise BudgetExhausted
        value = self.objective(int(idx))
        self._record(key, int(idx), value, af)
        return value

    def evaluate_config(self, cfg: Dict[str, Any], af: Optional[str] = None) -> float:
        """For constraint-unaware baselines proposing raw config dicts."""
        idx = self.space.index_of(cfg)
        if idx is not None:
            return self.evaluate(idx, af)
        key = "cfg:" + json.dumps(cfg, sort_keys=True, default=str)
        self.total_calls += 1
        if key in self.cache:
            if self.total_calls > self.max_total_calls:
                raise BudgetExhausted
            return self.cache[key]
        if self.unique_evals >= self.budget:
            raise BudgetExhausted
        self._record(key, None, math.nan, af)   # outside restricted space
        return math.nan

    # -- results ------------------------------------------------------------
    def best(self) -> Tuple[Optional[int], float]:
        best_idx, best_val = None, math.inf
        for idx, v in self.evaluated_idx.items():
            if math.isfinite(v) and v < best_val:
                best_idx, best_val = idx, v
        return best_idx, best_val

    def best_trace(self) -> np.ndarray:
        """best-so-far value after each unique evaluation (inf until a valid)."""
        out = np.empty(len(self.journal))
        cur = math.inf
        for i, o in enumerate(self.journal):
            if math.isfinite(o.value) and o.value < cur:
                cur = o.value
            out[i] = cur
        return out

    # -- fault tolerance ----------------------------------------------------
    def _checkpoint(self):
        tmp = self.checkpoint_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"objective": self.objective.name,
                       "budget": self.budget,
                       "journal": [[o.idx, o.key, o.value, o.af] for o in self.journal]},
                      f)
        os.replace(tmp, self.checkpoint_path)

    def resume(self) -> int:
        """Replay a journal written by a previous (killed) run. Returns #replayed."""
        if not self.checkpoint_path or not os.path.exists(self.checkpoint_path):
            return 0
        with open(self.checkpoint_path) as f:
            data = json.load(f)
        for idx, key, value, af in data["journal"]:
            self.cache[key] = value
            if idx is not None:
                self.evaluated_idx[idx] = value
            self.journal.append(Observation(idx, key, value, af))
        return len(data["journal"])


@dataclass
class TuneResult:
    strategy: str
    objective: str
    best_idx: Optional[int]
    best_value: float
    trace: np.ndarray
    unique_evals: int
    wall_time_s: float
    journal: List[Observation] = field(default_factory=list)
    worker_stats: Dict[str, Dict] = field(default_factory=dict)


def run_strategy(strategy, objective: Objective, budget: int,
                 seed: int = 0, checkpoint_path: Optional[str] = None,
                 resume: bool = False, batch_size: int = 1, workers: int = 1,
                 max_in_flight: Optional[int] = None,
                 backend: str = "thread") -> TuneResult:
    """Thin wrapper over the ask/tell engine (repro.core.engine).

    The defaults (``batch_size=1, workers=1``) evaluate inline in this thread
    and reproduce the historical sequential runner bit-for-bit; raise
    ``workers``/``batch_size`` to parallelize the expensive compile-and-run
    step."""
    from repro.core.engine import ParallelTuningEngine
    engine = ParallelTuningEngine(objective, budget, batch_size=batch_size,
                                  workers=workers, max_in_flight=max_in_flight,
                                  backend=backend,
                                  checkpoint_path=checkpoint_path)
    return engine.run(strategy, seed=seed, resume=resume)
