"""Tuning runner: budget accounting, caching, checkpoint/resume, metrics.

Budget semantics follow the paper: a budget of UNIQUE function evaluations
(20 initial + 200 optimization by default). Re-visits are served from cache
and don't consume budget (Kernel Tuner reports averages per configuration, so
"there is little practical need to revisit"). Invalid evaluations DO consume
budget — they cost real compile/run time on hardware.

Fault tolerance: every observation streams, in acceptance order, into a
``repro.store`` record stream when a checkpoint path (single-file store) or
a shared ``TuningRecordStore`` is given; ``resume`` replays the run's
records through the cache so a killed tuning run continues losslessly — the
same property the paper's simulation mode exploits, required here for
cluster-scale objectives (a dry-run compile job can take minutes). Journals
written in the pre-store whole-JSON format are migrated in place on resume
(``repro.store.migrate``); resume rejects records whose fingerprint does not
match the current problem.
"""
from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.objectives import Objective
from repro.store.migrate import is_legacy_checkpoint, migrate_checkpoint
from repro.store.records import (SpaceFingerprint, TuningRecord,
                                 TuningRecordStore)


class BudgetExhausted(Exception):
    """Raised by TuningRun's direct-evaluation API when the budget or the
    total-call cap is hit. The ask/tell engine (repro.core.engine) never
    raises it — it simply stops asking — but the exception remains for code
    that drives a TuningRun by hand."""


@dataclass
class Observation:
    idx: Optional[int]          # None for configs outside the space
    key: str                    # unique key (space idx or config repr)
    value: float                # NaN = invalid
    af: Optional[str] = None    # acquisition function that proposed it
    t: float = 0.0
    worker: str = "main"        # engine worker that ran the evaluation
    dur: float = 0.0            # seconds spent in the objective call


class TuningRun:
    def __init__(self, objective: Objective, budget: int,
                 max_total_calls: Optional[int] = None,
                 checkpoint_path: Optional[str] = None,
                 store: Optional[TuningRecordStore] = None,
                 run_id: Optional[str] = None, context: str = "",
                 run_meta: Optional[Dict[str, Any]] = None):
        self.objective = objective
        self.space = objective.space
        self.budget = budget
        self.max_total_calls = max_total_calls or budget * 50
        self.checkpoint_path = checkpoint_path
        self.store = store          # opened lazily when only a path is given
        self.run_id = run_id or "journal"
        self.run_meta = run_meta or {}
        self.fingerprint = SpaceFingerprint.of(
            self.space, objective=objective.name, context=context)
        self.cache: Dict[str, float] = {}
        self.journal: List[Observation] = []
        self.evaluated_idx: Dict[int, float] = {}
        self.total_calls = 0
        self.t0 = time.time()

    # -- core evaluation ----------------------------------------------------
    @property
    def unique_evals(self) -> int:
        return len(self.cache)

    def _record(self, key: str, idx: Optional[int], value: float,
                af: Optional[str], worker: str = "main", dur: float = 0.0):
        self.cache[key] = value
        if idx is not None:
            self.evaluated_idx[idx] = value
        obs = Observation(idx, key, value, af, time.time() - self.t0,
                          worker=worker, dur=dur)
        self.journal.append(obs)
        store = self._open_store()
        if store is not None:
            store.append(self._to_record(obs, len(self.journal) - 1),
                         fingerprint=self.fingerprint)

    def evaluate(self, idx: int, af: Optional[str] = None) -> float:
        key = str(int(idx))
        self.total_calls += 1
        if key in self.cache:
            if self.total_calls > self.max_total_calls:
                raise BudgetExhausted
            return self.cache[key]
        if self.unique_evals >= self.budget:
            raise BudgetExhausted
        value = self.objective(int(idx))
        self._record(key, int(idx), value, af)
        return value

    def evaluate_config(self, cfg: Dict[str, Any], af: Optional[str] = None) -> float:
        """For constraint-unaware baselines proposing raw config dicts."""
        idx = self.space.index_of(cfg)
        if idx is not None:
            return self.evaluate(idx, af)
        key = "cfg:" + json.dumps(cfg, sort_keys=True, default=str)
        self.total_calls += 1
        if key in self.cache:
            if self.total_calls > self.max_total_calls:
                raise BudgetExhausted
            return self.cache[key]
        if self.unique_evals >= self.budget:
            raise BudgetExhausted
        self._record(key, None, math.nan, af)   # outside restricted space
        return math.nan

    # -- results ------------------------------------------------------------
    def best(self) -> Tuple[Optional[int], float]:
        best_idx, best_val = None, math.inf
        for idx, v in self.evaluated_idx.items():
            if math.isfinite(v) and v < best_val:
                best_idx, best_val = idx, v
        return best_idx, best_val

    def best_trace(self) -> np.ndarray:
        """best-so-far value after each unique evaluation (inf until a valid)."""
        out = np.empty(len(self.journal))
        cur = math.inf
        for i, o in enumerate(self.journal):
            if math.isfinite(o.value) and o.value < cur:
                cur = o.value
            out[i] = cur
        return out

    # -- fault tolerance (store-backed journal) -----------------------------
    def _open_store(self) -> Optional[TuningRecordStore]:
        if self.store is None and self.checkpoint_path:
            self.store = TuningRecordStore(self.checkpoint_path)
        return self.store

    def _config_of(self, idx: Optional[int], key: str) -> Optional[Dict]:
        if idx is not None:
            return self.space.config(int(idx))
        if key.startswith("cfg:"):
            return json.loads(key[4:])
        return None

    def _to_record(self, o: Observation, seq: int) -> TuningRecord:
        return TuningRecord(
            fp=self.fingerprint.digest, run=self.run_id, seq=seq, key=o.key,
            idx=o.idx, value=o.value, af=o.af,
            config=self._config_of(o.idx, o.key), worker=o.worker, dur=o.dur,
            t=o.t, meta=self.run_meta)

    def resume(self) -> int:
        """Replay this run's record stream from the store (migrating a
        pre-store whole-JSON checkpoint in place first). Returns #replayed.
        Records under a different fingerprint are rejected: resuming a journal
        against the wrong space/objective corrupted runs silently before."""
        if self.checkpoint_path and is_legacy_checkpoint(self.checkpoint_path):
            migrate_checkpoint(self.checkpoint_path, self.fingerprint,
                               self.space, run_id=self.run_id)
        store = self._open_store()
        if store is None:
            return 0
        if store.single_file:
            # a journal file IS one run: any foreign fingerprint in it means
            # the space/objective changed under the checkpoint path
            recs = store.records(run=self.run_id)
            bad = [r for r in recs if r.fp != self.fingerprint.digest]
            if bad:
                raise ValueError(
                    f"run {self.run_id!r}: {len(bad)} stored records carry "
                    f"fingerprint {bad[0].fp}, current problem is "
                    f"{self.fingerprint.digest} ({self.fingerprint.objective})"
                    " — refusing to resume across space/objective changes")
        else:
            # shared store: the same run tag legitimately recurs under other
            # fingerprints (same strategy/seed on another kernel) — and
            # querying by digest keeps a lazy (indexed) open O(hot set)
            recs = store.records(fp=self.fingerprint.digest, run=self.run_id)
        # a twice-resumed run spans segments whose filename order need not
        # follow write order (new pid sorts before old) — seq is the truth
        recs.sort(key=lambda r: r.seq)
        for r in recs:
            self.cache[r.key] = r.value
            if r.idx is not None:
                self.evaluated_idx[r.idx] = r.value
            self.journal.append(Observation(r.idx, r.key, r.value, r.af,
                                            worker=r.worker, dur=r.dur))
        return len(recs)


@dataclass
class TuneResult:
    strategy: str
    objective: str
    best_idx: Optional[int]
    best_value: float
    trace: np.ndarray
    unique_evals: int
    wall_time_s: float
    journal: List[Observation] = field(default_factory=list)
    worker_stats: Dict[str, Dict] = field(default_factory=dict)


def run_strategy(strategy, objective: Objective, budget: int,
                 seed: int = 0, checkpoint_path: Optional[str] = None,
                 resume: bool = False, batch_size: int = 1, workers: int = 1,
                 max_in_flight: Optional[int] = None,
                 backend: str = "thread",
                 store=None, run_id: Optional[str] = None,
                 warm_start: bool = True) -> TuneResult:
    """Thin wrapper over the ask/tell engine (repro.core.engine).

    The defaults (``batch_size=1, workers=1``) evaluate inline in this thread
    and reproduce the historical sequential runner bit-for-bit; raise
    ``workers``/``batch_size`` to parallelize the expensive compile-and-run
    step. ``store`` (a TuningRecordStore or path) persists the journal and
    warm-starts the strategy from matching prior records."""
    from repro.core.engine import ParallelTuningEngine
    engine = ParallelTuningEngine(objective, budget, batch_size=batch_size,
                                  workers=workers, max_in_flight=max_in_flight,
                                  backend=backend,
                                  checkpoint_path=checkpoint_path,
                                  store=store, run_id=run_id,
                                  warm_start=warm_start)
    return engine.run(strategy, seed=seed, resume=resume)
