"""Discrete, constrained, normalized search spaces (paper §III-D).

The paper's representation decisions, reproduced exactly:
  * mixed-type parameters (ints, floats, strings, bools) — each parameter is
    an *ordered* list of values (the user is responsible for the ordering);
  * every numerical input is normalized "in a linear fashion" onto [0, 1] by
    ordinal position, which removes the distance distortion of non-linear
    value sets (powers of two etc.) and gives categorical values an integer
    encoding (§III-D1);
  * constraints ("restrictions") filter the Cartesian product up front;
  * runtime-invalid configurations are a property of the *objective*, not the
    space — the tuner discovers them (§III-D2).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Param:
    name: str
    values: Tuple[Any, ...]

    def __post_init__(self):
        assert len(self.values) >= 1


Constraint = Callable[[Dict[str, Any]], bool]


class SearchSpace:
    """Enumerated constrained space with ordinal-normalized coordinates."""

    def __init__(self, params: Sequence[Param],
                 constraints: Sequence[Constraint] = (),
                 name: str = "space", max_enumeration: int = 2_000_000):
        self.name = name
        self.params: Tuple[Param, ...] = tuple(params)
        self.constraints = tuple(constraints)
        cart = math.prod(len(p.values) for p in self.params)
        if cart > max_enumeration:
            raise ValueError(f"{name}: cartesian product {cart} too large to enumerate")
        self.cartesian_size = cart

        cols = []
        for idx_tuple in itertools.product(*[range(len(p.values)) for p in self.params]):
            cols.append(idx_tuple)
        idx = np.asarray(cols, dtype=np.int32)
        if self.constraints:
            keep = np.ones(len(idx), dtype=bool)
            for i, row in enumerate(idx):
                cfgd = {p.name: p.values[row[j]] for j, p in enumerate(self.params)}
                for c in self.constraints:
                    if not c(cfgd):
                        keep[i] = False
                        break
            idx = idx[keep]
        self.value_indices = idx                     # (N, d) int32
        self.size = len(idx)
        self.dim = len(self.params)
        if self.size == 0:
            raise ValueError(f"{name}: all configurations violate constraints")

        # ordinal normalization: value j of n -> j/(n-1)  (n==1 -> 0.5)
        denom = np.array([max(len(p.values) - 1, 1) for p in self.params],
                         dtype=np.float32)
        self.X_norm = idx.astype(np.float32) / denom
        for j, p in enumerate(self.params):
            if len(p.values) == 1:
                self.X_norm[:, j] = 0.5

        self._lookup: Dict[Tuple[int, ...], int] = {
            tuple(row): i for i, row in enumerate(idx)}

    # -- config access ------------------------------------------------------
    def config(self, i: int) -> Dict[str, Any]:
        row = self.value_indices[i]
        return {p.name: p.values[row[j]] for j, p in enumerate(self.params)}

    def configs(self, ids: Sequence[int]) -> List[Dict[str, Any]]:
        return [self.config(i) for i in ids]

    def index_of(self, cfg: Dict[str, Any]) -> Optional[int]:
        try:
            key = tuple(p.values.index(cfg[p.name]) for p in self.params)
        except (ValueError, KeyError):
            return None
        return self._lookup.get(key)

    # -- neighborhoods (Hamming: differ in exactly one parameter) -----------
    def hamming_neighbors(self, i: int) -> List[int]:
        row = self.value_indices[i]
        out = []
        for j, p in enumerate(self.params):
            for v in range(len(p.values)):
                if v == row[j]:
                    continue
                key = tuple(row[:j]) + (v,) + tuple(row[j + 1:])
                k = self._lookup.get(key)
                if k is not None:
                    out.append(k)
        return out

    def adjacent_neighbors(self, i: int) -> List[int]:
        """Differ in one parameter by one ordinal step (for local search)."""
        row = self.value_indices[i]
        out = []
        for j in range(self.dim):
            for dv in (-1, 1):
                v = row[j] + dv
                if 0 <= v < len(self.params[j].values):
                    key = tuple(row[:j]) + (int(v),) + tuple(row[j + 1:])
                    k = self._lookup.get(key)
                    if k is not None:
                        out.append(k)
        return out

    def random_index(self, rng: np.random.Generator) -> int:
        return int(rng.integers(0, self.size))

    def nearest_index(self, x_norm: np.ndarray,
                      exclude: Optional[set] = None) -> int:
        """Snap a [0,1]^d point to the nearest enumerated config (L2)."""
        d2 = np.sum((self.X_norm - x_norm[None, :]) ** 2, axis=1)
        if exclude:
            d2 = d2.copy()
            d2[list(exclude)] = np.inf
        return int(np.argmin(d2))

    def describe(self) -> str:
        lines = [f"SearchSpace {self.name}: {self.size} configs "
                 f"(cartesian {self.cartesian_size}, {self.dim} params)"]
        for p in self.params:
            vals = ", ".join(str(v) for v in p.values[:8])
            more = "..." if len(p.values) > 8 else ""
            lines.append(f"  {p.name}: [{vals}{more}] ({len(p.values)})")
        return "\n".join(lines)
