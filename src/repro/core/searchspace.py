"""Discrete, constrained, normalized search spaces (paper §III-D).

The paper's representation decisions, reproduced exactly:
  * mixed-type parameters (ints, floats, strings, bools) — each parameter is
    an *ordered* list of values (the user is responsible for the ordering);
  * every numerical input is normalized "in a linear fashion" onto [0, 1] by
    ordinal position, which removes the distance distortion of non-linear
    value sets (powers of two etc.) and gives categorical values an integer
    encoding (§III-D1);
  * constraints ("restrictions") filter the Cartesian product up front;
  * runtime-invalid configurations are a property of the *objective*, not the
    space — the tuner discovers them (§III-D2).

Scale (DESIGN.md §9): enumeration is chunked + vectorized — each chunk of the
Cartesian product is decoded arithmetically from its mixed-radix index (the
same lexicographic order ``itertools.product`` produced, so config indices
are stable across the refactor) and constraints declared as
``VectorConstraint`` are evaluated on whole value columns at once. Plain
``Constraint`` callables still work through a chunked per-row fallback.
Config lookup runs on the sorted mixed-radix code array (binary search, no
per-row tuple dict), and Hamming/adjacent neighborhoods are served from a
lazily built CSR index (or computed per row, vectorized, above
``csr_build_max`` configs).

Beyond enumeration (DESIGN.md §15): a space whose Cartesian product exceeds
``max_enumeration`` is constructed as a ``GenerativeSpace`` — the same API
surface with NO materialized codes, value-index table, or X_norm. Config
identity is the mixed-radix code itself, feasible samples come from
EWMA-adaptive rejection draws (declaration-order short-circuit preserved)
that automatically hand off to a constraint-PROPAGATING backtracking sampler
when acceptance collapses, neighborhoods are feasible walks validity-checked per candidate
and memoized like the partial-CSR frontier, and nearest-point queries round
per-dimension (exact when the rounded config is feasible) with a
deterministic feasible anchor-sample fallback. Construction is O(d).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Cartesian-product ceiling. Vectorized enumeration makes 10^7+ practical
#: (benchmarks/space_bench.py); the cap only guards against runaway memory.
DEFAULT_MAX_ENUMERATION = 20_000_000

#: Rows decoded/filtered per enumeration chunk.
ENUM_CHUNK = 1 << 17

#: Spaces at most this large get a precomputed CSR neighbor index on first
#: neighbor query; larger spaces answer each query vectorized on demand.
CSR_BUILD_MAX = 1 << 18

#: Kept-config count at which X_norm switches from an eagerly materialized
#: float32 (N, d) matrix to a chunk-computed row provider (LazyNorm).
X_NORM_LAZY_MIN = 10_000_000

#: On-demand neighbor rows memoized over the visited region (partial CSR) on
#: spaces too large for the precomputed index. FIFO-evicted above this count.
NEIGHBOR_CACHE_MAX = 1 << 16

#: Acceptance-EWMA threshold below which GenerativeSpace routes feasible
#: draws through the constraint-propagating sampler instead of rejection.
#: The EWMA initializes optimistically at 1.0, so loosely-constrained spaces
#: never cross it and keep byte-identical rejection draw streams.
PROPAGATE_BELOW = 0.01

#: Dead-end prefix memo entries kept per generative space (FIFO-evicted,
#: same policy as the partial-CSR neighbor cache).
DEAD_PREFIX_CACHE_MAX = 1 << 16


@dataclass(frozen=True)
class Param:
    name: str
    values: Tuple[Any, ...]

    def __post_init__(self):
        assert len(self.values) >= 1


Constraint = Callable[[Dict[str, Any]], bool]


class VectorConstraint:
    """A restriction evaluated on whole value columns at once.

    ``fn`` receives a dict mapping parameter name -> value array (one entry
    per candidate row of the current enumeration chunk) and returns a boolean
    array. NumPy's elementwise semantics mean most scalar restrictions — e.g.
    ``lambda c: c["MWG"] % (c["MDIMC"] * c["VWM"]) == 0`` — are already valid
    column predicates; wrapping marks them safe to broadcast. The same ``fn``
    serves scalar config dicts, so a VectorConstraint is a drop-in
    ``Constraint`` everywhere one is accepted.
    """

    __slots__ = ("fn", "name")

    def __init__(self, fn: Callable, name: Optional[str] = None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "<lambda>")

    def mask(self, cols: Dict[str, np.ndarray], n_rows: int) -> np.ndarray:
        out = np.asarray(self.fn(cols))
        if out.shape != (n_rows,):
            raise ValueError(
                f"VectorConstraint {self.name!r} returned shape {out.shape}, "
                f"expected ({n_rows},) — not a column predicate")
        return out.astype(bool, copy=False)

    def __call__(self, cfg: Dict[str, Any]) -> bool:
        return bool(self.fn(cfg))


class _DepProbe(dict):
    """Config mapping that records which parameter names a constraint
    actually reads — dependency discovery for constraint propagation."""

    def __init__(self, base: Dict[str, Any], seen: set):
        super().__init__(base)
        self.seen = seen

    def __getitem__(self, key):
        self.seen.add(key)
        return super().__getitem__(key)


def _jeffreys_interval(hits: int, draws: int,
                       conf: float = 0.95) -> Tuple[float, float]:
    """Jeffreys binomial interval: equal-tailed Beta(1/2+hits, 1/2+misses)
    quantiles — the standard choice for proportions near 0, where the
    normal approximation collapses. Falls back to a Wilson score interval
    when scipy is unavailable."""
    a, b = hits + 0.5, draws - hits + 0.5
    tail = (1.0 - conf) / 2.0
    try:
        from scipy.stats import beta as _beta
        return float(_beta.ppf(tail, a, b)), float(_beta.ppf(1.0 - tail, a, b))
    except Exception:
        p = hits / max(draws, 1)
        z = 1.959963984540054
        den = 1.0 + z * z / draws
        mid = (p + z * z / (2.0 * draws)) / den
        half = z * math.sqrt(p * (1.0 - p) / draws
                             + z * z / (4.0 * draws * draws)) / den
        return max(mid - half, 0.0), min(mid + half, 1.0)


class LazyNorm:
    """Chunk-computed view of the normalized coordinate matrix.

    Above ``x_norm_lazy_min`` kept configs the full float32 (N, d) matrix is
    never materialized; rows are decoded from ``value_indices`` on demand.
    Supports exactly the access patterns the tuning stack uses — integer,
    slice and fancy indexing — each returning a fresh dense array for the
    requested rows only.
    """

    __slots__ = ("_vi", "_denom", "_single", "shape")
    dtype = np.dtype(np.float32)

    def __init__(self, value_indices: np.ndarray, denom: np.ndarray,
                 single: np.ndarray):
        self._vi = value_indices
        self._denom = denom          # (d,) float32: max(n_j - 1, 1)
        self._single = single        # (d,) bool: single-valued params -> 0.5
        self.shape = value_indices.shape

    def __len__(self) -> int:
        return self.shape[0]

    def __getitem__(self, key) -> np.ndarray:
        X = self._vi[key].astype(np.float32) / self._denom
        if self._single.any():
            X[..., self._single] = 0.5
        return X


class SearchSpace:
    """Enumerated constrained space with ordinal-normalized coordinates.

    When the Cartesian product exceeds ``max_enumeration``,
    ``SearchSpace(...)`` transparently constructs a :class:`GenerativeSpace`
    instead of raising — the non-enumerative backend behind the same API
    (DESIGN.md §15). Explicit subclasses are never redirected.
    """

    #: True on the generative backend; consumers that need dense-position
    #: semantics (e.g. full-space acquisition) branch on this.
    generative = False

    def __new__(cls, *args, **kwargs):
        if cls is SearchSpace and (args or "params" in kwargs):
            params = kwargs.get("params", args[0] if args else ())
            max_enum = kwargs.get("max_enumeration")
            if max_enum is None and len(args) >= 4:
                max_enum = args[3]
            if max_enum is None:
                max_enum = DEFAULT_MAX_ENUMERATION
            try:
                cart = math.prod(len(p.values) for p in params)
            except (TypeError, AttributeError):
                cart = 0
            if cart > max_enum:
                # too large to enumerate: fall through to the generative
                # backend (Python then runs GenerativeSpace.__init__ with
                # the same arguments)
                return super().__new__(GenerativeSpace)
        return super().__new__(cls)

    def __init__(self, params: Sequence[Param],
                 constraints: Sequence[Constraint] = (),
                 name: str = "space",
                 max_enumeration: int = DEFAULT_MAX_ENUMERATION,
                 chunk_size: int = ENUM_CHUNK,
                 csr_build_max: int = CSR_BUILD_MAX,
                 x_norm_lazy_min: int = X_NORM_LAZY_MIN,
                 neighbor_cache_max: int = NEIGHBOR_CACHE_MAX):
        cart = self._init_radix(params, constraints, name,
                                csr_build_max=csr_build_max,
                                x_norm_lazy_min=x_norm_lazy_min,
                                neighbor_cache_max=neighbor_cache_max)
        if cart > max_enumeration:
            raise ValueError(f"{name}: cartesian product {cart} too large to enumerate")
        self.cartesian_size = cart

        idx, codes = self._enumerate(chunk_size)
        self.value_indices = idx                     # (N, d) int32
        self._codes = codes                          # (N,) int64, ascending
        self.size = len(idx)
        if self.size == 0:
            raise ValueError(f"{name}: all configurations violate constraints")

        self._set_x_norm()
        self._h_csr: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._a_csr: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._row_sq: Optional[np.ndarray] = None   # lazy ||X_norm||² cache
        self._nbr_cache: Dict[Tuple[str, int], np.ndarray] = {}

    def _init_radix(self, params: Sequence[Param],
                    constraints: Sequence[Constraint], name: str, *,
                    csr_build_max: int = CSR_BUILD_MAX,
                    x_norm_lazy_min: int = X_NORM_LAZY_MIN,
                    neighbor_cache_max: int = NEIGHBOR_CACHE_MAX) -> int:
        """Backend-independent setup (params, mixed-radix strides, value
        columns, normalization constants); returns the Cartesian size."""
        self.name = name
        self.params: Tuple[Param, ...] = tuple(params)
        self.constraints = tuple(constraints)
        self.dim = len(self.params)
        self._csr_build_max = csr_build_max
        self._x_norm_lazy_min = x_norm_lazy_min
        self._nbr_cache_max = neighbor_cache_max

        nvals = np.array([len(p.values) for p in self.params], np.int64)
        cart = math.prod(int(n) for n in nvals)
        # mixed-radix strides: the LAST parameter varies fastest, which is
        # exactly itertools.product's lexicographic order — decoding ascending
        # global indices g via (g // stride_j) % n_j reproduces the historical
        # enumeration (and therefore every pinned config index) bit-for-bit.
        strides = np.ones(self.dim, np.int64)
        for j in range(self.dim - 2, -1, -1):
            strides[j] = strides[j + 1] * nvals[j + 1]
        self._nvals = nvals
        self._strides = strides
        self._value_arrays = [np.asarray(p.values) for p in self.params]
        self._norm_denom = np.array(
            [max(len(p.values) - 1, 1) for p in self.params], np.float32)
        self._norm_single = np.array(
            [len(p.values) == 1 for p in self.params], bool)
        return cart

    def _constrain(self, idx: np.ndarray, alive: np.ndarray) -> np.ndarray:
        """Filter ``alive`` (row positions into ``idx``) through the
        constraints in declaration order, short-circuiting on survivors —
        the exact per-row semantics the seed's Python loop had."""
        for c in self.constraints:
            if alive.size == 0:
                break
            sub = idx[alive]
            if isinstance(c, VectorConstraint):
                cols = {p.name: arr[sub[:, j]] for j, (p, arr) in
                        enumerate(zip(self.params, self._value_arrays))}
                alive = alive[c.mask(cols, len(alive))]
            else:  # plain callable: chunked per-row fallback
                ok = np.fromiter(
                    (c({p.name: p.values[int(sub[i, j])]
                        for j, p in enumerate(self.params)})
                     for i in range(len(alive))),
                    dtype=bool, count=len(alive))
                alive = alive[ok]
        return alive

    # -- enumeration ---------------------------------------------------------
    def _enumerate(self, chunk_size: int) -> Tuple[np.ndarray, np.ndarray]:
        """Chunked vectorized Cartesian product + constraint filtering."""
        cart, d = self.cartesian_size, self.dim
        kept_idx: List[np.ndarray] = []
        kept_codes: List[np.ndarray] = []
        for lo in range(0, cart, chunk_size):
            g = np.arange(lo, min(lo + chunk_size, cart), dtype=np.int64)
            idx = (g[:, None] // self._strides[None, :]) % self._nvals[None, :]
            alive = self._constrain(idx, np.arange(len(g)))
            if alive.size:
                kept_idx.append(idx[alive].astype(np.int32))
                kept_codes.append(g[alive])
        if not kept_idx:
            return (np.zeros((0, d), np.int32), np.zeros(0, np.int64))
        return np.vstack(kept_idx), np.concatenate(kept_codes)

    def _set_x_norm(self) -> None:
        """Ordinal normalization: value j of n -> j/(n-1)  (n==1 -> 0.5).
        Above ``x_norm_lazy_min`` kept configs rows are chunk-computed on
        demand instead of materializing the full float32 (N, d) matrix."""
        lazy = LazyNorm(self.value_indices, self._norm_denom,
                        self._norm_single)
        self.X_norm = (lazy if self.size >= self._x_norm_lazy_min
                       else lazy[:])

    @property
    def x_norm_lazy(self) -> bool:
        return isinstance(self.X_norm, LazyNorm)

    def take(self, keep: np.ndarray) -> "SearchSpace":
        """Restrict the space to a sorted subset of its config indices
        (deterministic trimming, repro.core.spaces._trim). In place."""
        keep = np.asarray(keep)
        if np.any(np.diff(self._codes[keep]) <= 0):
            # checked before any mutation so a rejected call leaves the
            # space untouched
            raise ValueError("take() needs a sorted, duplicate-free subset: "
                             "code lookups binary-search an ascending array")
        self.value_indices = self.value_indices[keep]
        self._codes = self._codes[keep]
        self.size = len(self.value_indices)
        self._set_x_norm()
        self._h_csr = self._a_csr = self._row_sq = None
        self._nbr_cache = {}
        return self

    # -- config access ------------------------------------------------------
    def config(self, i: int) -> Dict[str, Any]:
        row = self.value_indices[i]
        return {p.name: p.values[row[j]] for j, p in enumerate(self.params)}

    def configs(self, ids: Sequence[int]) -> List[Dict[str, Any]]:
        return [self.config(i) for i in ids]

    def _find_code(self, code: int) -> Optional[int]:
        if code < 0 or code >= self.cartesian_size:
            # out-of-grid short-circuit: skip the binary search entirely —
            # hot in feasible-walk rejection loops
            return None
        pos = int(np.searchsorted(self._codes, code))
        if pos < self.size and self._codes[pos] == code:
            return pos
        return None

    def index_of(self, cfg: Dict[str, Any]) -> Optional[int]:
        try:
            key = tuple(p.values.index(cfg[p.name]) for p in self.params)
        except (ValueError, KeyError):
            return None
        return self._find_code(sum(k * int(s) for k, s in zip(key, self._strides)))

    def index_of_value_indices(self, row: Sequence[int]) -> Optional[int]:
        """Row of per-param value ordinals -> config index (or None if the
        combination was filtered out by the constraints)."""
        code = 0
        for v, n, s in zip(row, self._nvals, self._strides):
            v = int(v)
            if v < 0 or v >= n:
                # out-of-grid ordinal: without this check the radix fold can
                # alias a DIFFERENT valid config's code and return its index
                return None
            code += v * int(s)
        return self._find_code(code)

    # -- neighborhoods (Hamming: differ in exactly one parameter) -----------
    def _hamming_candidates(self, rows: np.ndarray, codes: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """(m,d) ordinal rows -> (m,K) candidate codes + validity, K = Σ n_j.
        Column order is (param j asc, value v asc, v != row_j) — the exact
        order the historical dict-probe loops produced."""
        cand, valid = [], []
        for j in range(self.dim):
            vs = np.arange(self._nvals[j], dtype=np.int64)
            cand.append(codes[:, None]
                        + (vs[None, :] - rows[:, j:j + 1]) * self._strides[j])
            valid.append(vs[None, :] != rows[:, j:j + 1])
        return np.concatenate(cand, axis=1), np.concatenate(valid, axis=1)

    def _adjacent_candidates(self, rows: np.ndarray, codes: np.ndarray
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """Column order (param j asc, dv in (-1, +1)), matching the old loop."""
        cand, valid = [], []
        for j in range(self.dim):
            for dv in (-1, 1):
                v = rows[:, j] + dv
                cand.append((codes + dv * self._strides[j])[:, None])
                valid.append(((v >= 0) & (v < self._nvals[j]))[:, None])
        return np.concatenate(cand, axis=1), np.concatenate(valid, axis=1)

    def _resolve_candidates(self, cand: np.ndarray, valid: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Candidate codes -> (found mask, positions), constraint-aware."""
        pos = np.searchsorted(self._codes, cand)
        pos_c = np.minimum(pos, self.size - 1)
        found = valid & (self._codes[pos_c] == cand)
        return found, pos_c

    def _build_csr(self, candidates_fn, chunk: int = 1 << 14
                   ) -> Tuple[np.ndarray, np.ndarray]:
        counts = np.zeros(self.size, np.int64)
        blocks: List[np.ndarray] = []
        rows_all = self.value_indices.astype(np.int64)
        for lo in range(0, self.size, chunk):
            hi = min(lo + chunk, self.size)
            cand, valid = candidates_fn(rows_all[lo:hi], self._codes[lo:hi])
            found, pos = self._resolve_candidates(cand, valid)
            counts[lo:hi] = found.sum(axis=1)
            blocks.append(pos[found].astype(np.int32))  # row-major: per-row
            #                                             column order kept
        indptr = np.zeros(self.size + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        indices = (np.concatenate(blocks) if blocks
                   else np.zeros(0, np.int32))
        return indptr, indices

    def _neighbors(self, i: int, candidates_fn, csr_attr: str) -> List[int]:
        csr = getattr(self, csr_attr)
        if csr is None and self.size <= self._csr_build_max:
            csr = self._build_csr(candidates_fn)
            setattr(self, csr_attr, csr)
        if csr is not None:
            indptr, indices = csr
            return indices[indptr[i]:indptr[i + 1]].tolist()
        # space too large for a precomputed index: partial CSR over the
        # visited region — local searches (SA/MLS/GA) re-query the incumbent
        # neighborhood every step, so memoized rows turn the ~90 µs vectorized
        # recompute into a dict hit. FIFO-evicted above _nbr_cache_max rows.
        key = (csr_attr, int(i))
        hit = self._nbr_cache.get(key)
        if hit is None:
            row = self.value_indices[i:i + 1].astype(np.int64)
            cand, valid = candidates_fn(row, self._codes[i:i + 1])
            found, pos = self._resolve_candidates(cand, valid)
            hit = pos[found].astype(np.int32)
            if len(self._nbr_cache) >= self._nbr_cache_max:
                self._nbr_cache.pop(next(iter(self._nbr_cache)))
            self._nbr_cache[key] = hit
        return hit.tolist()

    def hamming_neighbors(self, i: int) -> List[int]:
        return self._neighbors(i, self._hamming_candidates, "_h_csr")

    def axis_exchange(self, i: int, j: int) -> List[int]:
        """Config indices reachable from ``i`` by changing ONLY parameter
        ``j`` — the coordinate-exchange move set (pool-mode BO refinement).
        Ascending value-ordinal order, current value excluded."""
        row = self.value_indices[i]
        code = int(self._codes[i])
        out: List[int] = []
        for v in range(int(self._nvals[j])):
            if v == int(row[j]):
                continue
            pos = self._find_code(code + (v - int(row[j]))
                                  * int(self._strides[j]))
            if pos is not None:
                out.append(pos)
        return out

    def adjacent_neighbors(self, i: int) -> List[int]:
        """Differ in one parameter by one ordinal step (for local search)."""
        return self._neighbors(i, self._adjacent_candidates, "_a_csr")

    def random_index(self, rng: np.random.Generator) -> int:
        return int(rng.integers(0, self.size))

    def nearest_index(self, x_norm: np.ndarray,
                      exclude: Optional[set] = None,
                      chunk: int = 1 << 16) -> int:
        """Snap a [0,1]^d point to the nearest enumerated config (L2)."""
        x = np.asarray(x_norm)
        if x.dtype != self.X_norm.dtype:
            # don't let a float64 query upcast the whole (N, d) matrix
            x = x.astype(self.X_norm.dtype)
        if not self.x_norm_lazy:
            d2 = np.sum((self.X_norm - x[None, :]) ** 2, axis=1)
            if exclude:
                d2[list(exclude)] = np.inf   # fresh buffer: no copy needed
            return int(np.argmin(d2))
        # lazy X_norm: chunk the scan so no (N, d) buffer materializes
        best_d, best_i = np.inf, 0
        for lo in range(0, self.size, chunk):
            d2 = np.sum((self.X_norm[lo:lo + chunk] - x[None, :]) ** 2, axis=1)
            if exclude:
                local = [e - lo for e in exclude if lo <= e < lo + len(d2)]
                if local:
                    d2[local] = np.inf
            k = int(np.argmin(d2))
            if d2[k] < best_d:
                best_d, best_i = float(d2[k]), lo + k
        return best_i

    def nearest_indices(self, X: np.ndarray, chunk: int = 1 << 16) -> np.ndarray:
        """Batch nearest_index (no exclusion), chunked over the space so the
        (q, N) distance matrix never materializes. Used by candidate-pool BO's
        LHS refresh and by cross-size warm-start record mapping."""
        X = np.asarray(X, self.X_norm.dtype)
        if X.ndim == 1:
            X = X[None, :]
        q_sq = np.sum(X * X, axis=1)
        if self._row_sq is None and not self.x_norm_lazy:
            self._row_sq = np.sum(self.X_norm * self.X_norm, axis=1)
        best_d = np.full(len(X), np.inf, np.float32)
        best_i = np.zeros(len(X), np.int64)
        for lo in range(0, self.size, chunk):
            B = self.X_norm[lo:lo + chunk]
            b_sq = (np.sum(B * B, axis=1) if self._row_sq is None
                    else self._row_sq[lo:lo + chunk])
            d2 = (q_sq[:, None] + b_sq[None, :]
                  - 2.0 * (X @ B.T))                       # (q, m)
            k = np.argmin(d2, axis=1)                      # row-contiguous
            d = d2[np.arange(len(X)), k]
            better = d < best_d
            best_d[better] = d[better]
            best_i[better] = lo + k[better]
        return best_i

    @property
    def resident_bytes(self) -> int:
        """Bytes held by materialized per-config arrays (benchmark metric)."""
        total = self.value_indices.nbytes + self._codes.nbytes
        if isinstance(self.X_norm, np.ndarray):
            total += self.X_norm.nbytes
        if self._row_sq is not None:
            total += self._row_sq.nbytes
        for csr in (self._h_csr, self._a_csr):
            if csr is not None:
                total += csr[0].nbytes + csr[1].nbytes
        return total

    def describe(self) -> str:
        lines = [f"SearchSpace {self.name}: {self.size} configs "
                 f"(cartesian {self.cartesian_size}, {self.dim} params)"]
        for p in self.params:
            vals = ", ".join(str(v) for v in p.values[:8])
            more = "..." if len(p.values) > 8 else ""
            lines.append(f"  {p.name}: [{vals}{more}] ({len(p.values)})")
        return "\n".join(lines)


class CodeNorm:
    """Normalized-coordinate facade for the generative backend.

    There is no (N, d) matrix to index: configs are identified by their
    mixed-radix code, so ``X_norm[codes]`` decodes the requested rows on
    demand. Only the access patterns the tuning stack uses are supported —
    an integer code or an array of codes; dense slices would require
    enumeration and raise.
    """

    __slots__ = ("_space", "shape")
    dtype = np.dtype(np.float32)

    def __init__(self, space: "GenerativeSpace"):
        self._space = space
        self.shape = (space.size, space.dim)

    def __len__(self) -> int:
        return self.shape[0]

    def __getitem__(self, key) -> np.ndarray:
        if isinstance(key, slice):
            raise TypeError(
                "CodeNorm has no dense rows to slice — index by config code "
                "(the generative backend never enumerates)")
        scalar = isinstance(key, (int, np.integer))
        codes = np.atleast_1d(np.asarray(key, np.int64))
        X = self._space._norm_rows(self._space.decode(codes))
        return X[0] if scalar else X


class GenerativeSpace(SearchSpace):
    """Constraint-native backend for spaces too large to enumerate.

    Nothing per-config is materialized — no code table, no value-index
    matrix, no X_norm (DESIGN.md §15). A config's *index* is its mixed-radix
    code in the full Cartesian grid, so ``config``/``index_of`` are O(d)
    arithmetic plus a constraint check, and ``SpaceFingerprint`` identity is
    as stable as the enumerated backend's (the digest depends only on
    params/constraints/size, all deterministic at construction).

      * feasible sampling: batched uniform code draws filtered through the
        constraints in declaration order (``_constrain`` — same short-circuit
        the enumerator uses), with the batch size adapted by an acceptance-
        rate EWMA; when the EWMA sinks below ``PROPAGATE_BELOW`` (or a
        rejection budget exhausts with zero hits) draws switch to the
        constraint-propagating backtracking sampler — dimension-by-dimension
        with per-step grid pruning and dead-prefix memoization — so tight
        constraint sets stay fast instead of stalling;
      * neighborhoods: the enumerated backend's candidate generators produce
        the neighbor *codes* directly; each candidate is validity-checked
        against the constraints on the fly and the resulting rows are
        memoized FIFO like the partial-CSR frontier;
      * nearest-point queries: per-dimension ordinal rounding (exact whenever
        the rounded config is feasible) with a deterministic feasible anchor
        set — seeded independently of caller RNG — as the fallback metric.

    ``size`` equals ``cartesian_size``: the feasible count is unknown without
    enumeration, and every consumer treats indices as opaque keys.
    """

    generative = True

    #: Deterministic seed for the anchor sample backing nearest-point
    #: fallback queries — independent of caller RNGs so repeated
    #: constructions agree.
    ANCHOR_SEED = 0xA17C4
    ANCHOR_COUNT = 4096

    #: Acceptance-EWMA routing threshold (module default; per-instance
    #: override is allowed in tests/benchmarks).
    PROPAGATE_BELOW = PROPAGATE_BELOW

    def __init__(self, params: Sequence[Param],
                 constraints: Sequence[Constraint] = (),
                 name: str = "space",
                 max_enumeration: int = DEFAULT_MAX_ENUMERATION,
                 chunk_size: int = ENUM_CHUNK,
                 csr_build_max: int = CSR_BUILD_MAX,
                 x_norm_lazy_min: int = X_NORM_LAZY_MIN,
                 neighbor_cache_max: int = NEIGHBOR_CACHE_MAX):
        cart = self._init_radix(params, constraints, name,
                                csr_build_max=csr_build_max,
                                x_norm_lazy_min=x_norm_lazy_min,
                                neighbor_cache_max=neighbor_cache_max)
        if cart >= 2 ** 62:
            raise ValueError(
                f"{name}: cartesian product {cart} overflows int64 "
                f"mixed-radix code arithmetic")
        self.cartesian_size = cart
        self.size = cart
        self.X_norm = CodeNorm(self)
        self._accept_ewma = 1.0     # rejection-sampling acceptance estimate
        self._accept_draws = 0      # uniform draws the EWMA has folded
        self._accept_hits = 0       # feasible hits among those draws
        self._anchor_codes: Optional[np.ndarray] = None
        self._anchor_norm: Optional[np.ndarray] = None
        self._nbr_cache: Dict[Tuple[str, int], np.ndarray] = {}
        # constraint-propagation state (lazy — _prop_init)
        self._prop_deps: Optional[List[Tuple[int, ...]]] = None
        self._prop_by_step: Optional[List[List[int]]] = None
        self._dead_prefixes: Dict[Tuple[int, ...], None] = {}
        self._prop_draws = 0        # completed propagating draws

    # -- code arithmetic -----------------------------------------------------
    def decode(self, codes: np.ndarray) -> np.ndarray:
        """Mixed-radix codes -> (m, d) per-param ordinal rows."""
        codes = np.asarray(codes, np.int64)
        return (codes[:, None] // self._strides[None, :]) % self._nvals[None, :]

    def _norm_rows(self, idx: np.ndarray) -> np.ndarray:
        X = idx.astype(np.float32) / self._norm_denom
        if self._norm_single.any():
            X[..., self._norm_single] = 0.5
        return X

    def _feasible_mask(self, codes: np.ndarray) -> np.ndarray:
        codes = np.asarray(codes, np.int64)
        idx = self.decode(codes)
        alive = self._constrain(idx, np.arange(len(codes)))
        mask = np.zeros(len(codes), bool)
        mask[alive] = True
        return mask

    @property
    def value_indices(self):
        raise AttributeError(
            f"{self.name}: GenerativeSpace materializes no value-index table "
            f"— decode config codes on demand via decode()")

    @property
    def x_norm_lazy(self) -> bool:
        return True

    def take(self, keep: np.ndarray) -> "SearchSpace":
        raise NotImplementedError(
            "GenerativeSpace has no dense index table to subset; trim the "
            "parameter grids instead")

    # -- config access -------------------------------------------------------
    def config(self, i: int) -> Dict[str, Any]:
        row = self.decode(np.asarray([int(i)], np.int64))[0]
        return {p.name: p.values[int(row[j])]
                for j, p in enumerate(self.params)}

    def _find_code(self, code: int) -> Optional[int]:
        """A code IS the index — existence just means in-grid + feasible."""
        if code < 0 or code >= self.cartesian_size:
            return None
        if bool(self._feasible_mask(np.asarray([code], np.int64))[0]):
            return int(code)
        return None

    # -- feasible sampling ---------------------------------------------------
    def sample_feasible(self, rng: np.random.Generator, m: int) -> np.ndarray:
        """m feasible codes, routed between two samplers (DESIGN.md §15).

        Rejection — constraint-filtered uniform draws with EWMA-adaptive
        batch sizing — is the fast path while the acceptance estimate stays
        above ``PROPAGATE_BELOW``; loosely-constrained spaces never cross
        the threshold (the EWMA initializes at 1.0) and keep byte-identical
        draw streams. Below it, or when a rejection budget exhausts with
        zero hits, draws come from the constraint-propagating backtracking
        sampler instead of raising: per-candidate cost then depends on the
        number of parameters, not on 1/feasible-fraction. A raising call
        (truly infeasible space) restores the entry EWMA so it cannot
        poison the next call's adaptive batch size.
        """
        m = int(m)
        if m <= 0:
            return np.zeros(0, np.int64)
        if self.constraints and self._accept_ewma < self.PROPAGATE_BELOW:
            return self._sample_propagate(rng, m)
        ewma_entry = self._accept_ewma
        out: List[np.ndarray] = []
        got, attempts = 0, 0
        budget = max(64 * m, 1 << 20)
        while got < m and attempts < budget:
            rate = max(self._accept_ewma, 1e-3)
            batch = int(min(max(int((m - got) / rate) + 16, 256), 1 << 17))
            codes = rng.integers(0, self.cartesian_size, size=batch,
                                 dtype=np.int64)
            kept = codes[self._feasible_mask(codes)]
            self._accept_ewma = (0.7 * self._accept_ewma
                                 + 0.3 * (len(kept) / batch))
            self._accept_draws += batch
            self._accept_hits += int(kept.size)
            attempts += batch
            if kept.size:
                out.append(kept)
                got += len(kept)
            elif attempts >= (1 << 17) and self.constraints \
                    and self.PROPAGATE_BELOW >= 0:
                # zero hits this deep means the density is propagation
                # territory — stop burning the uniform-draw budget
                # (PROPAGATE_BELOW < 0 pins pure rejection: benchmarks
                # and parity tests use it as the legacy baseline)
                break
            if got < m and self.constraints and self.PROPAGATE_BELOW >= 0 \
                    and self._accept_ewma < self.PROPAGATE_BELOW:
                # the EWMA sank below the threshold MID-call: a call that
                # entered on the rejection path (fresh space, EWMA still
                # converging) must not burn its whole draw budget there —
                # finish the remainder by propagation now. Loose spaces
                # never sink this low, so their streams stay byte-identical.
                try:
                    rest = self._sample_propagate(rng, m - got)
                except ValueError:
                    self._accept_ewma = ewma_entry
                    raise
                out.append(rest)
                got += len(rest)
                break
        if got == 0:
            if self.constraints and self.PROPAGATE_BELOW >= 0:
                try:
                    return self._sample_propagate(rng, m)
                except ValueError:
                    self._accept_ewma = ewma_entry
                    raise
            self._accept_ewma = ewma_entry
            raise ValueError(
                f"{self.name}: no feasible configuration in {attempts} "
                f"uniform draws — constraints too tight for rejection "
                f"sampling")
        codes = np.concatenate(out)[:m]
        if len(codes) < m:
            fill = codes[rng.integers(0, len(codes), size=m - len(codes))]
            codes = np.concatenate([codes, fill])
        return codes

    def stratified_feasible(self, rng: np.random.Generator, m: int,
                            rounds: int = 16) -> np.ndarray:
        """One feasible code per equal-width code stratum (coverage draws).

        Stratum edges use Python-int arithmetic — np.linspace would lose
        integer precision above 2**53. Strata that stay dry after ``rounds``
        rejection attempts fall back to global feasible draws. When the
        acceptance EWMA is below ``PROPAGATE_BELOW`` the rejection rounds
        are skipped entirely and each stratum is filled by an in-stratum
        propagating draw (digit-bounded backtracking), so coverage survives
        constraint densities where per-stratum rejection stays dry forever.
        """
        cart = self.cartesian_size
        m = int(min(m, cart))
        if m <= 0:
            return np.zeros(0, np.int64)
        out = np.full(m, -1, np.int64)
        unfilled = np.arange(m)
        propagate = bool(self.constraints) and \
            self._accept_ewma < self.PROPAGATE_BELOW
        if not propagate:
            seen_draws = seen_hits = 0
            for _ in range(rounds):
                if unfilled.size == 0:
                    break
                los = np.array([i * cart // m for i in unfilled], np.int64)
                his = np.array([(i + 1) * cart // m for i in unfilled],
                               np.int64)
                draws = rng.integers(los, his, dtype=np.int64)
                mask = self._feasible_mask(draws)
                out[unfilled[mask]] = draws[mask]
                unfilled = unfilled[~mask]
                seen_draws += int(draws.size)
                seen_hits += int(mask.sum())
                if self.constraints and self.PROPAGATE_BELOW >= 0 \
                        and seen_draws >= 4096 \
                        and seen_hits < self.PROPAGATE_BELOW * seen_draws:
                    # this call's own acceptance is propagation-tight:
                    # stop the per-stratum rejection rounds (they would
                    # stay dry and the global fill would pad duplicates)
                    # and fill the rest by in-stratum propagation. A local
                    # counter, not the EWMA — these draws must not perturb
                    # the adaptive batch state loose-space traces pin.
                    propagate = True
                    break
        if unfilled.size:
            if propagate:
                dry: List[int] = []
                for i in unfilled:
                    lo = int(i) * cart // m
                    hi = (int(i) + 1) * cart // m
                    code = (self._propagate_draw(rng, lo, hi)
                            if hi > lo else None)
                    if code is None:
                        dry.append(int(i))   # stratum truly infeasible
                    else:
                        out[int(i)] = code
                if dry:
                    out[np.asarray(dry, np.int64)] = \
                        self.sample_feasible(rng, len(dry))
            else:
                out[unfilled] = self.sample_feasible(rng, int(unfilled.size))
        return out

    def random_index(self, rng: np.random.Generator) -> int:
        return int(self.sample_feasible(rng, 1)[0])

    # -- constraint propagation (DESIGN.md §15) ------------------------------
    def _prop_init(self) -> None:
        """Discover each constraint's parameter dependencies by probing it
        with a key-recording config mapping (several value assignments, so
        value-conditional reads are likely caught), then bucket constraints
        by the declaration-order step at which their free variables become
        fully bound. A constraint whose reads the probe cannot see at all
        falls back to a full dependency set — it is then enforced by the
        leaf check instead of pruning."""
        if self._prop_by_step is not None:
            return
        name_to_j = {p.name: j for j, p in enumerate(self.params)}
        deps: List[Tuple[int, ...]] = []
        probes = ({p.name: p.values[0] for p in self.params},
                  {p.name: p.values[-1] for p in self.params},
                  {p.name: p.values[len(p.values) // 2] for p in self.params})
        for c in self.constraints:
            seen: set = set()
            for base in probes:
                try:
                    c(_DepProbe(base, seen))
                except Exception:
                    pass   # only the key reads matter, not the outcome
            dep = {name_to_j[n] for n in seen if n in name_to_j}
            deps.append(tuple(sorted(dep)) if dep
                        else tuple(range(self.dim)))
        self._prop_deps = deps
        self._prop_rebucket()

    def _prop_rebucket(self) -> None:
        by_step: List[List[int]] = [[] for _ in range(self.dim)]
        for ci, d in enumerate(self._prop_deps):
            by_step[max(d)].append(ci)
        self._prop_by_step = by_step

    def _register_dep(self, ci: int, name: str) -> None:
        """A constraint read a parameter the probe missed (conditional
        access surfacing at prune time as a KeyError): grow its dependency
        set and re-bucket. The in-flight pruning pass skips the constraint;
        the leaf check still enforces it."""
        j = next((k for k, p in enumerate(self.params)
                  if p.name == name), None)
        if j is None:
            return
        self._prop_deps[ci] = tuple(sorted(set(self._prop_deps[ci]) | {j}))
        self._prop_rebucket()

    def _prune_axis(self, bound: Sequence[int], j: int, cand: np.ndarray,
                    cons: Sequence[int]) -> np.ndarray:
        """Prune candidate ordinals for parameter ``j`` against the
        constraints in ``cons`` (each fully bound once ``j`` is chosen),
        given ``bound`` ordinals for every other dependency. Mirrors
        ``_constrain``'s declaration-order short-circuit, evaluated on the
        one free value column at a time."""
        for ci in cons:
            if cand.size == 0:
                break
            c = self.constraints[ci]
            n = len(cand)
            try:
                if isinstance(c, VectorConstraint):
                    cols: Dict[str, np.ndarray] = {}
                    for p_idx in self._prop_deps[ci]:
                        arr = self._value_arrays[p_idx]
                        if p_idx == j:
                            cols[self.params[p_idx].name] = arr[cand]
                        else:
                            cols[self.params[p_idx].name] = arr[
                                np.full(n, int(bound[p_idx]))]
                    cand = cand[c.mask(cols, n)]
                else:    # plain callable: per-candidate fallback
                    base = {self.params[p].name:
                            self.params[p].values[int(bound[p])]
                            for p in self._prop_deps[ci] if p != j}
                    keep = [int(v) for v in cand
                            if c({**base, self.params[j].name:
                                  self.params[j].values[int(v)]})]
                    cand = np.asarray(keep, np.int64)
            except KeyError as e:    # dependency probe missed a read
                self._register_dep(ci, str(e.args[0]) if e.args else "")
        return cand

    def _dead_add(self, prefix: Tuple[int, ...]) -> None:
        if len(self._dead_prefixes) >= DEAD_PREFIX_CACHE_MAX:
            self._dead_prefixes.pop(next(iter(self._dead_prefixes)))
        self._dead_prefixes[prefix] = None

    def _propagate_draw(self, rng: np.random.Generator,
                        lo: Optional[int] = None,
                        hi: Optional[int] = None) -> Optional[int]:
        """One feasible code by dimension-by-dimension constraint
        propagation with backtracking.

        Parameters are bound in declaration order; at step ``j`` the
        candidate grid is pruned by every constraint whose free variables
        are fully bound once ``j`` is chosen (``_prop_by_step``), then
        walked in rng-shuffled order. Dead prefixes are memoized FIFO so
        repeated draws amortize to near-O(params). A completed assignment
        is re-checked through ``_feasible_mask`` (the rejection sampler's
        exact verdict) — pruning is an accelerator, never the authority.
        With ``lo``/``hi`` the draw is confined to the code stratum
        ``[lo, hi)`` via mixed-radix digit bounds; range-truncated
        subtrees are never recorded as dead (a stratum dead-end is not a
        global one). Returns None when the (sub)tree has no feasible
        completion."""
        self._prop_init()
        bounded = lo is not None
        lo_d = (self.decode(np.asarray([lo], np.int64))[0]
                if bounded else None)
        hi_d = (self.decode(np.asarray([hi - 1], np.int64))[0]
                if bounded else None)
        last = self.dim - 1
        prefix: List[int] = []

        def rec(j: int, tlo: bool, thi: bool) -> bool:
            vmin = int(lo_d[j]) if tlo else 0
            vmax = int(hi_d[j]) if thi else int(self._nvals[j]) - 1
            cand = np.arange(vmin, vmax + 1, dtype=np.int64)
            cand = self._prune_axis(prefix, j, cand, self._prop_by_step[j])
            # permutation length depends only on the pruned grid, never on
            # the memo, so rng consumption is memo-state independent
            for t in rng.permutation(len(cand)):
                v = int(cand[int(t)])
                prefix.append(v)
                if tuple(prefix) in self._dead_prefixes:
                    prefix.pop()
                    continue
                if j == last:
                    code = int(np.asarray(prefix, np.int64)
                               @ self._strides)
                    if bool(self._feasible_mask(
                            np.asarray([code], np.int64))[0]):
                        return True
                elif rec(j + 1, tlo and v == int(lo_d[j]),
                         thi and v == int(hi_d[j])):
                    return True
                prefix.pop()
            if not (tlo or thi):
                self._dead_add(tuple(prefix))
            return False

        if not rec(0, bounded, bounded):
            return None
        self._prop_draws += 1
        return int(np.asarray(prefix, np.int64) @ self._strides)

    def _sample_propagate(self, rng: np.random.Generator,
                          m: int) -> np.ndarray:
        out = np.empty(m, np.int64)
        for i in range(m):
            code = self._propagate_draw(rng)
            if code is None:
                raise ValueError(
                    f"{self.name}: no feasible configuration — constraint "
                    f"propagation exhausted the grid")
            out[i] = code
        return out

    def axis_exchange(self, i: int, j: int) -> List[int]:
        """Coordinate-exchange move set along parameter ``j`` from feasible
        config ``i``, validated by the propagating per-dimension pruner:
        only the constraints that mention ``j`` are evaluated (the
        incumbent already satisfies the rest), on the whole candidate
        column at once — never by rejection draws."""
        self._prop_init()
        row = self.decode(np.asarray([int(i)], np.int64))[0]
        cand = np.arange(int(self._nvals[j]), dtype=np.int64)
        cand = cand[cand != int(row[j])]
        cons = [ci for ci, d in enumerate(self._prop_deps) if j in d]
        cand = self._prune_axis(row, j, cand, cons)
        codes = int(i) + (cand - int(row[j])) * int(self._strides[j])
        if codes.size:   # belt and braces against under-probed dependencies
            codes = codes[self._feasible_mask(codes)]
        return [int(c) for c in codes]

    # -- feasible-fraction estimation ----------------------------------------
    def _propagation_fraction_probes(self, n: int = 12) -> List[float]:
        """Knuth tree-size probes: each descent walks root->leaf WITHOUT
        backtracking, choosing uniformly among the pruned candidates at
        every level, and returns the product of per-dimension pruned-grid
        fractions (0.0 on a dead end). Each product is an unbiased
        estimator of the feasible fraction; min/max over probes bracket
        the sampled prefixes' evidence. Deterministically seeded so
        repeated calls (and repeated constructions) agree."""
        self._prop_init()
        rng = np.random.default_rng(self.ANCHOR_SEED ^ 0x9E3779B9)
        out: List[float] = []
        for _ in range(n):
            prefix: List[int] = []
            frac = 1.0
            for j in range(self.dim):
                cand = np.arange(int(self._nvals[j]), dtype=np.int64)
                cand = self._prune_axis(prefix, j, cand,
                                        self._prop_by_step[j])
                if cand.size == 0:
                    frac = 0.0
                    break
                frac *= len(cand) / int(self._nvals[j])
                prefix.append(int(cand[int(rng.integers(0, len(cand)))]))
            out.append(frac)
        return out

    def feasible_fraction_interval(self) -> Dict[str, float]:
        """Principled feasible-fraction estimate (DESIGN.md §15).

        With sampling stats: Jeffreys 95% interval over accepted/attempted
        uniform-draw counts. Before any sampling: propagation-derived
        bracket — min/mean/max of per-dimension pruned-grid fraction
        products along probe descents. Returns ``{method, point, lo, hi}``.
        """
        if not self.constraints:
            return {"method": "exact", "point": 1.0, "lo": 1.0, "hi": 1.0}
        if self._accept_draws:
            lo, hi = _jeffreys_interval(self._accept_hits,
                                        self._accept_draws)
            return {"method": "jeffreys",
                    "point": self._accept_hits / self._accept_draws,
                    "lo": lo, "hi": hi}
        probes = self._propagation_fraction_probes()
        return {"method": "propagation", "point": float(np.mean(probes)),
                "lo": float(min(probes)), "hi": float(max(probes))}

    # -- neighborhoods: feasible walks --------------------------------------
    def _neighbors(self, i: int, candidates_fn, csr_attr: str) -> List[int]:
        """Neighbor codes generated on the fly, validity-checked against the
        constraints, memoized FIFO exactly like the partial-CSR frontier.
        Candidate column order is inherited from the enumerated backend's
        generators, so parity tests can compare neighbor *sets* directly."""
        key = (csr_attr, int(i))
        hit = self._nbr_cache.get(key)
        if hit is None:
            code = np.asarray([int(i)], np.int64)
            cand, valid = candidates_fn(self.decode(code), code)
            cand = cand[0][valid[0]]
            hit = cand[self._feasible_mask(cand)]
            if len(self._nbr_cache) >= self._nbr_cache_max:
                self._nbr_cache.pop(next(iter(self._nbr_cache)))
            self._nbr_cache[key] = hit
        return hit.tolist()

    # -- nearest-point queries -----------------------------------------------
    def _round_codes(self, X: np.ndarray) -> np.ndarray:
        """[0,1]^d points -> codes of the per-dimension nearest grid rows."""
        ords = np.rint(np.asarray(X, np.float64) * self._norm_denom)
        ords = np.clip(ords, 0, self._nvals - 1).astype(np.int64)
        return ords @ self._strides

    def _anchors(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._anchor_codes is None:
            rng = np.random.default_rng(self.ANCHOR_SEED)
            n = int(min(self.ANCHOR_COUNT, self.cartesian_size))
            self._anchor_codes = np.unique(self.sample_feasible(rng, n))
            self._anchor_norm = self._norm_rows(
                self.decode(self._anchor_codes))
        return self._anchor_codes, self._anchor_norm

    def nearest_index(self, x_norm: np.ndarray,
                      exclude: Optional[set] = None,
                      chunk: int = 1 << 16) -> int:
        x = np.asarray(x_norm, np.float32)
        code = int(self._round_codes(x[None, :])[0])
        if (exclude is None or code not in exclude) and \
                self._find_code(code) is not None:
            return code
        anchors, anchor_norm = self._anchors()
        d2 = np.sum((anchor_norm - x[None, :]) ** 2, axis=1)
        if exclude:
            hit = np.isin(anchors, np.fromiter(exclude, np.int64,
                                               count=len(exclude)))
            d2[hit] = np.inf
        return int(anchors[int(np.argmin(d2))])

    def nearest_indices(self, X: np.ndarray, chunk: int = 1 << 16
                        ) -> np.ndarray:
        X = np.asarray(X, np.float32)
        if X.ndim == 1:
            X = X[None, :]
        codes = self._round_codes(X)
        ok = self._feasible_mask(codes)
        if not ok.all():
            anchors, anchor_norm = self._anchors()
            bad = np.flatnonzero(~ok)
            d2 = (np.sum(X[bad] ** 2, axis=1)[:, None]
                  + np.sum(anchor_norm ** 2, axis=1)[None, :]
                  - 2.0 * (X[bad] @ anchor_norm.T))
            codes[bad] = anchors[np.argmin(d2, axis=1)]
        return codes

    @property
    def resident_bytes(self) -> int:
        total = (self._nvals.nbytes + self._strides.nbytes
                 + self._norm_denom.nbytes + self._norm_single.nbytes)
        if self._anchor_codes is not None:
            total += self._anchor_codes.nbytes + self._anchor_norm.nbytes
        for arr in self._nbr_cache.values():
            total += arr.nbytes
        return total

    def describe(self) -> str:
        # the feasible count is never enumerated here, so the fraction is a
        # loudly-labeled estimate: a Jeffreys interval over the rejection
        # sampler's accepted/attempted counts once draws exist, and before
        # any sampling a propagation-derived bracket (pruned-grid fraction
        # products along probe descents)
        est = self.feasible_fraction_interval()
        if est["method"] == "exact":
            frac = "feasible fraction 1 (unconstrained grid)"
        elif est["method"] == "jeffreys":
            frac = (f"feasible fraction ~{est['point']:.3g} "
                    f"(Jeffreys 95% [{est['lo']:.2g}, {est['hi']:.2g}] "
                    f"over {self._accept_hits}/{self._accept_draws} "
                    f"accepted/attempted uniform draws)")
        else:
            frac = (f"feasible fraction ~{est['point']:.3g} "
                    f"(PROPAGATION bound [{est['lo']:.2g}, {est['hi']:.2g}]"
                    f": pruned-grid fraction products along probe "
                    f"descents; no sampling stats yet)")
        lines = [f"GenerativeSpace {self.name}: cartesian "
                 f"{self.cartesian_size} ({self.dim} params, not enumerated; "
                 f"{frac})"]
        for p in self.params:
            vals = ", ".join(str(v) for v in p.values[:8])
            more = "..." if len(p.values) > 8 else ""
            lines.append(f"  {p.name}: [{vals}{more}] ({len(p.values)})")
        return "\n".join(lines)
