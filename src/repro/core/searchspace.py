"""Discrete, constrained, normalized search spaces (paper §III-D).

The paper's representation decisions, reproduced exactly:
  * mixed-type parameters (ints, floats, strings, bools) — each parameter is
    an *ordered* list of values (the user is responsible for the ordering);
  * every numerical input is normalized "in a linear fashion" onto [0, 1] by
    ordinal position, which removes the distance distortion of non-linear
    value sets (powers of two etc.) and gives categorical values an integer
    encoding (§III-D1);
  * constraints ("restrictions") filter the Cartesian product up front;
  * runtime-invalid configurations are a property of the *objective*, not the
    space — the tuner discovers them (§III-D2).

Scale (DESIGN.md §9): enumeration is chunked + vectorized — each chunk of the
Cartesian product is decoded arithmetically from its mixed-radix index (the
same lexicographic order ``itertools.product`` produced, so config indices
are stable across the refactor) and constraints declared as
``VectorConstraint`` are evaluated on whole value columns at once. Plain
``Constraint`` callables still work through a chunked per-row fallback.
Config lookup runs on the sorted mixed-radix code array (binary search, no
per-row tuple dict), and Hamming/adjacent neighborhoods are served from a
lazily built CSR index (or computed per row, vectorized, above
``csr_build_max`` configs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Cartesian-product ceiling. Vectorized enumeration makes 10^7+ practical
#: (benchmarks/space_bench.py); the cap only guards against runaway memory.
DEFAULT_MAX_ENUMERATION = 20_000_000

#: Rows decoded/filtered per enumeration chunk.
ENUM_CHUNK = 1 << 17

#: Spaces at most this large get a precomputed CSR neighbor index on first
#: neighbor query; larger spaces answer each query vectorized on demand.
CSR_BUILD_MAX = 1 << 18


@dataclass(frozen=True)
class Param:
    name: str
    values: Tuple[Any, ...]

    def __post_init__(self):
        assert len(self.values) >= 1


Constraint = Callable[[Dict[str, Any]], bool]


class VectorConstraint:
    """A restriction evaluated on whole value columns at once.

    ``fn`` receives a dict mapping parameter name -> value array (one entry
    per candidate row of the current enumeration chunk) and returns a boolean
    array. NumPy's elementwise semantics mean most scalar restrictions — e.g.
    ``lambda c: c["MWG"] % (c["MDIMC"] * c["VWM"]) == 0`` — are already valid
    column predicates; wrapping marks them safe to broadcast. The same ``fn``
    serves scalar config dicts, so a VectorConstraint is a drop-in
    ``Constraint`` everywhere one is accepted.
    """

    __slots__ = ("fn", "name")

    def __init__(self, fn: Callable, name: Optional[str] = None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "<lambda>")

    def mask(self, cols: Dict[str, np.ndarray], n_rows: int) -> np.ndarray:
        out = np.asarray(self.fn(cols))
        if out.shape != (n_rows,):
            raise ValueError(
                f"VectorConstraint {self.name!r} returned shape {out.shape}, "
                f"expected ({n_rows},) — not a column predicate")
        return out.astype(bool, copy=False)

    def __call__(self, cfg: Dict[str, Any]) -> bool:
        return bool(self.fn(cfg))


class SearchSpace:
    """Enumerated constrained space with ordinal-normalized coordinates."""

    def __init__(self, params: Sequence[Param],
                 constraints: Sequence[Constraint] = (),
                 name: str = "space",
                 max_enumeration: int = DEFAULT_MAX_ENUMERATION,
                 chunk_size: int = ENUM_CHUNK,
                 csr_build_max: int = CSR_BUILD_MAX):
        self.name = name
        self.params: Tuple[Param, ...] = tuple(params)
        self.constraints = tuple(constraints)
        self.dim = len(self.params)
        self._csr_build_max = csr_build_max

        nvals = np.array([len(p.values) for p in self.params], np.int64)
        cart = math.prod(int(n) for n in nvals)
        if cart > max_enumeration:
            raise ValueError(f"{name}: cartesian product {cart} too large to enumerate")
        self.cartesian_size = cart

        # mixed-radix strides: the LAST parameter varies fastest, which is
        # exactly itertools.product's lexicographic order — decoding ascending
        # global indices g via (g // stride_j) % n_j reproduces the historical
        # enumeration (and therefore every pinned config index) bit-for-bit.
        strides = np.ones(self.dim, np.int64)
        for j in range(self.dim - 2, -1, -1):
            strides[j] = strides[j + 1] * nvals[j + 1]
        self._nvals = nvals
        self._strides = strides
        self._value_arrays = [np.asarray(p.values) for p in self.params]

        idx, codes = self._enumerate(chunk_size)
        self.value_indices = idx                     # (N, d) int32
        self._codes = codes                          # (N,) int64, ascending
        self.size = len(idx)
        if self.size == 0:
            raise ValueError(f"{name}: all configurations violate constraints")

        self.X_norm = self._normalize(idx)
        self._h_csr: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._a_csr: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._row_sq: Optional[np.ndarray] = None   # lazy ||X_norm||² cache

    # -- enumeration ---------------------------------------------------------
    def _enumerate(self, chunk_size: int) -> Tuple[np.ndarray, np.ndarray]:
        """Chunked vectorized Cartesian product + constraint filtering."""
        cart, d = self.cartesian_size, self.dim
        kept_idx: List[np.ndarray] = []
        kept_codes: List[np.ndarray] = []
        for lo in range(0, cart, chunk_size):
            g = np.arange(lo, min(lo + chunk_size, cart), dtype=np.int64)
            idx = (g[:, None] // self._strides[None, :]) % self._nvals[None, :]
            alive = np.arange(len(g))
            # constraints run in declaration order on the surviving rows only,
            # preserving the old per-row short-circuit semantics
            for c in self.constraints:
                if alive.size == 0:
                    break
                sub = idx[alive]
                if isinstance(c, VectorConstraint):
                    cols = {p.name: arr[sub[:, j]] for j, (p, arr) in
                            enumerate(zip(self.params, self._value_arrays))}
                    alive = alive[c.mask(cols, len(alive))]
                else:  # plain callable: chunked per-row fallback
                    ok = np.fromiter(
                        (c({p.name: p.values[int(sub[i, j])]
                            for j, p in enumerate(self.params)})
                         for i in range(len(alive))),
                        dtype=bool, count=len(alive))
                    alive = alive[ok]
            if alive.size:
                kept_idx.append(idx[alive].astype(np.int32))
                kept_codes.append(g[alive])
        if not kept_idx:
            return (np.zeros((0, d), np.int32), np.zeros(0, np.int64))
        return np.vstack(kept_idx), np.concatenate(kept_codes)

    def _normalize(self, idx: np.ndarray) -> np.ndarray:
        """Ordinal normalization: value j of n -> j/(n-1)  (n==1 -> 0.5)."""
        denom = np.array([max(len(p.values) - 1, 1) for p in self.params],
                         dtype=np.float32)
        X = idx.astype(np.float32) / denom
        for j, p in enumerate(self.params):
            if len(p.values) == 1:
                X[:, j] = 0.5
        return X

    def take(self, keep: np.ndarray) -> "SearchSpace":
        """Restrict the space to a sorted subset of its config indices
        (deterministic trimming, repro.core.spaces._trim). In place."""
        keep = np.asarray(keep)
        if np.any(np.diff(self._codes[keep]) <= 0):
            # checked before any mutation so a rejected call leaves the
            # space untouched
            raise ValueError("take() needs a sorted, duplicate-free subset: "
                             "code lookups binary-search an ascending array")
        self.value_indices = self.value_indices[keep]
        self.X_norm = self.X_norm[keep]
        self._codes = self._codes[keep]
        self.size = len(self.value_indices)
        self._h_csr = self._a_csr = self._row_sq = None
        return self

    # -- config access ------------------------------------------------------
    def config(self, i: int) -> Dict[str, Any]:
        row = self.value_indices[i]
        return {p.name: p.values[row[j]] for j, p in enumerate(self.params)}

    def configs(self, ids: Sequence[int]) -> List[Dict[str, Any]]:
        return [self.config(i) for i in ids]

    def _find_code(self, code: int) -> Optional[int]:
        pos = int(np.searchsorted(self._codes, code))
        if pos < self.size and self._codes[pos] == code:
            return pos
        return None

    def index_of(self, cfg: Dict[str, Any]) -> Optional[int]:
        try:
            key = tuple(p.values.index(cfg[p.name]) for p in self.params)
        except (ValueError, KeyError):
            return None
        return self._find_code(sum(k * int(s) for k, s in zip(key, self._strides)))

    def index_of_value_indices(self, row: Sequence[int]) -> Optional[int]:
        """Row of per-param value ordinals -> config index (or None if the
        combination was filtered out by the constraints)."""
        return self._find_code(
            sum(int(v) * int(s) for v, s in zip(row, self._strides)))

    # -- neighborhoods (Hamming: differ in exactly one parameter) -----------
    def _hamming_candidates(self, rows: np.ndarray, codes: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """(m,d) ordinal rows -> (m,K) candidate codes + validity, K = Σ n_j.
        Column order is (param j asc, value v asc, v != row_j) — the exact
        order the historical dict-probe loops produced."""
        cand, valid = [], []
        for j in range(self.dim):
            vs = np.arange(self._nvals[j], dtype=np.int64)
            cand.append(codes[:, None]
                        + (vs[None, :] - rows[:, j:j + 1]) * self._strides[j])
            valid.append(vs[None, :] != rows[:, j:j + 1])
        return np.concatenate(cand, axis=1), np.concatenate(valid, axis=1)

    def _adjacent_candidates(self, rows: np.ndarray, codes: np.ndarray
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """Column order (param j asc, dv in (-1, +1)), matching the old loop."""
        cand, valid = [], []
        for j in range(self.dim):
            for dv in (-1, 1):
                v = rows[:, j] + dv
                cand.append((codes + dv * self._strides[j])[:, None])
                valid.append(((v >= 0) & (v < self._nvals[j]))[:, None])
        return np.concatenate(cand, axis=1), np.concatenate(valid, axis=1)

    def _resolve_candidates(self, cand: np.ndarray, valid: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Candidate codes -> (found mask, positions), constraint-aware."""
        pos = np.searchsorted(self._codes, cand)
        pos_c = np.minimum(pos, self.size - 1)
        found = valid & (self._codes[pos_c] == cand)
        return found, pos_c

    def _build_csr(self, candidates_fn, chunk: int = 1 << 14
                   ) -> Tuple[np.ndarray, np.ndarray]:
        counts = np.zeros(self.size, np.int64)
        blocks: List[np.ndarray] = []
        rows_all = self.value_indices.astype(np.int64)
        for lo in range(0, self.size, chunk):
            hi = min(lo + chunk, self.size)
            cand, valid = candidates_fn(rows_all[lo:hi], self._codes[lo:hi])
            found, pos = self._resolve_candidates(cand, valid)
            counts[lo:hi] = found.sum(axis=1)
            blocks.append(pos[found].astype(np.int32))  # row-major: per-row
            #                                             column order kept
        indptr = np.zeros(self.size + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        indices = (np.concatenate(blocks) if blocks
                   else np.zeros(0, np.int32))
        return indptr, indices

    def _neighbors(self, i: int, candidates_fn, csr_attr: str) -> List[int]:
        csr = getattr(self, csr_attr)
        if csr is None and self.size <= self._csr_build_max:
            csr = self._build_csr(candidates_fn)
            setattr(self, csr_attr, csr)
        if csr is not None:
            indptr, indices = csr
            return indices[indptr[i]:indptr[i + 1]].tolist()
        # space too large for a precomputed index: one row, still vectorized
        row = self.value_indices[i:i + 1].astype(np.int64)
        cand, valid = candidates_fn(row, self._codes[i:i + 1])
        found, pos = self._resolve_candidates(cand, valid)
        return pos[found].tolist()

    def hamming_neighbors(self, i: int) -> List[int]:
        return self._neighbors(i, self._hamming_candidates, "_h_csr")

    def adjacent_neighbors(self, i: int) -> List[int]:
        """Differ in one parameter by one ordinal step (for local search)."""
        return self._neighbors(i, self._adjacent_candidates, "_a_csr")

    def random_index(self, rng: np.random.Generator) -> int:
        return int(rng.integers(0, self.size))

    def nearest_index(self, x_norm: np.ndarray,
                      exclude: Optional[set] = None) -> int:
        """Snap a [0,1]^d point to the nearest enumerated config (L2)."""
        x = np.asarray(x_norm)
        if x.dtype != self.X_norm.dtype:
            # don't let a float64 query upcast the whole (N, d) matrix
            x = x.astype(self.X_norm.dtype)
        d2 = np.sum((self.X_norm - x[None, :]) ** 2, axis=1)
        if exclude:
            d2[list(exclude)] = np.inf   # d2 is a fresh buffer: no copy needed
        return int(np.argmin(d2))

    def nearest_indices(self, X: np.ndarray, chunk: int = 1 << 16) -> np.ndarray:
        """Batch nearest_index (no exclusion), chunked over the space so the
        (q, N) distance matrix never materializes. Used by candidate-pool BO's
        LHS refresh."""
        X = np.asarray(X, self.X_norm.dtype)
        if X.ndim == 1:
            X = X[None, :]
        q_sq = np.sum(X * X, axis=1)
        if self._row_sq is None:
            self._row_sq = np.sum(self.X_norm * self.X_norm, axis=1)
        best_d = np.full(len(X), np.inf, np.float32)
        best_i = np.zeros(len(X), np.int64)
        for lo in range(0, self.size, chunk):
            B = self.X_norm[lo:lo + chunk]
            d2 = (q_sq[:, None] + self._row_sq[None, lo:lo + chunk]
                  - 2.0 * (X @ B.T))                       # (q, m)
            k = np.argmin(d2, axis=1)                      # row-contiguous
            d = d2[np.arange(len(X)), k]
            better = d < best_d
            best_d[better] = d[better]
            best_i[better] = lo + k[better]
        return best_i

    def describe(self) -> str:
        lines = [f"SearchSpace {self.name}: {self.size} configs "
                 f"(cartesian {self.cartesian_size}, {self.dim} params)"]
        for p in self.params:
            vals = ", ".join(str(v) for v in p.values[:8])
            more = "..." if len(p.values) > 8 else ""
            lines.append(f"  {p.name}: [{vals}{more}] ({len(p.values)})")
        return "\n".join(lines)
