"""Discrete, constrained, normalized search spaces (paper §III-D).

The paper's representation decisions, reproduced exactly:
  * mixed-type parameters (ints, floats, strings, bools) — each parameter is
    an *ordered* list of values (the user is responsible for the ordering);
  * every numerical input is normalized "in a linear fashion" onto [0, 1] by
    ordinal position, which removes the distance distortion of non-linear
    value sets (powers of two etc.) and gives categorical values an integer
    encoding (§III-D1);
  * constraints ("restrictions") filter the Cartesian product up front;
  * runtime-invalid configurations are a property of the *objective*, not the
    space — the tuner discovers them (§III-D2).

Scale (DESIGN.md §9): enumeration is chunked + vectorized — each chunk of the
Cartesian product is decoded arithmetically from its mixed-radix index (the
same lexicographic order ``itertools.product`` produced, so config indices
are stable across the refactor) and constraints declared as
``VectorConstraint`` are evaluated on whole value columns at once. Plain
``Constraint`` callables still work through a chunked per-row fallback.
Config lookup runs on the sorted mixed-radix code array (binary search, no
per-row tuple dict), and Hamming/adjacent neighborhoods are served from a
lazily built CSR index (or computed per row, vectorized, above
``csr_build_max`` configs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Cartesian-product ceiling. Vectorized enumeration makes 10^7+ practical
#: (benchmarks/space_bench.py); the cap only guards against runaway memory.
DEFAULT_MAX_ENUMERATION = 20_000_000

#: Rows decoded/filtered per enumeration chunk.
ENUM_CHUNK = 1 << 17

#: Spaces at most this large get a precomputed CSR neighbor index on first
#: neighbor query; larger spaces answer each query vectorized on demand.
CSR_BUILD_MAX = 1 << 18

#: Kept-config count at which X_norm switches from an eagerly materialized
#: float32 (N, d) matrix to a chunk-computed row provider (LazyNorm).
X_NORM_LAZY_MIN = 10_000_000

#: On-demand neighbor rows memoized over the visited region (partial CSR) on
#: spaces too large for the precomputed index. FIFO-evicted above this count.
NEIGHBOR_CACHE_MAX = 1 << 16


@dataclass(frozen=True)
class Param:
    name: str
    values: Tuple[Any, ...]

    def __post_init__(self):
        assert len(self.values) >= 1


Constraint = Callable[[Dict[str, Any]], bool]


class VectorConstraint:
    """A restriction evaluated on whole value columns at once.

    ``fn`` receives a dict mapping parameter name -> value array (one entry
    per candidate row of the current enumeration chunk) and returns a boolean
    array. NumPy's elementwise semantics mean most scalar restrictions — e.g.
    ``lambda c: c["MWG"] % (c["MDIMC"] * c["VWM"]) == 0`` — are already valid
    column predicates; wrapping marks them safe to broadcast. The same ``fn``
    serves scalar config dicts, so a VectorConstraint is a drop-in
    ``Constraint`` everywhere one is accepted.
    """

    __slots__ = ("fn", "name")

    def __init__(self, fn: Callable, name: Optional[str] = None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "<lambda>")

    def mask(self, cols: Dict[str, np.ndarray], n_rows: int) -> np.ndarray:
        out = np.asarray(self.fn(cols))
        if out.shape != (n_rows,):
            raise ValueError(
                f"VectorConstraint {self.name!r} returned shape {out.shape}, "
                f"expected ({n_rows},) — not a column predicate")
        return out.astype(bool, copy=False)

    def __call__(self, cfg: Dict[str, Any]) -> bool:
        return bool(self.fn(cfg))


class LazyNorm:
    """Chunk-computed view of the normalized coordinate matrix.

    Above ``x_norm_lazy_min`` kept configs the full float32 (N, d) matrix is
    never materialized; rows are decoded from ``value_indices`` on demand.
    Supports exactly the access patterns the tuning stack uses — integer,
    slice and fancy indexing — each returning a fresh dense array for the
    requested rows only.
    """

    __slots__ = ("_vi", "_denom", "_single", "shape")
    dtype = np.dtype(np.float32)

    def __init__(self, value_indices: np.ndarray, denom: np.ndarray,
                 single: np.ndarray):
        self._vi = value_indices
        self._denom = denom          # (d,) float32: max(n_j - 1, 1)
        self._single = single        # (d,) bool: single-valued params -> 0.5
        self.shape = value_indices.shape

    def __len__(self) -> int:
        return self.shape[0]

    def __getitem__(self, key) -> np.ndarray:
        X = self._vi[key].astype(np.float32) / self._denom
        if self._single.any():
            X[..., self._single] = 0.5
        return X


class SearchSpace:
    """Enumerated constrained space with ordinal-normalized coordinates."""

    def __init__(self, params: Sequence[Param],
                 constraints: Sequence[Constraint] = (),
                 name: str = "space",
                 max_enumeration: int = DEFAULT_MAX_ENUMERATION,
                 chunk_size: int = ENUM_CHUNK,
                 csr_build_max: int = CSR_BUILD_MAX,
                 x_norm_lazy_min: int = X_NORM_LAZY_MIN,
                 neighbor_cache_max: int = NEIGHBOR_CACHE_MAX):
        self.name = name
        self.params: Tuple[Param, ...] = tuple(params)
        self.constraints = tuple(constraints)
        self.dim = len(self.params)
        self._csr_build_max = csr_build_max
        self._x_norm_lazy_min = x_norm_lazy_min
        self._nbr_cache_max = neighbor_cache_max

        nvals = np.array([len(p.values) for p in self.params], np.int64)
        cart = math.prod(int(n) for n in nvals)
        if cart > max_enumeration:
            raise ValueError(f"{name}: cartesian product {cart} too large to enumerate")
        self.cartesian_size = cart

        # mixed-radix strides: the LAST parameter varies fastest, which is
        # exactly itertools.product's lexicographic order — decoding ascending
        # global indices g via (g // stride_j) % n_j reproduces the historical
        # enumeration (and therefore every pinned config index) bit-for-bit.
        strides = np.ones(self.dim, np.int64)
        for j in range(self.dim - 2, -1, -1):
            strides[j] = strides[j + 1] * nvals[j + 1]
        self._nvals = nvals
        self._strides = strides
        self._value_arrays = [np.asarray(p.values) for p in self.params]

        idx, codes = self._enumerate(chunk_size)
        self.value_indices = idx                     # (N, d) int32
        self._codes = codes                          # (N,) int64, ascending
        self.size = len(idx)
        if self.size == 0:
            raise ValueError(f"{name}: all configurations violate constraints")

        self._norm_denom = np.array(
            [max(len(p.values) - 1, 1) for p in self.params], np.float32)
        self._norm_single = np.array(
            [len(p.values) == 1 for p in self.params], bool)
        self._set_x_norm()
        self._h_csr: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._a_csr: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._row_sq: Optional[np.ndarray] = None   # lazy ||X_norm||² cache
        self._nbr_cache: Dict[Tuple[str, int], np.ndarray] = {}

    # -- enumeration ---------------------------------------------------------
    def _enumerate(self, chunk_size: int) -> Tuple[np.ndarray, np.ndarray]:
        """Chunked vectorized Cartesian product + constraint filtering."""
        cart, d = self.cartesian_size, self.dim
        kept_idx: List[np.ndarray] = []
        kept_codes: List[np.ndarray] = []
        for lo in range(0, cart, chunk_size):
            g = np.arange(lo, min(lo + chunk_size, cart), dtype=np.int64)
            idx = (g[:, None] // self._strides[None, :]) % self._nvals[None, :]
            alive = np.arange(len(g))
            # constraints run in declaration order on the surviving rows only,
            # preserving the old per-row short-circuit semantics
            for c in self.constraints:
                if alive.size == 0:
                    break
                sub = idx[alive]
                if isinstance(c, VectorConstraint):
                    cols = {p.name: arr[sub[:, j]] for j, (p, arr) in
                            enumerate(zip(self.params, self._value_arrays))}
                    alive = alive[c.mask(cols, len(alive))]
                else:  # plain callable: chunked per-row fallback
                    ok = np.fromiter(
                        (c({p.name: p.values[int(sub[i, j])]
                            for j, p in enumerate(self.params)})
                         for i in range(len(alive))),
                        dtype=bool, count=len(alive))
                    alive = alive[ok]
            if alive.size:
                kept_idx.append(idx[alive].astype(np.int32))
                kept_codes.append(g[alive])
        if not kept_idx:
            return (np.zeros((0, d), np.int32), np.zeros(0, np.int64))
        return np.vstack(kept_idx), np.concatenate(kept_codes)

    def _set_x_norm(self) -> None:
        """Ordinal normalization: value j of n -> j/(n-1)  (n==1 -> 0.5).
        Above ``x_norm_lazy_min`` kept configs rows are chunk-computed on
        demand instead of materializing the full float32 (N, d) matrix."""
        lazy = LazyNorm(self.value_indices, self._norm_denom,
                        self._norm_single)
        self.X_norm = (lazy if self.size >= self._x_norm_lazy_min
                       else lazy[:])

    @property
    def x_norm_lazy(self) -> bool:
        return isinstance(self.X_norm, LazyNorm)

    def take(self, keep: np.ndarray) -> "SearchSpace":
        """Restrict the space to a sorted subset of its config indices
        (deterministic trimming, repro.core.spaces._trim). In place."""
        keep = np.asarray(keep)
        if np.any(np.diff(self._codes[keep]) <= 0):
            # checked before any mutation so a rejected call leaves the
            # space untouched
            raise ValueError("take() needs a sorted, duplicate-free subset: "
                             "code lookups binary-search an ascending array")
        self.value_indices = self.value_indices[keep]
        self._codes = self._codes[keep]
        self.size = len(self.value_indices)
        self._set_x_norm()
        self._h_csr = self._a_csr = self._row_sq = None
        self._nbr_cache = {}
        return self

    # -- config access ------------------------------------------------------
    def config(self, i: int) -> Dict[str, Any]:
        row = self.value_indices[i]
        return {p.name: p.values[row[j]] for j, p in enumerate(self.params)}

    def configs(self, ids: Sequence[int]) -> List[Dict[str, Any]]:
        return [self.config(i) for i in ids]

    def _find_code(self, code: int) -> Optional[int]:
        pos = int(np.searchsorted(self._codes, code))
        if pos < self.size and self._codes[pos] == code:
            return pos
        return None

    def index_of(self, cfg: Dict[str, Any]) -> Optional[int]:
        try:
            key = tuple(p.values.index(cfg[p.name]) for p in self.params)
        except (ValueError, KeyError):
            return None
        return self._find_code(sum(k * int(s) for k, s in zip(key, self._strides)))

    def index_of_value_indices(self, row: Sequence[int]) -> Optional[int]:
        """Row of per-param value ordinals -> config index (or None if the
        combination was filtered out by the constraints)."""
        return self._find_code(
            sum(int(v) * int(s) for v, s in zip(row, self._strides)))

    # -- neighborhoods (Hamming: differ in exactly one parameter) -----------
    def _hamming_candidates(self, rows: np.ndarray, codes: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """(m,d) ordinal rows -> (m,K) candidate codes + validity, K = Σ n_j.
        Column order is (param j asc, value v asc, v != row_j) — the exact
        order the historical dict-probe loops produced."""
        cand, valid = [], []
        for j in range(self.dim):
            vs = np.arange(self._nvals[j], dtype=np.int64)
            cand.append(codes[:, None]
                        + (vs[None, :] - rows[:, j:j + 1]) * self._strides[j])
            valid.append(vs[None, :] != rows[:, j:j + 1])
        return np.concatenate(cand, axis=1), np.concatenate(valid, axis=1)

    def _adjacent_candidates(self, rows: np.ndarray, codes: np.ndarray
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """Column order (param j asc, dv in (-1, +1)), matching the old loop."""
        cand, valid = [], []
        for j in range(self.dim):
            for dv in (-1, 1):
                v = rows[:, j] + dv
                cand.append((codes + dv * self._strides[j])[:, None])
                valid.append(((v >= 0) & (v < self._nvals[j]))[:, None])
        return np.concatenate(cand, axis=1), np.concatenate(valid, axis=1)

    def _resolve_candidates(self, cand: np.ndarray, valid: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Candidate codes -> (found mask, positions), constraint-aware."""
        pos = np.searchsorted(self._codes, cand)
        pos_c = np.minimum(pos, self.size - 1)
        found = valid & (self._codes[pos_c] == cand)
        return found, pos_c

    def _build_csr(self, candidates_fn, chunk: int = 1 << 14
                   ) -> Tuple[np.ndarray, np.ndarray]:
        counts = np.zeros(self.size, np.int64)
        blocks: List[np.ndarray] = []
        rows_all = self.value_indices.astype(np.int64)
        for lo in range(0, self.size, chunk):
            hi = min(lo + chunk, self.size)
            cand, valid = candidates_fn(rows_all[lo:hi], self._codes[lo:hi])
            found, pos = self._resolve_candidates(cand, valid)
            counts[lo:hi] = found.sum(axis=1)
            blocks.append(pos[found].astype(np.int32))  # row-major: per-row
            #                                             column order kept
        indptr = np.zeros(self.size + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        indices = (np.concatenate(blocks) if blocks
                   else np.zeros(0, np.int32))
        return indptr, indices

    def _neighbors(self, i: int, candidates_fn, csr_attr: str) -> List[int]:
        csr = getattr(self, csr_attr)
        if csr is None and self.size <= self._csr_build_max:
            csr = self._build_csr(candidates_fn)
            setattr(self, csr_attr, csr)
        if csr is not None:
            indptr, indices = csr
            return indices[indptr[i]:indptr[i + 1]].tolist()
        # space too large for a precomputed index: partial CSR over the
        # visited region — local searches (SA/MLS/GA) re-query the incumbent
        # neighborhood every step, so memoized rows turn the ~90 µs vectorized
        # recompute into a dict hit. FIFO-evicted above _nbr_cache_max rows.
        key = (csr_attr, int(i))
        hit = self._nbr_cache.get(key)
        if hit is None:
            row = self.value_indices[i:i + 1].astype(np.int64)
            cand, valid = candidates_fn(row, self._codes[i:i + 1])
            found, pos = self._resolve_candidates(cand, valid)
            hit = pos[found].astype(np.int32)
            if len(self._nbr_cache) >= self._nbr_cache_max:
                self._nbr_cache.pop(next(iter(self._nbr_cache)))
            self._nbr_cache[key] = hit
        return hit.tolist()

    def hamming_neighbors(self, i: int) -> List[int]:
        return self._neighbors(i, self._hamming_candidates, "_h_csr")

    def adjacent_neighbors(self, i: int) -> List[int]:
        """Differ in one parameter by one ordinal step (for local search)."""
        return self._neighbors(i, self._adjacent_candidates, "_a_csr")

    def random_index(self, rng: np.random.Generator) -> int:
        return int(rng.integers(0, self.size))

    def nearest_index(self, x_norm: np.ndarray,
                      exclude: Optional[set] = None,
                      chunk: int = 1 << 16) -> int:
        """Snap a [0,1]^d point to the nearest enumerated config (L2)."""
        x = np.asarray(x_norm)
        if x.dtype != self.X_norm.dtype:
            # don't let a float64 query upcast the whole (N, d) matrix
            x = x.astype(self.X_norm.dtype)
        if not self.x_norm_lazy:
            d2 = np.sum((self.X_norm - x[None, :]) ** 2, axis=1)
            if exclude:
                d2[list(exclude)] = np.inf   # fresh buffer: no copy needed
            return int(np.argmin(d2))
        # lazy X_norm: chunk the scan so no (N, d) buffer materializes
        best_d, best_i = np.inf, 0
        for lo in range(0, self.size, chunk):
            d2 = np.sum((self.X_norm[lo:lo + chunk] - x[None, :]) ** 2, axis=1)
            if exclude:
                local = [e - lo for e in exclude if lo <= e < lo + len(d2)]
                if local:
                    d2[local] = np.inf
            k = int(np.argmin(d2))
            if d2[k] < best_d:
                best_d, best_i = float(d2[k]), lo + k
        return best_i

    def nearest_indices(self, X: np.ndarray, chunk: int = 1 << 16) -> np.ndarray:
        """Batch nearest_index (no exclusion), chunked over the space so the
        (q, N) distance matrix never materializes. Used by candidate-pool BO's
        LHS refresh and by cross-size warm-start record mapping."""
        X = np.asarray(X, self.X_norm.dtype)
        if X.ndim == 1:
            X = X[None, :]
        q_sq = np.sum(X * X, axis=1)
        if self._row_sq is None and not self.x_norm_lazy:
            self._row_sq = np.sum(self.X_norm * self.X_norm, axis=1)
        best_d = np.full(len(X), np.inf, np.float32)
        best_i = np.zeros(len(X), np.int64)
        for lo in range(0, self.size, chunk):
            B = self.X_norm[lo:lo + chunk]
            b_sq = (np.sum(B * B, axis=1) if self._row_sq is None
                    else self._row_sq[lo:lo + chunk])
            d2 = (q_sq[:, None] + b_sq[None, :]
                  - 2.0 * (X @ B.T))                       # (q, m)
            k = np.argmin(d2, axis=1)                      # row-contiguous
            d = d2[np.arange(len(X)), k]
            better = d < best_d
            best_d[better] = d[better]
            best_i[better] = lo + k[better]
        return best_i

    def describe(self) -> str:
        lines = [f"SearchSpace {self.name}: {self.size} configs "
                 f"(cartesian {self.cartesian_size}, {self.dim} params)"]
        for p in self.params:
            vals = ", ".join(str(v) for v in p.values[:8])
            more = "..." if len(p.values) > 8 else ""
            lines.append(f"  {p.name}: [{vals}{more}] ({len(p.values)})")
        return "\n".join(lines)
