"""Objectives: the expensive black-box f(x) (paper §III-A).

Three families:
  * SimulatedObjective — the paper's simulation mode: a recorded/synthetic
    table of per-config runtimes (NaN = runtime-invalid). Deterministic,
    hardware-free benchmarking of search strategies.
  * CallableObjective — wraps a real measurement (e.g. timing a jitted
    Pallas kernel config, used by examples/tune_kernel.py).
  * Subprocess/compile objectives for distribution tuning live in
    repro.core.tuning_targets (the objective is a dry-run compile).

Invalid configurations return NaN; the runner records them but the BO
surrogate never sees them (§III-D2).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.core.searchspace import SearchSpace


class Objective:
    """Protocol: evaluate config index -> runtime (lower better, NaN invalid)."""

    space: SearchSpace
    name: str = "objective"

    def __call__(self, idx: int) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    def eval_config(self, cfg: Dict[str, Any]) -> float:
        """Evaluate an arbitrary config dict (constraint-unaware strategies
        may propose configs outside the restricted space -> invalid)."""
        idx = self.space.index_of(cfg)
        if idx is None:
            return math.nan
        return self(idx)

    @property
    def optimum(self) -> Optional[float]:
        return None


class SimulatedObjective(Objective):
    """Paper's simulation mode: precomputed runtimes for the whole space."""

    def __init__(self, space: SearchSpace, times: np.ndarray, name: str = "sim"):
        assert len(times) == space.size
        self.space = space
        self.times = np.asarray(times, np.float64)
        self.name = name
        valid = self.times[np.isfinite(self.times)]
        self._optimum = float(valid.min()) if len(valid) else math.nan

    def __call__(self, idx: int) -> float:
        return float(self.times[idx])

    @property
    def optimum(self) -> float:
        return self._optimum

    @property
    def n_invalid(self) -> int:
        return int(np.sum(~np.isfinite(self.times)))


class CallableObjective(Objective):
    def __init__(self, space: SearchSpace, fn: Callable[[Dict[str, Any]], float],
                 name: str = "callable"):
        self.space = space
        self.fn = fn
        self.name = name

    def __call__(self, idx: int) -> float:
        try:
            v = self.fn(self.space.config(idx))
        except Exception:
            return math.nan
        return float(v) if v is not None else math.nan
