"""Batched parallel evaluation engine for ask/tell strategies (DESIGN.md §5).

The engine owns the loop the strategies used to own: it asks a strategy for
up to ``batch_size`` proposals, evaluates them on a worker pool (thread or
process backend), and tells the strategy each result. Semantics are pinned
to the sequential seed implementation:

  * Budget counts UNIQUE evaluations; cache hits cost only ``total_calls``
    (capped at ``max_total_calls``); invalid configs and proposals outside
    the restricted space consume budget without an objective call.
  * In-flight dedup: a proposal for a config already being evaluated is not
    dispatched again — it is resolved with the first evaluation's result.
  * Ordered journal: observations are recorded (and checkpointed) in
    proposal-acceptance order, never completion order, so the journal is
    always a prefix of a deterministic sequence and ``TuningRun.resume``
    stays lossless even when a run is killed mid-batch.
  * Strategy tells arrive in the same acceptance order, which is what makes
    ``batch_size=1, workers=1`` reproduce the seed's sequential runs
    bit-for-bit (golden-trace tests).
  * Per-worker budget accounting: every dispatched evaluation is attributed
    to the worker that ran it (``TuneResult.worker_stats``).

With ``workers=1`` evaluations run inline in the caller's thread — no pool,
no overhead, identical to the seed runner. The process backend requires a
picklable objective (it is shipped once per worker via the pool initializer);
use it for objectives that hold the GIL, e.g. in-process compile jobs.
"""
from __future__ import annotations

import json
import math
import multiprocessing as mp
import os
import threading
import time
from collections import deque
from concurrent.futures import Future, ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Deque, Dict, Optional

import numpy as np

from repro.core.objectives import Objective
from repro.core.runner import TuneResult, TuningRun
from repro.core.strategies.base import Proposal, Strategy, StrategyContext
from repro.store.records import TuningRecordStore
from repro.store.transfer import warm_matches

_PROC_OBJECTIVE: Optional[Objective] = None


@dataclass(frozen=True)
class RetuneRequest:
    """A serving-side ask for fresh tuning of one cell (DESIGN.md §12).

    Emitted by the online serve loop when observed prod latency diverges
    from the deployed config's stored roofline prediction; serviced by any
    tuner with access to the shared store (``run_retune``), whose journal
    the serving fleet then hot-reloads."""

    key: str                 # dedupe key: the cell, e.g. "dryrun[a×s×m]"
    objective: str = ""      # tuning-objective id of the cell
    observed: float = math.nan    # windowed median prod latency (s)
    predicted: float = math.nan   # stored roofline step time (s)
    reason: str = "drift"
    t: float = 0.0


class RetuneQueue:
    """Thread-safe IN-PROCESS intake for drift-triggered re-tune requests.

    One pending request per cell: a fleet of servers all observing the same
    drifted cell collapses to a single re-tune instead of a stampede. The
    key re-arms once the request is popped (taken by a tuner).

    This queue dies with its process; production serving uses the durable
    store-backed ``repro.store.queue.TuningJobQueue`` (same ``submit``
    interface), whose requests survive crashes and are claimed — under
    fenced, exactly-once leases — by a fleet of ``repro.launch.retune``
    daemons."""

    def __init__(self):
        self._lock = threading.Lock()
        self._queue: Deque[RetuneRequest] = deque()
        self._pending: set = set()

    def submit(self, req: RetuneRequest) -> bool:
        """Enqueue unless the cell already has a pending request."""
        with self._lock:
            if req.key in self._pending:
                return False
            self._pending.add(req.key)
            self._queue.append(req)
            return True

    def pop(self) -> Optional[RetuneRequest]:
        with self._lock:
            if not self._queue:
                return None
            req = self._queue.popleft()
            self._pending.discard(req.key)
            return req

    def __len__(self) -> int:
        with self._lock:
            return len(self._queue)


def run_retune(request: RetuneRequest, objective: Objective, strategy, *,
               store, budget: int, seed: int = 0, job_type: str = "retune",
               run_meta: Optional[Dict[str, Any]] = None, **engine_kw):
    """Service one tuning-job request: a warm-started engine run journaled
    into the shared ``store`` under a request-derived run id. Prior records
    for the cell — including the ``context="prod"`` telemetry that triggered
    the request — seed the strategy through the standard warm-start path, so
    a drift re-tune starts from everything serving has learned. The serving
    fleet picks the new records up by tailing the same store.

    ``job_type`` prefixes the run id (``retune`` keeps the historical ids);
    ``run_meta`` is stamped into every journaled record — the retune daemon
    passes its claim's fencing token here (``{"fence": {"key", "token"}}``)
    so consumers can reject a fenced-out claimant's late writes."""
    engine = ParallelTuningEngine(
        objective, budget, store=store,
        run_id=f"{job_type}[{request.key}]@{request.t:g}",
        run_meta=run_meta, **engine_kw)
    return engine.run(strategy, seed=seed)


def _proc_init(objective: Objective) -> None:
    global _PROC_OBJECTIVE
    _PROC_OBJECTIVE = objective


def _proc_eval(idx: int):
    t0 = time.time()
    v = _PROC_OBJECTIVE(idx)
    return v, time.time() - t0, f"pid-{os.getpid()}"


@dataclass
class WorkerStats:
    n_evals: int = 0
    busy_s: float = 0.0


@dataclass
class _Pending:
    """One accepted proposal awaiting record+tell, in acceptance order."""
    proposal: Proposal
    key: str
    idx: Optional[int]
    primary: bool                      # this entry owns the journal record
    future: Optional[Future] = None    # set when dispatched to the pool
    dup_of: Optional["_Pending"] = None  # in-flight dedup target
    resolved: bool = False
    value: float = math.nan
    dur: float = 0.0
    worker: str = "main"

    def ready(self) -> bool:
        if self.resolved:
            return True
        if self.future is not None:
            return self.future.done()
        if self.dup_of is not None:
            return self.dup_of.resolved
        return False


class ParallelTuningEngine:
    def __init__(self, objective: Objective, budget: int, *,
                 batch_size: int = 1, workers: int = 1,
                 max_in_flight: Optional[int] = None,
                 backend: str = "thread",
                 max_total_calls: Optional[int] = None,
                 checkpoint_path: Optional[str] = None,
                 store=None, run_id: Optional[str] = None,
                 context: str = "", warm_start: bool = True,
                 run_meta: Optional[Dict[str, Any]] = None):
        if backend not in ("thread", "process"):
            raise ValueError(f"unknown backend {backend!r}")
        self.objective = objective
        self.budget = budget
        self.batch_size = max(int(batch_size), 1)
        self.workers = max(int(workers), 1)
        self.max_in_flight = max(max_in_flight or max(self.workers,
                                                      self.batch_size), 1)
        self.backend = backend
        self.max_total_calls = max_total_calls
        self.checkpoint_path = checkpoint_path
        # shared record store (repro.store): journal persistence + transfer.
        # A path opens through the sidecar segment index (lazy=True): the
        # engine touches only this run's fingerprint and its warm-start
        # matches, so opening must stay O(hot set) on fleet-scale stores.
        self.store = (TuningRecordStore(store, lazy=True)
                      if isinstance(store, str) else store)
        self.run_id = run_id
        self.context = context
        self.warm_start = warm_start
        # extra meta stamped into every journaled record alongside the
        # strategy/seed/budget triple (e.g. the fencing token of the claim
        # this run services — repro.store.queue)
        self.run_meta = dict(run_meta) if run_meta else {}
        self.worker_stats: Dict[str, WorkerStats] = {}

    # ------------------------------------------------------------------
    def run(self, strategy: Strategy, seed: int = 0,
            resume: bool = False) -> TuneResult:
        run_id = self.run_id or f"{strategy.name}-s{seed}"
        if (not resume and self.store is None and self.checkpoint_path
                and os.path.isfile(self.checkpoint_path)):
            # a journal file is ONE run: a fresh (non-resume) run replaces a
            # stale journal, exactly as the pre-store whole-JSON rewrite did
            os.remove(self.checkpoint_path)
        run = TuningRun(self.objective, self.budget,
                        max_total_calls=self.max_total_calls,
                        checkpoint_path=self.checkpoint_path,
                        store=self.store, run_id=run_id, context=self.context,
                        run_meta={"strategy": strategy.name, "seed": seed,
                                  "budget": self.budget, **self.run_meta})
        if resume:
            run.resume()
        rng = np.random.default_rng(seed)
        strategy.reset(StrategyContext(
            space=run.space, budget=self.budget, rng=rng,
            replayed=tuple((o.idx, o.value) for o in run.journal)))
        if self.warm_start and self.store is not None and len(self.store) > 0:
            # transfer-aware warm start: prior records under this fingerprint
            # (other runs) or a compatible cross-size one. Only an explicitly
            # shared store transfers — a bare checkpoint journal keeps the
            # historical semantics (its records are for resume only). Cold
            # stores yield no matches and leave the run bit-for-bit identical.
            warm = warm_matches(self.store, run.fingerprint, run.space,
                                exclude_runs=(run_id,))
            if warm:
                strategy.warm_start(warm)
        self.worker_stats = {}
        t0 = time.time()
        pool = None
        if self.workers > 1:
            if self.backend == "thread":
                pool = ThreadPoolExecutor(self.workers,
                                          thread_name_prefix="tuner")
            else:
                # spawn, not fork: the parent holds JAX's thread pools and a
                # forked child can deadlock inside them
                pool = ProcessPoolExecutor(
                    self.workers, mp_context=mp.get_context("spawn"),
                    initializer=_proc_init, initargs=(self.objective,))
        try:
            self._loop(strategy, run, pool)
        finally:
            if pool is not None:
                pool.shutdown(wait=False, cancel_futures=True)
        best_idx, best_val = run.best()
        return TuneResult(strategy=strategy.name, objective=run.objective.name,
                          best_idx=best_idx, best_value=best_val,
                          trace=run.best_trace(),
                          unique_evals=run.unique_evals,
                          wall_time_s=time.time() - t0, journal=run.journal,
                          worker_stats={k: vars(v).copy() for k, v
                                        in self.worker_stats.items()})

    # ------------------------------------------------------------------
    def _loop(self, strategy: Strategy, run: TuningRun, pool) -> None:
        pending: Deque[_Pending] = deque()
        in_flight: Dict[str, _Pending] = {}
        stop = False
        while True:
            exhausted = False
            if not stop and len(pending) < self.max_in_flight:
                want = min(self.batch_size,
                           self.max_in_flight - len(pending))
                props = strategy.suggest(want)
                if not props:
                    exhausted = True
                for p in props:
                    if not self._accept(p, run, pool, pending, in_flight):
                        stop = True     # budget / total-call cap reached
                        break
            if not pending:
                # either the run is over (stop/exhausted) or every accept
                # above appended an entry — nothing to spin-wait on
                break
            # drain the head (blocking), then any already-finished successors,
            # so the journal and the tells stay in acceptance order
            self._settle(pending.popleft(), run, in_flight, strategy)
            while pending and pending[0].ready():
                self._settle(pending.popleft(), run, in_flight, strategy)

    # ------------------------------------------------------------------
    def _accept(self, p: Proposal, run: TuningRun, pool,
                pending: Deque[_Pending], in_flight: Dict[str, _Pending]) -> bool:
        """Replicates TuningRun.evaluate/evaluate_config bookkeeping. Returns
        False when the run must stop (budget or total-call cap)."""
        if p.config is not None:
            idx = run.space.index_of(p.config)
            key = (str(int(idx)) if idx is not None
                   else "cfg:" + json.dumps(p.config, sort_keys=True,
                                            default=str))
        else:
            idx, key = int(p.idx), str(int(p.idx))
        run.total_calls += 1
        if key in run.cache:
            if run.total_calls > run.max_total_calls:
                return False
            pending.append(_Pending(p, key, idx, primary=False, resolved=True,
                                    value=run.cache[key]))
            return True
        if key in in_flight:
            if run.total_calls > run.max_total_calls:
                return False
            pending.append(_Pending(p, key, idx, primary=False,
                                    dup_of=in_flight[key]))
            return True
        if run.unique_evals + len(in_flight) >= run.budget:
            return False
        entry = _Pending(p, key, idx, primary=True)
        if idx is None:
            # outside the restricted space: recorded invalid, no objective call
            entry.resolved, entry.value = True, math.nan
        elif pool is None:
            t_eval = time.time()
            entry.value = run.objective(idx)
            entry.dur = time.time() - t_eval
            entry.resolved = True
        else:
            entry.future = (pool.submit(self._eval_threaded, idx)
                            if self.backend == "thread"
                            else pool.submit(_proc_eval, idx))
        pending.append(entry)
        in_flight[key] = entry
        return True

    def _eval_threaded(self, idx: int):
        t0 = time.time()
        v = self.objective(idx)
        return v, time.time() - t0, threading.current_thread().name

    # ------------------------------------------------------------------
    def _settle(self, entry: _Pending, run: TuningRun,
                in_flight: Dict[str, _Pending], strategy: Strategy) -> None:
        if entry.future is not None:
            entry.value, entry.dur, entry.worker = entry.future.result()
            entry.resolved = True
        elif entry.dup_of is not None:
            # the primary was accepted earlier, so it settled earlier
            entry.value, entry.resolved = entry.dup_of.value, True
        if entry.primary:
            # worker/dur go in BEFORE _record serializes the observation to
            # the store — patched-after fields would never reach disk
            run._record(entry.key, entry.idx, entry.value, entry.proposal.af,
                        worker=entry.worker, dur=entry.dur)
            in_flight.pop(entry.key, None)
            ws = self.worker_stats.setdefault(entry.worker, WorkerStats())
            ws.n_evals += 1
            ws.busy_s += entry.dur
        strategy.observe(entry.proposal, entry.value)
