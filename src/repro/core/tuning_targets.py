"""Distribution-config auto-tuning target (the beyond-paper integration).

The objective is a MULTI-POD DRY-RUN COMPILE: a (sharding rules, remat,
microbatch, chunking, capacity...) configuration is lowered + compiled
against the production mesh in a subprocess, and the roofline step time
(max of compute/memory/collective terms, repro.launch.roofline) is returned.
Configs that fail to compile, or whose per-device memory exceeds HBM, are
INVALID — giving the exact problem shape of the paper (expensive black box,
discrete constrained space, runtime-discovered invalids) at datacenter scale.

Evaluations take ~20–120 s of XLA compile each, so results are cached on
disk keyed by (arch, shape, mesh, config) and runs are resumable through the
tuner journal (repro.core.runner).
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import re
import subprocess
import sys
from typing import Any, Dict, List, Optional

from repro.core.objectives import Objective
from repro.core.searchspace import Param, SearchSpace, VectorConstraint
from repro.launch.roofline import HBM_BYTES
from repro.parallel.sharding import (VMEM_BYTES, attn_tile_occupancy,
                                     flash_vmem_bytes)

REPO = os.path.join(os.path.dirname(__file__), "..", "..", "..")

#: Tokens per global batch for the train shapes — microbatching must divide it.
GLOBAL_BATCH = 32


def _seq_tokens(shape: str) -> int:
    """Sequence length a cell shape implies (``train_4k`` → 4096);
    unknown shapes use the production default."""
    m = re.search(r"(\d+)k$", shape)
    return int(m.group(1)) * 1024 if m else 4096


def sharding_space(arch: str, shape: str, wide: bool = False,
                   hard: bool = False) -> SearchSpace:
    """Distribution knobs applicable to the given cell.

    ``wide=True`` opens the full chunk-size grids (cartesian >10^6, >2M for
    MoE cells) with the physically-required combinations expressed as
    vectorized ``VectorConstraint`` column predicates — the scale the old
    per-row Python enumeration could not reach. The default narrow space is
    unchanged, so existing tuning caches and journals stay valid.

    ``hard=True`` (implies ``wide``) is the tightly-constrained variant the
    propagating sampler (DESIGN.md §15) unlocks: every cell gets the
    ``attn_block_q`` grid plus VMEM-residency and occupancy constraints
    coupling four-plus knobs at once (double-buffered flash tiles and the
    chunked-logits tile must co-reside in per-core VMEM; the attention grid
    must keep every core busy). Rejection sampling stalls on grids like
    these — feasible fractions sink orders of magnitude below the wide
    variant's — so the space is published under a NEW fingerprint family
    (``sharding_hard[...]``): hard-grid journals never mix with wide ones.
    """
    if hard:
        wide = True
    if not wide:
        params = [
            Param("remat", ("none", "dots", "full")),
            Param("attn_q_chunks", (1, 2, 4)),
            Param("logits_chunk", (512, 2048, 8192)),
            Param("attn_block_kv", (512, 1024, 2048)),
            Param("flash", (1, 0)),   # 1: blockwise flash; 0: direct attention
        ]
        if shape == "train_4k":
            params.append(Param("opt_moment_dtype", ("float32", "bfloat16")))
            params.append(Param("microbatches", (1, 2, 4)))
        if arch.startswith(("deepseek", "qwen3")):
            params.append(Param("capacity_factor", (1.0, 1.25, 1.5)))
            params.append(Param("experts_rule", ("model", "model+data")))
        if arch.startswith("xlstm"):
            params.append(Param("mlstm_chunk", (0, 32, 64, 128)))
        params.append(Param("embed_rule", ("data", "none")))  # ZeRO-3 on/off
        return SearchSpace(params, (), name=f"sharding[{arch}×{shape}]")

    params = [
        Param("remat", ("none", "dots", "full")),
        Param("attn_q_chunks", (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64)),
        Param("logits_chunk", (128, 192, 256, 384, 512, 768, 1024, 1536,
                               2048, 3072, 4096, 6144, 8192, 12288, 16384,
                               32768)),
        Param("attn_block_kv", (128, 192, 256, 384, 512, 768, 1024, 1536,
                                2048, 3072, 4096)),
        Param("flash", (1, 0)),
    ]
    cons = [
        # blockwise flash needs at least a 256-token KV block per grid step
        VectorConstraint(lambda c: (c["flash"] == 0)
                         | (c["attn_block_kv"] >= 256),
                         name="flash_min_kv_block"),
        # direct attention materializes the (q, kv) block: cap the KV tile
        VectorConstraint(lambda c: (c["flash"] == 1)
                         | (c["attn_block_kv"] <= 2048),
                         name="direct_max_kv_block"),
        # combined q-chunk × kv-block tiling degenerates past this product
        VectorConstraint(lambda c: c["attn_q_chunks"] * c["attn_block_kv"]
                         <= 32768, name="tile_product"),
    ]
    if shape == "train_4k":
        params.append(Param("opt_moment_dtype", ("float32", "bfloat16")))
        params.append(Param("microbatches", tuple(
            m for m in (1, 2, 4, 8, 16, 32) if GLOBAL_BATCH % m == 0)))
        # vacuous for the derived grid above; keeps the coupling declared if
        # the grid is ever widened past the divisors
        cons.append(VectorConstraint(
            lambda c: GLOBAL_BATCH % c["microbatches"] == 0,
            name="microbatch_divides_batch"))
    if arch.startswith(("deepseek", "qwen3")):
        # MoE cells get the full distribution-knob grid: cartesian goes past
        # 10^9 on train_4k, which the generative backend (DESIGN.md §15)
        # serves without enumeration. Narrow/trimmed MoE fingerprints are
        # intentionally incompatible with this wide grid (extra params), so
        # cross-width transfer is off for MoE cells — by design, not drift.
        params.append(Param("capacity_factor", (1.0, 1.05, 1.1, 1.25, 1.4,
                                                1.5, 1.6, 1.75, 2.0)))
        params.append(Param("experts_rule", ("model", "model+data")))
        params.append(Param("attn_block_q", (128, 192, 256, 384, 512, 768,
                                             1024, 1536, 2048, 3072, 4096)))
        params.append(Param("moe_combine", ("gather", "a2a")))
        params.append(Param("grad_compression", ("none", "topk", "int8")))
        params.append(Param("grad_compression_topk", (0.01, 0.05, 0.1)))
        cons += [
            # blockwise flash keeps a q×kv f32 accumulator tile in VMEM
            VectorConstraint(lambda c: (c["flash"] == 0)
                             | (c["attn_block_q"] * c["attn_block_kv"]
                                <= 2 ** 21),
                             name="flash_q_kv_vmem"),
            # the top-k ratio only exists under top-k compression; pin it to
            # its default otherwise so the knob can't split identical configs
            VectorConstraint(lambda c: (c["grad_compression"] == "topk")
                             | (c["grad_compression_topk"] == 0.05),
                             name="topk_ratio_coupling"),
        ]
    if arch.startswith("xlstm"):
        params.append(Param("mlstm_chunk", (0, 16, 32, 48, 64, 96, 128,
                                            192, 256)))
    params.append(Param("embed_rule", ("data", "none")))  # ZeRO-3 on/off
    if hard:
        if not any(p.name == "attn_block_q" for p in params):
            params.append(Param("attn_block_q", (128, 192, 256, 384, 512,
                                                 768, 1024, 1536, 2048,
                                                 3072, 4096)))
        seq = _seq_tokens(shape)
        cons += [
            # double-buffered flash tiles plus the chunked-logits tile
            # (bf16 activations + f32 accumulator over a 128-row block)
            # must co-reside in per-core VMEM — couples flash, both
            # attention blocks, and logits_chunk in one predicate
            VectorConstraint(
                lambda c: (c["flash"] * 2
                           * flash_vmem_bytes(c["attn_block_q"],
                                              c["attn_block_kv"])
                           + c["logits_chunk"] * 128 * 6) <= VMEM_BYTES,
                name="vmem_coresidency"),
            # the q×kv attention grid (after q-chunking) must keep every
            # core busy each wave
            VectorConstraint(
                lambda c: attn_tile_occupancy(
                    seq // c["attn_q_chunks"], c["attn_block_q"],
                    c["attn_block_kv"]) >= 1.0,
                name="occupancy_floor"),
            # direct attention has no streaming stats: its full q-block of
            # logits must fit outright, steeply capping the block product
            VectorConstraint(
                lambda c: (c["flash"] == 1)
                | (c["attn_block_q"] * c["attn_block_kv"] * 4
                   <= VMEM_BYTES // 4),
                name="direct_logits_fit"),
            # no ragged tiles: the q-chunking times the q block must divide
            # the sequence exactly, and so must the kv block — the
            # divisibility restrictions of real kernel grids (the paper's
            # own constraint family), and the main tightness driver here
            VectorConstraint(
                lambda c: seq % (c["attn_q_chunks"] * c["attn_block_q"]) == 0,
                name="q_tiles_divide_seq"),
            VectorConstraint(lambda c: seq % c["attn_block_kv"] == 0,
                             name="kv_tiles_divide_seq"),
        ]
        return SearchSpace(params, cons, name=f"sharding_hard[{arch}×{shape}]")
    return SearchSpace(params, cons, name=f"sharding_wide[{arch}×{shape}]")


def _config_args(cfg: Dict[str, Any]) -> List[str]:
    args = []
    if cfg.get("remat") and cfg["remat"] != "none":
        args += ["--remat", cfg["remat"]]
    if cfg.get("attn_q_chunks", 1) != 1:
        args += ["--q-chunks", str(cfg["attn_q_chunks"])]
    if cfg.get("microbatches", 1) != 1:
        args += ["--microbatches", str(cfg["microbatches"])]
    if cfg.get("capacity_factor"):
        args += ["--capacity-factor", str(cfg["capacity_factor"])]
    if cfg.get("logits_chunk") is not None:
        args += ["--logits-chunk", str(cfg["logits_chunk"])]
    if cfg.get("attn_block_kv"):
        args += ["--attn-block-kv", str(cfg["attn_block_kv"])]
    if cfg.get("opt_moment_dtype"):
        args += ["--opt-moment-dtype", cfg["opt_moment_dtype"]]
    if cfg.get("flash", 1) == 0:
        args += ["--no-flash"]
    if cfg.get("mlstm_chunk"):
        args += ["--mlstm-chunk", str(cfg["mlstm_chunk"])]
    if cfg.get("attn_block_q"):
        args += ["--attn-block-q", str(cfg["attn_block_q"])]
    if cfg.get("moe_combine") and cfg["moe_combine"] != "gather":
        args += ["--moe-combine", cfg["moe_combine"]]
    if cfg.get("grad_compression") and cfg["grad_compression"] != "none":
        args += ["--grad-compression", cfg["grad_compression"]]
        if cfg["grad_compression"] == "topk" and cfg.get("grad_compression_topk"):
            args += ["--grad-compression-topk",
                     str(cfg["grad_compression_topk"])]
    rules = []
    if cfg.get("experts_rule") == "model+data":
        rules.append("experts=model+data")
    if cfg.get("embed_rule") == "none":
        rules.append("embed=None")
    if rules:
        args += ["--rules", ",".join(rules)]
    return args


class DryRunObjective(Objective):
    """step-time (s) of the compiled cell under a distribution config."""

    def __init__(self, arch: str, shape: str, mesh: str = "single",
                 cache_dir: str = "results/tune_cache",
                 check_hbm: bool = True, timeout_s: int = 2400,
                 repo_root: Optional[str] = None, verbose: bool = True,
                 wide: bool = False):
        self.arch, self.shape, self.mesh = arch, shape, mesh
        self.space = sharding_space(arch, shape, wide=wide)
        self.cache_dir = cache_dir
        self.check_hbm = check_hbm
        self.timeout_s = timeout_s
        self.verbose = verbose
        self.root = repo_root or os.path.abspath(REPO)
        self.name = f"dryrun[{arch}×{shape}×{mesh}]"
        os.makedirs(os.path.join(self.root, cache_dir), exist_ok=True)

    def _cache_key(self, cfg: Dict[str, Any]) -> str:
        blob = json.dumps([self.arch, self.shape, self.mesh, cfg], sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def record_for(self, cfg: Dict[str, Any]) -> Optional[Dict]:
        path = os.path.join(self.root, self.cache_dir,
                            self._cache_key(cfg) + ".json")
        tagdir = os.path.join(self.root, self.cache_dir,
                              self._cache_key(cfg) + ".d")
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f)
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", self.arch, "--shape", self.shape,
               "--mesh", self.mesh, "--out", tagdir,
               "--tag", "tune"] + _config_args(cfg)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(self.root, "src")
        env.pop("XLA_FLAGS", None)
        try:
            subprocess.run(cmd, cwd=self.root, env=env, timeout=self.timeout_s,
                           capture_output=True, text=True)
        except subprocess.TimeoutExpired:
            rec = {"status": "timeout"}
            with open(path, "w") as f:
                json.dump(rec, f)
            return rec
        out = os.path.join(tagdir,
                           f"tune__{self.arch}__{self.shape}__{self.mesh}.json")
        if not os.path.exists(out):
            rec = {"status": "crash"}
        else:
            with open(out) as f:
                rec = json.load(f)
        with open(path, "w") as f:
            json.dump(rec, f)
        return rec

    def __call__(self, idx: int) -> float:
        cfg = self.space.config(idx)
        rec = self.record_for(cfg)
        if rec.get("status") != "ok":
            if self.verbose:
                print(f"  [tune] {cfg} -> INVALID ({rec.get('status')})")
            return math.nan
        if self.check_hbm:
            mem = rec.get("memory", {})
            live = mem.get("argument_size_in_bytes", 0) + mem.get(
                "temp_size_in_bytes", 0)
            if live > HBM_BYTES:
                if self.verbose:
                    print(f"  [tune] {cfg} -> INVALID "
                          f"(HBM {live/2**30:.1f} GiB > 16 GiB)")
                return math.nan
        t = rec["roofline"]["step_time"]
        if self.verbose:
            rf = rec["roofline"]
            print(f"  [tune] {cfg} -> {t:.3f}s "
                  f"(c={rf['t_compute']:.2f} m={rf['t_memory']:.2f} "
                  f"x={rf['t_collective']:.2f})")
        return float(t)
