"""Gradient compression for the DCN (pod) axis.

At 2+ pods the inter-pod all-reduce crosses DCN (~6 GB/s/host vs 50 GB/s ICI
links); compressing the pod-axis gradient exchange is the standard lever.
Two schemes, both under shard_map on the `pod` axis:

  * int8 stochastic-rounding quantized all-reduce (8x fewer DCN bytes,
    unbiased);
  * top-k sparsification with ERROR FEEDBACK (residual carried to the next
    step — converges like dense SGD for k as low as 1-5%).

These operate on the DP-replicated gradient after the intra-pod reduction;
`repro.runtime.train.TrainLoop` wires them in when
ParallelConfig.grad_compression != "none".
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def int8_allreduce(g: jax.Array, axis_name: str, key: jax.Array) -> jax.Array:
    """Unbiased int8-quantized psum over `axis_name`.

    The scale must be SHARED across ranks (Σᵢ qᵢ·sᵢ ≠ (Σᵢ qᵢ)·s̄ for per-rank
    scales), so one scalar pmax precedes the int8 payload exchange.
    """
    gmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
    scale = gmax / 127.0 + 1e-12
    # decorrelate dither across ranks or it sums coherently instead of
    # cancelling ~1/sqrt(n)
    key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    noise = jax.random.uniform(key, g.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(g / scale + noise), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n


def topk_error_feedback(g: jax.Array, residual: jax.Array, axis_name: str,
                        k_frac: float = 0.05) -> Tuple[jax.Array, jax.Array]:
    """Sparse all-reduce with error feedback.

    Returns (averaged dense gradient, new residual). The dense psum of the
    sparsified tensor stands in for the index-union exchange; DCN bytes are
    k_frac of dense (the payload that actually needs to move).
    """
    acc = g + residual
    flat = jnp.abs(acc.reshape(-1))
    k = max(int(k_frac * flat.size), 1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    mask = (jnp.abs(acc) >= thresh).astype(acc.dtype)
    sparse = acc * mask
    new_residual = acc - sparse
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    reduced = jax.lax.psum(sparse, axis_name) / n
    return reduced, new_residual


def compress_tree_psum(grads: Any, residuals: Optional[Any], axis_name: str,
                       method: str, key: jax.Array, k_frac: float = 0.05
                       ) -> Tuple[Any, Optional[Any]]:
    """Apply a compression scheme leaf-wise over a gradient pytree."""
    if method == "none":
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, grads), residuals
    if method == "int8":
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        keys = jax.random.split(key, len(leaves))
        out = [int8_allreduce(g, axis_name, k) for g, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, out), residuals
    if method == "topk":
        assert residuals is not None
        pairs = jax.tree.map(
            lambda g, r: topk_error_feedback(g, r, axis_name, k_frac),
            grads, residuals)
        reduced = jax.tree.map(lambda p: p[0], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_res = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return reduced, new_res
    raise ValueError(method)
