"""Logical-axis sharding rules → GSPMD shardings.

Every parameter and activation in the model is annotated with *logical* axis
names ("embed", "heads", "mlp", "experts", "act_batch", ...). A rule table
maps logical axes onto physical mesh axes; `resolve_spec` drops mesh axes
that don't divide the dimension (e.g. kv_heads=1 under model=16 → replicate)
or that are already taken by another dimension of the same tensor. This makes
one rule table serve all ten architectures and both production meshes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Axis = Union[str, Tuple[str, ...], None]

# Parameter logical axes. "embed" on weights is the ZeRO-3/FSDP axis.
DEFAULT_PARAM_RULES: Dict[str, Axis] = {
    "vocab": "model",
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "lora": None,
    "layers": None,
}

# Activation logical axes.
DEFAULT_ACT_RULES: Dict[str, Axis] = {
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "act_experts": "model",
    "act_group": "data",       # MoE dispatch groups
    "act_cache_seq": None,
    "act_vocab": "model",
}


@dataclass(frozen=True)
class KernelConfig:
    """Tuned Pallas-kernel dispatch knobs (DESIGN.md §14).

    ``ParallelConfig.kernel is None`` (the default) keeps every model path on
    the pure-JAX implementations — byte-identical to pre-kernel-tuning
    behavior. Block sizes come from the kernel-tuning cells in
    ``repro.kernels.tuning`` (same store/engine machinery as sharding
    configs); ``interpret=None`` auto-selects interpret mode off-TPU, which
    is what makes the dispatch testable on CPU.
    """

    use_flash: bool = False          # Pallas flash_attention on train/prefill
    flash_block_q: int = 512
    flash_block_kv: int = 512
    use_decode: bool = False         # Pallas flash_decode on the serve hot path
    decode_block_kv: int = 512
    decode_num_splits: int = 1
    decode_combine: str = "jax"      # cross-split merge: "jax" | "kernel"
    interpret: Optional[bool] = None  # None = auto (interpret off-TPU)

    def replace(self, **kw) -> "KernelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ParallelConfig:
    """Distribution + performance knobs. Every field is BO-tunable."""

    param_rules: Mapping[str, Axis] = field(default_factory=lambda: dict(DEFAULT_PARAM_RULES))
    act_rules: Mapping[str, Axis] = field(default_factory=lambda: dict(DEFAULT_ACT_RULES))
    remat: str = "none"              # none | dots | full
    microbatches: int = 1
    attn_block_q: int = 1024         # flash q block
    attn_block_kv: int = 1024        # flash kv block
    attn_q_chunks: int = 1           # causal q-chunking (1 = off); saves ~(1-(c+1)/2c) attn FLOPs
    capacity_factor: Optional[float] = None  # override ArchConfig.moe
    logits_chunk: int = 1024         # chunked-softmax xent chunk (0 = unchunked)
    opt_moment_dtype: str = "float32"
    grad_compression: str = "none"   # none | topk | int8 (pod/DCN axis)
    grad_compression_topk: float = 0.05
    scan_layers: bool = True
    flash_threshold: int = 2048      # use blockwise attention when seq >= this
    # chunkwise-parallel mLSTM chunk length (0 = paper-faithful per-step scan)
    mlstm_chunk: int = 0
    mlstm_bf16_streams: bool = False  # bf16 intra-chunk streams (state fp32)
    moe_combine: str = "gather"       # gather | a2a (axis-swap reshard)
    # tuned Pallas-kernel dispatch; None = pure-JAX paths (byte-identical)
    kernel: Optional[KernelConfig] = None

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShardCtx:
    """Threaded through model code; mesh=None disables constraints (CPU smoke)."""

    mesh: Optional[Mesh]
    pcfg: ParallelConfig

    @property
    def axis_sizes(self) -> Dict[str, int]:
        if self.mesh is None:
            return {}
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))


def resolve_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
                 rules: Mapping[str, Axis], mesh: Mesh) -> PartitionSpec:
    """Map logical axes to a PartitionSpec, dropping invalid assignments."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        assign: Tuple[str, ...] = ()
        cand = rules.get(name) if name is not None else None
        if cand is not None:
            cand_t = (cand,) if isinstance(cand, str) else tuple(cand)
            picked = []
            prod = 1
            for ax in cand_t:
                if ax not in sizes or ax in used:
                    continue
                if dim % (prod * sizes[ax]) != 0:
                    continue
                picked.append(ax)
                prod *= sizes[ax]
            assign = tuple(picked)
            used.update(assign)
        if len(assign) == 0:
            out.append(None)
        elif len(assign) == 1:
            out.append(assign[0])
        else:
            out.append(assign)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def param_shardings(specs_tree: Any, mesh: Mesh, pcfg: ParallelConfig) -> Any:
    """NamedSharding tree matching a ParamSpec tree."""
    from repro.models.params import ParamSpec, is_spec

    def one(spec: ParamSpec) -> NamedSharding:
        return NamedSharding(mesh, resolve_spec(spec.shape, spec.logical,
                                                pcfg.param_rules, mesh))

    return jax.tree.map(one, specs_tree, is_leaf=is_spec)


def constrain(x: jax.Array, logical: Sequence[Optional[str]], px: ShardCtx) -> jax.Array:
    """with_sharding_constraint by logical activation axes (no-op off-mesh)."""
    if px.mesh is None:
        return x
    spec = resolve_spec(x.shape, logical, px.pcfg.act_rules, px.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(px.mesh, spec))


def act_sharding(shape: Sequence[int], logical: Sequence[Optional[str]],
                 mesh: Mesh, pcfg: ParallelConfig) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(shape, logical, pcfg.act_rules, mesh))


# ---------------------------------------------------------------------------
# kernel-residency arithmetic for HARD-constrained tuning grids
# ---------------------------------------------------------------------------
# Pure column arithmetic (ints or numpy arrays) so the same expressions work
# as vectorized ``VectorConstraint`` predicates over a GenerativeSpace's
# candidate columns (repro.core.tuning_targets.sharding_space(hard=True)).

#: per-core on-chip vector memory (v5e; matches launch/roofline.VMEM_BYTES)
VMEM_BYTES = 16 * 2 ** 20


def flash_vmem_bytes(block_q, block_kv, head_dim=128, *,
                     dtype_bytes=2, acc_bytes=4):
    """Per-grid-step VMEM residency of the blockwise flash-attention kernel:
    the bf16 Q/K/V tiles, the f32 logits tile, the f32 output accumulator,
    and the running max/denominator stats. Vectorizes over numpy columns."""
    q_tile = block_q * head_dim * dtype_bytes
    kv_tiles = 2 * block_kv * head_dim * dtype_bytes      # K and V
    logits = block_q * block_kv * acc_bytes
    acc = block_q * head_dim * acc_bytes
    stats = 2 * block_q * acc_bytes                       # rowmax + denom
    return q_tile + kv_tiles + logits + acc + stats


def attn_tile_occupancy(seq_len, block_q, block_kv, *, cores=8):
    """Grid steps per core of a (seq/block_q) x (seq/block_kv) attention
    tiling. Below 1.0 some cores idle every wave — the occupancy floor the
    hard grids enforce. Ceil-divides, so oversized blocks count as one."""
    q_steps = -(-seq_len // block_q)
    kv_steps = -(-seq_len // block_kv)
    return (q_steps * kv_steps) / cores
