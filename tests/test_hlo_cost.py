"""HLO cost analyzer: parsing robustness + trip-count correctness."""
import numpy as np
import pytest

from repro.launch.hlo_cost import (HloCostModel, analyze, parse_instr,
                                   shape_bytes, shape_elems, _groups_span_dcn)


def test_parse_instr_simple():
    ln = "  %dot.1 = f32[128,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}"
    name, rtype, op = parse_instr(ln)
    assert name == "%dot.1" and op == "dot"
    assert shape_elems(rtype) == 128 * 64


def test_parse_instr_tuple_with_comments():
    ln = ("  %while.1 = (s32[], bf16[2,3]{1,0}, /*index=2*/f32[4]{0}) "
          "while(%t), condition=%c, body=%b")
    name, rtype, op = parse_instr(ln)
    assert op == "while"
    assert shape_bytes(rtype) == 4 + 2 * 3 * 2 + 4 * 4


def test_parse_instr_root():
    ln = "  ROOT %add.3 = s32[] add(%x, %y)"
    assert parse_instr(ln)[2] == "add"


def test_shape_bytes_dtypes():
    assert shape_bytes("bf16[10,10]{1,0}") == 200
    assert shape_bytes("pred[8]{0}") == 8
    assert shape_bytes("f32[]") == 4


def test_dcn_group_detection_iota():
    ln = "x all-reduce(%a), replica_groups=[2,256]<=[512], other"
    assert _groups_span_dcn(ln, 256) is False      # groups of 256 consecutive
    ln2 = "x all-reduce(%a), replica_groups=[256,2]<=[2,256]T(1,0), other"
    assert _groups_span_dcn(ln2, 256) is True      # pairs straddle pods


def test_dcn_group_detection_list():
    assert _groups_span_dcn("replica_groups={{0,256},{1,257}} ", 256) is True
    assert _groups_span_dcn("replica_groups={{0,1},{2,3}} ", 256) is False


_HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %dot.1 = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %dot.1)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %while.1 = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%while.1), index=1
}
"""


def test_while_trip_count_multiplies_flops():
    res = analyze(_HLO)
    # one 8x8x8 dot (1024 flops) x 10 trips (+ small add flops)
    assert 10 * 1024 <= res["flops"] <= 10 * 1024 + 200


def test_collectives_inside_while_scale():
    hlo = _HLO.replace(
        "%dot.1 = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}",
        "%dot.1 = f32[8,8]{1,0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%c2")
    res = analyze(hlo)
    assert res["coll_bytes"] == 10 * 8 * 8 * 4


def test_scan_matches_unrolled_on_real_program():
    import jax, jax.numpy as jnp
    from jax import lax

    def scanned(x, w):
        y, _ = lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return y

    def unrolled(x, w):
        for i in range(6):
            x = x @ w[i]
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    fs = analyze(jax.jit(scanned).lower(x, w).compile().as_text())["flops"]
    fu = analyze(jax.jit(unrolled).lower(x, w).compile().as_text())["flops"]
    true = 6 * 2 * 64 ** 3
    assert abs(fs - true) / true < 0.2
    assert abs(fu - true) / true < 0.2
