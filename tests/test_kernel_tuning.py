"""Kernel-autotuning cells (DESIGN.md §14): invalid-config journaling, store
round-trip, warm-start reuse, serve-side resolution, compiled-kernel cache."""
import math
import os

import numpy as np
import pytest

from repro.kernels import tuning as kt
from repro.kernels.cache import CompiledKernelCache, config_key
from repro.store.records import TuningRecordStore


@pytest.fixture
def store(tmp_path):
    return TuningRecordStore(os.path.join(tmp_path, "store"))


def tiny_gp_cell():
    return kt.gp_cell(N=1024, T=128, d=8, t_obs=8)


# -- invalid-config semantics ------------------------------------------------

def test_over_vmem_config_is_nan_not_exception():
    cell = tiny_gp_cell()
    obj = kt.KernelObjective(cell, reps=1, vmem_bytes=1024)   # ~nothing fits
    for i in range(cell.space.size):
        assert math.isnan(obj(i))


def test_valid_config_measures_positive_time():
    cell = tiny_gp_cell()
    obj = kt.KernelObjective(cell, reps=1)
    v = obj(0)
    assert math.isfinite(v) and v > 0


def test_misaligned_flash_config_invalid():
    # S=256 cell: block 512 passes the space constraint of a bigger S but
    # not this cell's alignment check
    cell = kt.flash_cell(1, 256, 2, 64)
    obj = kt.KernelObjective(cell, reps=1)
    bad = {"block_q": 512, "block_kv": 512}
    assert not cell.valid(bad, obj.vmem_bytes)
    assert math.isnan(obj.eval_config(bad))


def test_invalid_configs_journaled_not_raised(store):
    """An over-VMEM config inside a tuning run lands in the store as a NaN
    record — the paper's invalid configuration — rather than killing the
    run; valid configs still win."""
    cell = tiny_gp_cell()
    # budget over the whole 4-config space; tiny vmem invalidates block>=512
    from repro.core.runner import run_strategy
    from repro.core.strategies.baselines import RandomSearch
    from repro.kernels import matern_gp as _mgp
    # enough for block_n<=256 at (T=128, d=8), not for 512
    budget_bytes = _mgp.gp_vmem_bytes(256, 128, 8) + 1
    obj = kt.KernelObjective(cell, reps=1, vmem_bytes=budget_bytes)
    res = run_strategy(RandomSearch(), obj, budget=cell.space.size,
                       seed=0, store=store, run_id="inv-test")
    recs = store.records()
    vals = {tuple(sorted(r.config.items())): r.value for r in recs
            if r.config is not None}
    assert any(math.isnan(v) for v in vals.values())      # invalid journaled
    assert any(math.isfinite(v) for v in vals.values())
    assert math.isfinite(res.best_value)
    best_cfg = cell.space.config(res.best_idx)
    assert cell.valid(best_cfg, budget_bytes)


# -- store round-trip / warm start ------------------------------------------

def test_tuning_journals_under_kernel_fingerprint(store):
    cell = tiny_gp_cell()
    kt.run_kernel_tuning(cell, store, budget=3, init=2, reps=1)
    descs = list(store.fingerprints().values())
    assert len(descs) == 1
    obj_id = descs[0].objective
    assert obj_id == cell.objective_id()
    assert obj_id.startswith("kernel[gp×") and obj_id.endswith(
        f"×{kt.device_kind()}]")


def test_best_kernel_config_resolution(store):
    cell = tiny_gp_cell()
    kt.run_kernel_tuning(cell, store, budget=3, init=2, reps=1)
    hit = kt.best_kernel_config(store, "gp", cell.shape_sig)
    assert hit is not None
    cfg, val = hit
    assert "block_n" in cfg and math.isfinite(val)
    # shape-relaxed lookup finds it too; wrong device does not
    assert kt.best_kernel_config(store, "gp") == hit
    assert kt.best_kernel_config(store, "gp", device="tpu") is None
    assert kt.best_kernel_config(store, "gemm") is None
    # path-based open + missing path
    assert kt.best_kernel_config(store.path, "gp") == hit
    assert kt.best_kernel_config("/nonexistent/store", "gp") is None


def test_warm_start_reuses_kernel_records(store):
    cell = tiny_gp_cell()
    kt.run_kernel_tuning(cell, store, budget=3, init=2, reps=1, seed=0)
    n0 = len(store.records())
    res = kt.run_kernel_tuning(cell, store, budget=2, init=1, reps=1, seed=1)
    # second run journals under the same fingerprint (warm-startable family)
    assert len(store.fingerprints()) == 1
    assert len(store.records()) > n0
    assert math.isfinite(res.best_value)


def test_tuned_gp_block_n(store):
    assert kt.tuned_gp_block_n(store, default=512) == 512      # cold store
    cell = tiny_gp_cell()
    kt.run_kernel_tuning(cell, store, budget=3, init=2, reps=1)
    bn = kt.tuned_gp_block_n(store)
    assert bn in (128, 256, 512, 1024)                         # N=1024 cell
    # N smaller than every stored block: fall back
    assert kt.tuned_gp_block_n(store, N=64) == 512


def test_kernel_config_from_store(store):
    assert kt.kernel_config_from_store(store, S=256, hd=64) is None
    cell = kt.flash_cell(1, 256, 2, 64)
    kt.run_kernel_tuning(cell, store, budget=3, init=2, reps=1)
    kc = kt.kernel_config_from_store(store, S=256, hd=64)
    assert kc is not None and kc.use_flash
    assert 256 % kc.flash_block_q == 0 and 256 % kc.flash_block_kv == 0
    # a sequence the tuned blocks don't tile -> stay pure-JAX
    assert kt.kernel_config_from_store(store, S=100, hd=64) is None


# -- compiled-kernel cache ---------------------------------------------------

def test_compiled_kernel_cache_hits_and_eviction():
    cache = CompiledKernelCache(max_entries=2)
    builds = []

    def make(v):
        def build():
            builds.append(v)
            return v
        return build

    assert cache.get(("a",), make(1)) == 1
    assert cache.get(("a",), make(99)) == 1          # hit: no rebuild
    assert builds == [1]
    assert cache.stats()["hits"] == 1
    cache.get(("b",), make(2))
    cache.get(("c",), make(3))                       # evicts LRU ("a")
    assert cache.stats()["evictions"] == 1
    assert ("a",) not in cache and ("c",) in cache
    n = cache.invalidate(lambda k: k == ("b",))
    assert n == 1 and len(cache) == 1


def test_config_key_canonical():
    assert config_key({"b": 2, "a": 1}) == config_key({"a": 1, "b": 2})
    assert config_key(None) == ()


def test_apply_kernel_config_overlay():
    from repro.parallel.sharding import ParallelConfig
    from repro.store.resolve import apply_kernel_config
    pcfg = ParallelConfig()
    assert pcfg.kernel is None
    out = apply_kernel_config(pcfg, {"block_q": 128, "block_kv": 256})
    assert out.kernel is not None and out.kernel.use_flash
    assert out.kernel.flash_block_q == 128
    assert out.kernel.flash_block_kv == 256
    # a gemm-cell config has no flash keys: untouched
    same = apply_kernel_config(pcfg, {"block_m": 64})
    assert same.kernel is None


# -- daemon-side cell-key parsing (launch/retune.py) -------------------------

def test_kernel_cell_keys_round_trip_to_objectives():
    """The retune daemon reconstructs the exact cell a server resolved
    blocks for, from nothing but the objective-id string in the ticket."""
    from repro.launch.retune import cell_objective_for, kernel_objective_for
    for cell in (kt.gemm_cell(512, 256, 128),
                 kt.flash_cell(2, 256, 4, 64),
                 kt.gp_cell(N=1024, T=128, d=8)):
        key = cell.objective_id("tpu")
        obj = cell_objective_for(key)
        assert isinstance(obj, kt.KernelObjective)
        assert obj.name == key, "re-tuned records land under the same id"
        assert obj.space.size == cell.space.size
        assert kernel_objective_for(key).name == key


def test_malformed_kernel_cell_keys_fail_loud():
    from repro.launch.retune import cell_objective_for, kernel_objective_for
    for bad in ("kernel[gemm×512x256×tpu]",          # malformed gemm sig
                "kernel[flash×512x256x128×tpu]",     # sig of the wrong cell
                "kernel[conv×1x2x3×tpu]",            # unknown kernel name
                "kernel[gemm×512x256x128]"):         # missing device field
        with pytest.raises(ValueError):
            kernel_objective_for(bad)
    with pytest.raises(ValueError):
        cell_objective_for("not-a-cell-key")


# -- decode cell (ISSUE 8) ---------------------------------------------------

def tiny_decode_cell(**kw):
    kw.setdefault("B", 1)
    kw.setdefault("S", 128)
    kw.setdefault("H", 4)
    kw.setdefault("KV", 2)
    kw.setdefault("hd", 16)
    return kt.decode_cell(**kw)


def test_decode_cell_invalid_configs_are_nan():
    """Both faces of the decode resource model journal as NaN: VMEM
    overflow, and split counts whose leading tiles overhang the cache."""
    cell = tiny_decode_cell()
    obj = kt.KernelObjective(cell, reps=1, vmem_bytes=64)     # nothing fits
    assert math.isnan(obj(0))
    obj = kt.KernelObjective(cell, reps=1)
    overhang = {"block_kv": 128, "num_splits": 4, "combine": "jax"}
    assert not cell.valid(overhang, obj.vmem_bytes)
    assert math.isnan(obj.eval_config(overhang))


def test_decode_cell_valid_config_measures_positive_time():
    cell = tiny_decode_cell()
    obj = kt.KernelObjective(cell, reps=1)
    v = obj.eval_config({"block_kv": 128, "num_splits": 1, "combine": "jax"})
    assert math.isfinite(v) and v > 0


def test_decode_cell_in_default_matrix():
    for smoke in (True, False):
        cells = kt.default_cells(smoke=smoke)
        assert [c.kernel for c in cells] == list(kt.KERNEL_NAMES)


def test_decode_kernel_config_from_store(store):
    from repro.parallel.sharding import KernelConfig
    cell = tiny_decode_cell()
    kt.run_kernel_tuning(cell, store, budget=4, init=2, reps=1, seed=0)
    kc = kt.decode_kernel_config_from_store(
        store, cache_cap=128, H=4, KV=2, hd=16)
    assert kc is not None and kc.use_decode
    assert kc.decode_block_kv * (kc.decode_num_splits - 1) < 128
    # overlay composes: flash fields of the base survive
    base = KernelConfig(use_flash=True, flash_block_q=128)
    kc2 = kt.decode_kernel_config_from_store(
        store, cache_cap=128, H=4, KV=2, hd=16, base=base)
    assert kc2.use_flash and kc2.flash_block_q == 128 and kc2.use_decode
    # a tiny cache no stored split config can cover resolves to None
    assert kt.decode_kernel_config_from_store(
        store, cache_cap=0, H=4, KV=2, hd=16) is None


def test_apply_kernel_config_decode_overlay():
    from repro.parallel.sharding import ParallelConfig
    from repro.store.resolve import apply_kernel_config
    pcfg = ParallelConfig()
    dec = {"block_kv": 256, "num_splits": 4, "combine": "kernel"}
    out = apply_kernel_config(pcfg, dec)
    assert out.kernel is not None and out.kernel.use_decode
    assert not out.kernel.use_flash
    assert out.kernel.decode_block_kv == 256
    assert out.kernel.decode_num_splits == 4
    assert out.kernel.decode_combine == "kernel"
    # decode overlay on a flash-enabled config keeps the flash blocks,
    # and a later flash overlay keeps the decode blocks (they compose)
    both = apply_kernel_config(
        apply_kernel_config(pcfg, {"block_q": 128, "block_kv": 128}), dec)
    assert both.kernel.use_flash and both.kernel.flash_block_kv == 128
    assert both.kernel.use_decode and both.kernel.decode_block_kv == 256
    back = apply_kernel_config(both, {"block_q": 256, "block_kv": 512})
    assert back.kernel.use_decode and back.kernel.decode_block_kv == 256
    assert back.kernel.flash_block_q == 256


def test_decode_cell_key_round_trips_to_objective():
    from repro.launch.retune import cell_objective_for
    cell = tiny_decode_cell()
    key = cell.objective_id("tpu")
    assert "kernel[decode×B1_S128_H4_KV2_hd16×tpu]" == key
    obj = cell_objective_for(key)
    assert isinstance(obj, kt.KernelObjective)
    assert obj.name == key
    assert obj.space.size == cell.space.size
