"""Ask/tell engine: golden sequential parity, batching, dedup, resume.

The golden traces in tests/golden/seed_traces.json were captured from the
pre-refactor blocking-loop implementation (seed commit) on the toy objective:
every strategy's full journal (key, value, af) for budget=40 at seeds 0/1.
``batch_size=1, workers=1`` must reproduce them bit-for-bit.
"""
import json
import math
import os
import time

import numpy as np
import pytest

from repro.core.engine import ParallelTuningEngine
from repro.core.gp import GP
from repro.core.gp_fast import IncrementalGP
from repro.core.objectives import Objective, SimulatedObjective
from repro.core.runner import run_strategy
from repro.core.searchspace import Param, SearchSpace
from repro.core.strategies import make_strategy
from repro.core.strategies.base import Proposal, Strategy, StrategyContext
from repro.store.records import TuningRecordStore

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "seed_traces.json")


def _toy_objective(seed=0, n=400, invalid_frac=0.2):
    """Must stay identical to the objective the golden traces were captured
    on (test_strategies._toy_objective at the seed commit)."""
    rng = np.random.default_rng(seed)
    space = SearchSpace([Param("a", tuple(range(20))),
                         Param("b", tuple(range(20)))], name="toy")
    x = space.X_norm
    times = 1.0 + 5 * ((x[:, 0] - 0.3) ** 2 + (x[:, 1] - 0.7) ** 2) \
        + 0.3 * np.sin(7 * x[:, 0]) * np.cos(5 * x[:, 1])
    inv = rng.choice(n, int(invalid_frac * n), replace=False)
    times = times.astype(np.float64)
    times[inv] = math.nan
    return SimulatedObjective(space, times, name="toy")


class SlowObjective(Objective):
    """Per-eval sleep: models the expensive compile-and-run step."""

    def __init__(self, inner: Objective, delay_s: float):
        self.inner, self.delay_s = inner, delay_s
        self.space, self.name = inner.space, "slow_" + inner.name

    def __call__(self, idx: int) -> float:
        time.sleep(self.delay_s)
        return self.inner(idx)


class DyingObjective(Objective):
    """Raises after k evaluations — simulates a run killed mid-batch."""

    def __init__(self, inner: Objective, k: int):
        self.inner, self.k, self.count = inner, k, 0
        self.space, self.name = inner.space, inner.name

    def __call__(self, idx: int) -> float:
        self.count += 1
        if self.count > self.k:
            raise RuntimeError("killed")
        return self.inner(idx)


# ---------------------------------------------------------------------------
# golden sequential parity (acceptance: batch_size=1 == seed sequential)
# ---------------------------------------------------------------------------
with open(GOLDEN) as f:
    _GOLDEN = json.load(f)


@pytest.mark.parametrize("case", sorted(_GOLDEN))
def test_batch1_reproduces_seed_sequential_exactly(case):
    strat, seed = case.rsplit(":", 1)
    res = run_strategy(make_strategy(strat), _toy_objective(), budget=40,
                       seed=int(seed))
    got = [[o.key, None if not math.isfinite(o.value) else o.value, o.af]
           for o in res.journal]
    assert got == _GOLDEN[case]["journal"], f"{case}: journal diverged"
    got_trace = [None if not math.isfinite(v) else v for v in res.trace]
    assert got_trace == _GOLDEN[case]["trace"], f"{case}: best_trace diverged"
    assert res.unique_evals == _GOLDEN[case]["unique_evals"]


# ---------------------------------------------------------------------------
# batching / parallelism
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strat", ["ei", "advanced_multi", "random",
                                   "genetic_algorithm"])
def test_batched_parallel_run_is_valid(strat):
    """workers>1 keeps every invariant: budget, unique journal keys, a best
    value no worse than random luck allows on this easy space."""
    obj = _toy_objective()
    res = run_strategy(make_strategy(strat), obj, budget=48, seed=0,
                       batch_size=8, workers=8)
    assert res.unique_evals <= 48
    keys = [o.key for o in res.journal]
    assert len(keys) == len(set(keys)), "re-evaluated a config"
    assert math.isfinite(res.best_value)
    assert len(res.worker_stats) > 1, "work never fanned out"


def test_bo_batch_suggest_distinct_and_rolled_back():
    """suggest(n) returns n distinct configs and leaves the GP untouched."""
    obj = _toy_objective()
    strat = make_strategy("ei")
    rng = np.random.default_rng(0)
    strat.reset(StrategyContext(space=obj.space, budget=40, rng=rng))
    # drive through init sequentially
    while True:
        props = strat.suggest(1)
        assert props, "init phase never ended"
        strat.observe(props[0], obj(props[0].idx))
        if strat._phase == "bo":
            break
    t_before = strat.gp.gp.t
    batch = strat.suggest(6)
    assert len(batch) == 6
    idxs = [p.idx for p in batch]
    assert len(set(idxs)) == 6, "constant-liar batch suggested duplicates"
    assert strat.gp.gp.t == t_before, "fantasy observations not rolled back"
    # async ask without tell: the next ask must avoid in-flight configs
    more = strat.suggest(4)
    assert not (set(p.idx for p in more) & set(idxs))


def test_throughput_workers_beat_sequential():
    """Sleep-dominated objective: 8 workers ≳ 4× faster than 1 (the engine
    acceptance bar; the full-size version lives in benchmarks/engine_bench)."""
    obj = SlowObjective(_toy_objective(), 0.01)
    t0 = time.time()
    r1 = run_strategy(make_strategy("random"), obj, budget=32, seed=0)
    t_seq = time.time() - t0
    t0 = time.time()
    r8 = run_strategy(make_strategy("random"), obj, budget=32, seed=0,
                      batch_size=8, workers=8)
    t_par = time.time() - t0
    assert r1.unique_evals == r8.unique_evals == 32
    assert [o.key for o in r1.journal] == [o.key for o in r8.journal]
    assert t_seq / t_par >= 2.5, f"only {t_seq / t_par:.1f}x"


def test_process_backend_matches_thread():
    obj = _toy_objective()   # picklable: no lambda restrictions
    res_p = run_strategy(make_strategy("random"), obj, budget=24, seed=0,
                         batch_size=8, workers=2, backend="process")
    res_s = run_strategy(make_strategy("random"), obj, budget=24, seed=0)
    assert [o.key for o in res_p.journal] == [o.key for o in res_s.journal]
    assert all(w.startswith("pid-") for w in res_p.worker_stats)


def test_max_in_flight_caps_concurrency():
    class Gauge(Objective):
        def __init__(self, inner):
            self.inner, self.space, self.name = inner, inner.space, inner.name
            self.live, self.peak = 0, 0
            import threading
            self.lock = threading.Lock()

        def __call__(self, idx):
            with self.lock:
                self.live += 1
                self.peak = max(self.peak, self.live)
            time.sleep(0.002)
            with self.lock:
                self.live -= 1
            return self.inner(idx)

    gauge = Gauge(_toy_objective())
    eng = ParallelTuningEngine(gauge, 32, batch_size=8, workers=8,
                               max_in_flight=3)
    eng.run(make_strategy("random"), seed=0)
    assert gauge.peak <= 3


def test_per_worker_budget_accounting():
    obj = SlowObjective(_toy_objective(), 0.003)
    res = run_strategy(make_strategy("random"), obj, budget=32, seed=0,
                       batch_size=8, workers=4)
    assert sum(w["n_evals"] for w in res.worker_stats.values()) == 32
    assert all(w["busy_s"] > 0 for w in res.worker_stats.values())
    assert all(o.dur > 0 for o in res.journal)


# ---------------------------------------------------------------------------
# checkpoint/resume mid-batch (acceptance: lossless with workers > 1)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strat", ["ei", "genetic_algorithm"])
def test_checkpoint_resume_mid_batch_with_workers(tmp_path, strat):
    obj = _toy_objective()
    ck = str(tmp_path / "ck.json")
    with pytest.raises(RuntimeError):
        run_strategy(make_strategy(strat), DyingObjective(obj, 17), budget=40,
                     seed=0, checkpoint_path=ck, batch_size=4, workers=4)
    recorded = TuningRecordStore(ck).records()
    assert 0 < len(recorded) <= 17, "journal not an evaluation-prefix"
    res = run_strategy(make_strategy(strat), obj, budget=40, seed=0,
                       checkpoint_path=ck, resume=True, batch_size=4,
                       workers=4)
    assert res.unique_evals == 40
    keys = [o.key for o in res.journal]
    assert len(keys) == len(set(keys)), "resume re-evaluated a config"
    # the checkpointed prefix survived verbatim
    assert [o.key for o in res.journal[:len(recorded)]] \
        == [r.key for r in recorded]


def test_journal_order_deterministic_under_parallelism():
    """Ordered journal writes: completion order may scramble, acceptance
    order may not."""
    obj = _toy_objective()
    runs = [run_strategy(make_strategy("random"), obj, budget=32, seed=0,
                         batch_size=8, workers=8) for _ in range(2)]
    assert [o.key for o in runs[0].journal] == [o.key for o in runs[1].journal]


# ---------------------------------------------------------------------------
# speculative GP add/rollback
# ---------------------------------------------------------------------------
def test_incremental_gp_rollback_exact():
    rng = np.random.default_rng(0)
    Xc = rng.random((80, 3))
    g = IncrementalGP(Xc, max_obs=16, ell=1.5)
    for i in range(5):
        g.add(Xc[i], float(rng.normal()))
    mu0, sd0 = g.predict()
    ssq0 = g.ssq.copy()
    g.mark()
    for i in range(5, 9):
        g.add(Xc[i], float(rng.normal()))
    assert g.t == 9
    g.rollback()
    assert g.t == 5
    mu1, sd1 = g.predict()
    np.testing.assert_array_equal(mu0, mu1)   # exact, not approximate
    np.testing.assert_array_equal(sd0, sd1)
    np.testing.assert_array_equal(ssq0, g.ssq)
    # the slot is reusable after rollback
    g.add(Xc[20], 1.0)
    assert g.t == 6


def test_jax_gp_rollback_exact():
    rng = np.random.default_rng(1)
    Xc = rng.random((40, 3)).astype(np.float32)
    g = GP(3, max_obs=16, ell=1.5)
    for i in range(4):
        g.add(Xc[i], float(rng.normal()))
    mu0, sd0 = g.predict(Xc)
    g.mark()
    g.add(Xc[10], 5.0)
    g.add(Xc[11], -5.0)
    g.rollback()
    assert g.n == 4
    mu1, sd1 = g.predict(Xc)
    np.testing.assert_array_equal(np.asarray(mu0), np.asarray(mu1))
    np.testing.assert_array_equal(np.asarray(sd0), np.asarray(sd1))


def test_rollback_without_mark_is_noop():
    rng = np.random.default_rng(2)
    Xc = rng.random((20, 2))
    g = IncrementalGP(Xc, max_obs=8, ell=2.0)
    g.add(Xc[0], 1.0)
    g.rollback()
    assert g.t == 1


# ---------------------------------------------------------------------------
# engine bookkeeping edge cases
# ---------------------------------------------------------------------------
def test_engine_stops_on_strategy_exhaustion():
    """Random search on a tiny space: strategy runs dry before the budget."""
    space = SearchSpace([Param("a", (1, 2, 3))], name="tiny")
    obj = SimulatedObjective(space, np.array([3.0, 1.0, 2.0]))
    res = run_strategy(make_strategy("random"), obj, budget=50, seed=0,
                       batch_size=4, workers=2)
    assert res.unique_evals == 3
    assert res.best_value == 1.0


def test_engine_budget_counts_in_flight():
    """Dispatching a full batch near the budget edge must not overshoot."""
    obj = SlowObjective(_toy_objective(), 0.002)
    res = run_strategy(make_strategy("random"), obj, budget=10, seed=0,
                       batch_size=8, workers=8)
    assert res.unique_evals == 10


def test_outside_space_proposals_consume_budget_in_engine():
    space = SearchSpace([Param("a", (1, 2, 4, 8)), Param("b", (1, 2, 4, 8))],
                        [lambda c: c["a"] * c["b"] <= 8], name="constrained")
    times = np.linspace(1, 2, space.size)
    obj = SimulatedObjective(space, times)
    res = run_strategy(make_strategy("bayesopt_ucb"), obj, budget=30, seed=0)
    outside = [o for o in res.journal if o.idx is None]
    assert len(outside) > 0
    assert all(not math.isfinite(o.value) for o in outside)
