"""GP surrogate: closed-form checks, engine equivalence, invariants."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from repro.core.gp import GP, gp_fit, gp_predict, kernel_fn
from repro.core.gp_fast import IncrementalGP, kernel_np


def test_matern_kernels_at_zero_and_decay():
    r = jnp.asarray([0.0, 0.5, 1.0, 5.0])
    for name in ("matern12", "matern32", "matern52", "rbf"):
        k = np.asarray(kernel_fn(name, r, 2.0))
        assert np.isclose(k[0], 1.0)
        assert np.all(np.diff(k) < 0), name      # monotone decreasing
        assert np.all(k > 0)


def test_matern_np_matches_jax():
    r = np.linspace(0, 4, 50)
    for name in ("matern12", "matern32", "matern52", "rbf"):
        np.testing.assert_allclose(kernel_np(name, r, 1.7),
                                   np.asarray(kernel_fn(name, jnp.asarray(r), 1.7)),
                                   rtol=1e-6)


def _closed_form(X, y, Xc, ell, noise=1e-6):
    """Dense float64 reference posterior."""
    def k(A, B):
        r = np.sqrt(np.maximum(
            (A * A).sum(1)[:, None] + (B * B).sum(1)[None] - 2 * A @ B.T, 0))
        return kernel_np("matern32", r, ell)
    ym, ys = y.mean(), max(y.std(), 1e-12)
    yc = (y - ym) / ys
    K = k(X, X) + noise * np.eye(len(X))
    Ks = k(Xc, X)
    Kinv = np.linalg.inv(K)
    mu = ym + ys * (Ks @ Kinv @ yc)
    var = 1.0 - np.einsum("ij,jk,ik->i", Ks, Kinv, Ks)
    return mu, np.sqrt(np.maximum(var, 1e-12)) * ys


def _rand_problem(seed, n_obs=15, n_cand=100, d=4):
    rng = np.random.default_rng(seed)
    X = rng.random((n_obs, d))
    y = rng.normal(3.0, 1.5, n_obs)
    Xc = rng.random((n_cand, d))
    return X.astype(np.float32), y.astype(np.float64), Xc.astype(np.float32)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_jax_gp_matches_closed_form(seed):
    X, y, Xc = _rand_problem(seed)
    g = GP(X.shape[1], max_obs=32, kernel="matern32", ell=2.0)
    for x, yy in zip(X, y):
        g.add(x, float(yy))
    mu, sd = g.predict(Xc)
    mu_ref, sd_ref = _closed_form(X.astype(np.float64), y, Xc.astype(np.float64), 2.0)
    np.testing.assert_allclose(np.asarray(mu), mu_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sd), sd_ref, rtol=5e-2, atol=2e-3)


@pytest.mark.parametrize("seed", [3, 4])
def test_incremental_matches_closed_form(seed):
    X, y, Xc = _rand_problem(seed)
    g = IncrementalGP(Xc, max_obs=32, kernel="matern32", ell=2.0)
    for x, yy in zip(X, y):
        g.add(x, float(yy))
    mu, sd = g.predict()
    mu_ref, sd_ref = _closed_form(X.astype(np.float64), y, Xc.astype(np.float64), 2.0)
    np.testing.assert_allclose(mu, mu_ref, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(sd, sd_ref, rtol=1e-5, atol=1e-6)


def test_engines_equivalent_incrementally():
    rng = np.random.default_rng(7)
    Xc = rng.random((200, 5)).astype(np.float32)
    g1 = GP(5, max_obs=24, ell=1.5)
    g2 = IncrementalGP(Xc, max_obs=24, ell=1.5)
    for i in range(20):
        x = Xc[rng.integers(200)]
        yv = float(rng.normal(10, 2))
        g1.add(x, yv)
        g2.add(x, yv)
        if i % 5 == 4:
            m1, s1 = g1.predict(Xc)
            m2, s2 = g2.predict()
            np.testing.assert_allclose(np.asarray(m1), m2, rtol=2e-3, atol=2e-3)
            np.testing.assert_allclose(np.asarray(s1), s2, rtol=2e-2, atol=2e-3)


def test_gp_interpolates_observations():
    """With tiny noise the posterior mean passes through the data and the
    posterior std collapses there."""
    X, y, _ = _rand_problem(11, n_obs=10)
    g = IncrementalGP(X, max_obs=16, ell=2.0, noise=1e-8)
    for x, yy in zip(X, y):
        g.add(x, float(yy))
    mu, sd = g.predict()
    np.testing.assert_allclose(mu, y, rtol=1e-4, atol=1e-4)
    assert sd.max() < 1e-2 * max(y.std(), 1.0)


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_prop_posterior_variance_bounds(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 20))
    Xc = rng.random((50, 3)).astype(np.float32)
    g = IncrementalGP(Xc, max_obs=24, ell=float(rng.uniform(0.5, 3.0)))
    for _ in range(n):
        g.add(rng.random(3), float(rng.normal()))
    _, sd = g.predict()
    assert np.all(sd >= 0)
    assert np.all(sd <= 1.05 * g.y_std + 1e-6)  # prior variance bound


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_prop_variance_shrinks_with_observations(seed):
    rng = np.random.default_rng(seed)
    Xc = rng.random((60, 3)).astype(np.float32)
    g = IncrementalGP(Xc, max_obs=24, ell=2.0)
    g.add(rng.random(3), 1.0)
    _, sd1 = g.predict()
    for _ in range(8):
        g.add(rng.random(3), float(rng.normal(1.0, 0.1)))
    _, sd2 = g.predict()
    # normalized (unit-prior) variance is monotone non-increasing in data
    assert np.all(sd2 / g.y_std <= sd1 / 1.0 + 1e-5)
