"""End-to-end behaviour tests for the paper's system.

The paper's headline claims, verified on our regenerated search spaces:
  1. the BO strategies reliably find near-optimal configurations;
  2. they beat the best non-BO Kernel Tuner strategy (GA) in MDF;
  3. invalid-heavy spaces are handled (ExpDist, 50.8% invalid);
  4. the whole tuning pipeline survives kill/resume (simulation mode).
"""
import math

import numpy as np
import pytest

# full-budget end-to-end runs: the nightly tier (PR CI runs -m "not slow")
pytestmark = pytest.mark.slow

from repro.core.metrics import mae, mdf_table
from repro.core.runner import run_strategy
from repro.core.spaces import make_objective
from repro.core.strategies import make_strategy


@pytest.mark.slow
def test_bo_near_optimal_on_gemm():
    obj = make_objective("gemm", "gtx_titan_x")
    res = run_strategy(make_strategy("advanced_multi"), obj, budget=220, seed=0)
    assert res.best_value <= obj.optimum * 1.05


@pytest.mark.slow
def test_paper_claim_bo_beats_ga_in_mdf():
    """advanced multi < GA and < random in MDF over two kernels, 3 seeds."""
    per_kernel = {}
    for kernel in ("pnpoly", "adding"):
        obj = make_objective(kernel, "gtx_titan_x")
        maes = {}
        for strat in ("advanced_multi", "genetic_algorithm", "random"):
            vals = [mae(run_strategy(make_strategy(strat), obj, budget=220,
                                     seed=s).trace, obj.optimum)
                    for s in range(3)]
            maes[strat] = float(np.mean(vals))
        per_kernel[kernel] = maes
    t = mdf_table(per_kernel)
    assert t["advanced_multi"]["mdf"] < t["genetic_algorithm"]["mdf"]
    assert t["advanced_multi"]["mdf"] < t["random"]["mdf"]


@pytest.mark.slow
def test_invalid_heavy_space_handled():
    """ExpDist is 50.8% invalid — BO must still optimize (paper §IV-E)."""
    obj = make_objective("expdist", "a100")
    res = run_strategy(make_strategy("multi"), obj, budget=220, seed=0)
    assert math.isfinite(res.best_value)
    assert res.best_value <= obj.optimum * 1.5
    n_invalid_seen = sum(1 for o in res.journal if not math.isfinite(o.value))
    assert n_invalid_seen > 0          # it did encounter invalids


def test_tuner_kill_resume_equivalence(tmp_path):
    """A tuning run killed at 50 evals and resumed keeps every earlier
    observation (fault tolerance of the tuner itself)."""
    obj = make_objective("adding", "gtx_titan_x")
    ck = str(tmp_path / "t.json")
    r1 = run_strategy(make_strategy("ei"), obj, budget=50, seed=3,
                      checkpoint_path=ck)
    r2 = run_strategy(make_strategy("ei"), obj, budget=100, seed=3,
                      checkpoint_path=ck, resume=True)
    keys1 = [o.key for o in r1.journal]
    keys2 = [o.key for o in r2.journal]
    assert keys2[:len(keys1)] == keys1
    assert r2.best_value <= r1.best_value
