"""Concurrent store access: a tail-following reader vs a per-record-flushing
writer.

The contract under test (ISSUE 4 satellite): however polls interleave with
appends, ``StoreWatcher`` delivers every record EXACTLY ONCE, IN WRITE
ORDER — including when the reader observes a torn (partially written) final
line, and across a segment rollover (writer close + reopen). The
deterministic cases pin the edges; the hypothesis property drives randomized
interleavings of {write, poll, rollover} over both store layouts.
"""
import json
import os
import tempfile

import pytest

from repro.core.searchspace import Param, SearchSpace
from repro.store import (SpaceFingerprint, StoreWatcher, TuningRecord,
                         TuningRecordStore)

SPACE = SearchSpace([Param("a", (0, 1, 2, 3)), Param("b", (0, 1, 2))],
                    name="cc")
FP = SpaceFingerprint.of(SPACE, objective="cc@sim")


def _rec(seq: int) -> TuningRecord:
    idx = seq % SPACE.size
    return TuningRecord(fp=FP.digest, run="w", seq=seq, key=str(seq),
                        idx=idx, value=1.0 + 0.01 * seq,
                        config=SPACE.config(idx))


def _drain(watcher: StoreWatcher):
    return [int(r.key) for r in watcher.poll()]


@pytest.mark.parametrize("layout", ["dir", "single"])
def test_reader_sees_interleaved_appends_once_in_order(tmp_path, layout):
    path = str(tmp_path / ("store" if layout == "dir" else "store.jsonl"))
    watcher = StoreWatcher(path)        # watching before the store exists
    assert watcher.poll() == []
    store = TuningRecordStore(path)
    seen = []
    n = 0
    for burst in (1, 3, 1, 5, 2):
        for _ in range(burst):
            store.append(_rec(n), fingerprint=FP)
            n += 1
        seen += _drain(watcher)
    assert seen == list(range(n))
    assert _drain(watcher) == []        # nothing re-delivered


@pytest.mark.parametrize("layout", ["dir", "single"])
def test_torn_final_line_held_until_completed(tmp_path, layout):
    path = str(tmp_path / ("store" if layout == "dir" else "store.jsonl"))
    store = TuningRecordStore(path)
    store.append(_rec(0), fingerprint=FP)
    store.close()
    seg = path if layout == "single" else os.path.join(
        path, os.listdir(path)[0])

    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0]
    line = json.dumps(_rec(1).to_json()) + "\n"
    with open(seg, "ab") as f:          # a mid-flush / killed writer
        f.write(line[:len(line) // 2].encode())
        f.flush()
        assert _drain(watcher) == [], "torn line must not be delivered"
        f.write(line[len(line) // 2:].encode())
    assert _drain(watcher) == [1], "completed line delivered exactly once"
    assert _drain(watcher) == []


def test_rollover_preserves_order_past_ten_segments(tmp_path):
    """Lexicographic segment order breaks at rollover #10 (``-10`` sorts
    before ``-2``); the watcher must follow numeric rollover order."""
    path = str(tmp_path / "store")
    watcher = StoreWatcher(path)
    store = TuningRecordStore(path)
    for seq in range(12):               # 12 segments: one record each
        store.append(_rec(seq), fingerprint=FP)
        store.close()
    assert len(os.listdir(path)) == 12
    assert _drain(watcher) == list(range(12))


def test_torn_line_across_rollover(tmp_path):
    """A killed writer's torn tail in an old segment never blocks delivery
    from the successor segment — and never resurfaces."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    store.append(_rec(0), fingerprint=FP)
    store.close()
    seg0 = os.path.join(path, os.listdir(path)[0])
    with open(seg0, "ab") as f:
        f.write(b'{"kind": "obs", "fp": "dead')    # killed mid-record
    store = TuningRecordStore(path)                # new writer, new segment
    store.append(_rec(1), fingerprint=FP)
    store.close()

    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1]
    assert _drain(watcher) == []


# ---------------------------------------------------------------------------
# randomized interleavings (hypothesis) — guarded import, NOT importorskip:
# the deterministic edge-case tests above must run even without hypothesis
# ---------------------------------------------------------------------------
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(st.sampled_from(["write", "poll", "rollover"]),
                        min_size=1, max_size=40),
           layout=st.sampled_from(["dir", "single"]))
    def test_any_interleaving_delivers_every_record_once_in_order(ops,
                                                                  layout):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d,
                                "store" if layout == "dir" else "store.jsonl")
            store = TuningRecordStore(path)
            watcher = StoreWatcher(path)
            written, seen = 0, []
            for op in ops:
                if op == "write":
                    store.append(_rec(written), fingerprint=FP)
                    written += 1
                elif op == "poll":
                    seen += _drain(watcher)
                else:                    # rollover: writer restarts
                    store.close()
                    if layout == "dir":  # a single file IS one segment
                        store = TuningRecordStore(path)
            seen += _drain(watcher)
            assert seen == list(range(written))
            assert _drain(watcher) == []
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_any_interleaving_delivers_every_record_once_in_order():
        pass
