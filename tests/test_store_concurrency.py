"""Concurrent store access: a tail-following reader vs a per-record-flushing
writer — now with compaction rewriting segments underneath both.

The contract under test (ISSUE 4 satellite, extended by ISSUE 5): however
polls interleave with appends, ``StoreWatcher`` delivers every record
EXACTLY ONCE, IN WRITE ORDER — including when the reader observes a torn
(partially written) final line, across a segment rollover (writer close +
reopen), and across a ``compact_store`` rewrite-and-swap that folds sealed
segments mid-tail. The sidecar index must survive the same traffic: a
stale index (segments rewritten under it) rebuilds, a torn index write is
treated as missing, and appends past the indexed frontier are picked up by
the tail scan. The deterministic cases pin the edges; the hypothesis
property drives randomized interleavings of {write, poll, rollover,
compact}.
"""
import json
import os
import tempfile

import pytest

from repro.core.searchspace import Param, SearchSpace
from repro.store import (SpaceFingerprint, StoreWatcher, TuningRecord,
                         TuningRecordStore, compact_store, index_path,
                         load_index)

SPACE = SearchSpace([Param("a", (0, 1, 2, 3)), Param("b", (0, 1, 2))],
                    name="cc")
FP = SpaceFingerprint.of(SPACE, objective="cc@sim")


def _rec(seq: int) -> TuningRecord:
    idx = seq % SPACE.size
    return TuningRecord(fp=FP.digest, run="w", seq=seq, key=str(seq),
                        idx=idx, value=1.0 + 0.01 * seq,
                        config=SPACE.config(idx))


def _drain(watcher: StoreWatcher):
    return [int(r.key) for r in watcher.poll()]


@pytest.mark.parametrize("layout", ["dir", "single"])
def test_reader_sees_interleaved_appends_once_in_order(tmp_path, layout):
    path = str(tmp_path / ("store" if layout == "dir" else "store.jsonl"))
    watcher = StoreWatcher(path)        # watching before the store exists
    assert watcher.poll() == []
    store = TuningRecordStore(path)
    seen = []
    n = 0
    for burst in (1, 3, 1, 5, 2):
        for _ in range(burst):
            store.append(_rec(n), fingerprint=FP)
            n += 1
        seen += _drain(watcher)
    assert seen == list(range(n))
    assert _drain(watcher) == []        # nothing re-delivered


@pytest.mark.parametrize("layout", ["dir", "single"])
def test_torn_final_line_held_until_completed(tmp_path, layout):
    path = str(tmp_path / ("store" if layout == "dir" else "store.jsonl"))
    store = TuningRecordStore(path)
    store.append(_rec(0), fingerprint=FP)
    store.close()
    seg = path if layout == "single" else os.path.join(
        path, os.listdir(path)[0])

    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0]
    line = json.dumps(_rec(1).to_json()) + "\n"
    with open(seg, "ab") as f:          # a mid-flush / killed writer
        f.write(line[:len(line) // 2].encode())
        f.flush()
        assert _drain(watcher) == [], "torn line must not be delivered"
        f.write(line[len(line) // 2:].encode())
    assert _drain(watcher) == [1], "completed line delivered exactly once"
    assert _drain(watcher) == []


def test_rollover_preserves_order_past_ten_segments(tmp_path):
    """Lexicographic segment order breaks at rollover #10 (``-10`` sorts
    before ``-2``); the watcher must follow numeric rollover order."""
    path = str(tmp_path / "store")
    watcher = StoreWatcher(path)
    store = TuningRecordStore(path)
    for seq in range(12):               # 12 segments: one record each
        store.append(_rec(seq), fingerprint=FP)
        store.close()
    assert len(os.listdir(path)) == 12
    assert _drain(watcher) == list(range(12))


def test_torn_line_across_rollover(tmp_path):
    """A killed writer's torn tail in an old segment never blocks delivery
    from the successor segment — and never resurfaces."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    store.append(_rec(0), fingerprint=FP)
    store.close()
    seg0 = os.path.join(path, os.listdir(path)[0])
    with open(seg0, "ab") as f:
        f.write(b'{"kind": "obs", "fp": "dead')    # killed mid-record
    store = TuningRecordStore(path)                # new writer, new segment
    store.append(_rec(1), fingerprint=FP)
    store.close()

    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1]
    assert _drain(watcher) == []


# ---------------------------------------------------------------------------
# compaction vs a live tail (ISSUE 5)
# ---------------------------------------------------------------------------
def test_compaction_mid_tail_delivers_unconsumed_exactly_once(tmp_path):
    """The core swap contract: a watcher that consumed some sealed segments
    and never touched others must, after compaction folds them all into one
    ``segment-0-*`` file, receive exactly the records it had NOT yet seen —
    in write order, nothing twice."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    for seq in range(3):
        store.append(_rec(seq), fingerprint=FP)
    store.close()
    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1, 2]       # segment 0: fully consumed
    store = TuningRecordStore(path)
    for seq in range(3, 6):
        store.append(_rec(seq), fingerprint=FP)
    store.close()                              # segment 1: never polled
    store = TuningRecordStore(path)
    store.append(_rec(6), fingerprint=FP)      # segment 2: active writer

    stats = compact_store(path)
    assert stats.folded and len(stats.sources) == 2
    assert _drain(watcher) == [3, 4, 5, 6], \
        "exactly the unconsumed records, oldest first"
    assert _drain(watcher) == []
    store.append(_rec(7), fingerprint=FP)      # the live tail keeps working
    assert _drain(watcher) == [7]
    # a fresh reader sees one copy of everything, in order
    assert _drain(StoreWatcher(path)) == list(range(8))
    assert [int(r.key) for r in TuningRecordStore(path).records()] \
        == list(range(8))


def test_compaction_mid_segment_consumption(tmp_path):
    """Partial consumption WITHIN one sealed segment: the watcher polled
    half its records before the writer rolled over and compaction folded
    it — the compacted copy must resume at the exact line the tail left."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    for seq in range(2):
        store.append(_rec(seq), fingerprint=FP)
    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1]           # mid-segment tail position
    for seq in range(2, 5):
        store.append(_rec(seq), fingerprint=FP)
    store.close()
    store = TuningRecordStore(path)
    store.append(_rec(5), fingerprint=FP)      # seals segment 0
    compact_store(path)
    assert _drain(watcher) == [2, 3, 4, 5]
    assert _drain(watcher) == []


def test_compaction_racing_appender_loses_nothing(tmp_path):
    """An appender holding its segment open across a compaction keeps
    appending into the same (untouched) file: compaction only folds sealed
    segments, and the appender's numbering never reuses a folded name."""
    path = str(tmp_path / "store")
    old = TuningRecordStore(path)
    for seq in range(3):
        old.append(_rec(seq), fingerprint=FP)
    old.close()
    live = TuningRecordStore(path)
    live.append(_rec(3), fingerprint=FP)       # live handle, active segment
    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1, 2, 3]
    compact_store(path)
    live.append(_rec(4), fingerprint=FP)       # racing append, same handle
    live.append(_rec(5), fingerprint=FP)
    assert _drain(watcher) == [4, 5]
    live.close()
    # rollover after compaction: the new segment's name must sort after the
    # folded ones (numbering restarts past the compaction high water)
    relay = TuningRecordStore(path)
    relay.append(_rec(6), fingerprint=FP)
    relay.close()
    assert _drain(watcher) == [6]
    assert _drain(StoreWatcher(path)) == list(range(7))


def test_from_start_false_watcher_across_compaction(tmp_path):
    """An opened-at-end watcher must treat pre-open history as consumed and
    post-open appends as deliverable — including when compaction folds the
    segment before the watcher's next poll (byte-offset provenance: the
    open-time size IS the consumed frontier)."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    for seq in range(3):
        store.append(_rec(seq), fingerprint=FP)      # pre-open history
    watcher = StoreWatcher(path, from_start=False)
    for seq in range(3, 5):
        store.append(_rec(seq), fingerprint=FP)      # post-open, unpolled
    store.close()
    store = TuningRecordStore(path)
    store.append(_rec(5), fingerprint=FP)            # seals segment 0
    compact_store(path)
    assert _drain(watcher) == [3, 4, 5], \
        "history skipped, post-open appends survive the fold"
    assert _drain(watcher) == []


def test_double_compaction_chains_provenance(tmp_path):
    """Folding a compacted segment again re-stamps provenance one level at
    a time; a tail that consumed generation 1 must not see its records
    resurface from generation 2."""
    path = str(tmp_path / "store")
    for seq in range(2):
        store = TuningRecordStore(path)
        store.append(_rec(seq), fingerprint=FP)
        store.close()
    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1]
    compact_store(path)                        # gen 1 folds both
    assert _drain(watcher) == []
    store = TuningRecordStore(path)
    store.append(_rec(2), fingerprint=FP)
    store.close()
    assert _drain(watcher) == [2]
    compact_store(path)                        # gen 2 folds gen 1 + segment
    assert _drain(watcher) == []
    assert _drain(StoreWatcher(path)) == [0, 1, 2]


# ---------------------------------------------------------------------------
# sidecar index under concurrent traffic (ISSUE 5)
# ---------------------------------------------------------------------------
def _store_view(store: TuningRecordStore):
    return ([r.to_json() for r in store.records(fp=FP.digest)],
            None if store.best(FP.digest) is None
            else store.best(FP.digest).to_json())


def test_stale_index_rebuilt_when_segments_rewritten(tmp_path):
    """An index referencing a segment that shrank or vanished (a rewrite it
    never saw) is discarded and rebuilt — results match a full load."""
    path = str(tmp_path / "store")
    for seq in range(4):
        store = TuningRecordStore(path)
        store.append(_rec(seq), fingerprint=FP)
        store.close()
    TuningRecordStore(path, lazy=True)         # writes the sidecar
    doomed = [f for f in sorted(os.listdir(path)) if f.endswith(".jsonl")][0]
    os.remove(os.path.join(path, doomed))      # rewrite the index missed
    lazy = TuningRecordStore(path, lazy=True)
    assert _store_view(lazy) == _store_view(TuningRecordStore(path))
    fresh = load_index(path)                   # sidecar was repaired too
    assert fresh is not None and doomed not in fresh.segments


def test_torn_index_write_treated_as_missing(tmp_path):
    """A torn (partially written) sidecar must never poison an open: it
    reads as missing, the index rebuilds, results match a full load."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    for seq in range(5):
        store.append(_rec(seq), fingerprint=FP)
    store.close()
    TuningRecordStore(path, lazy=True)
    idx_file = index_path(path)
    blob = open(idx_file, "rb").read()
    with open(idx_file, "wb") as f:            # killed mid-write
        f.write(blob[:len(blob) // 2])
    assert load_index(path) is None
    lazy = TuningRecordStore(path, lazy=True)
    assert _store_view(lazy) == _store_view(TuningRecordStore(path))
    assert load_index(path) is not None


def test_outdated_index_tail_scan_picks_up_appends(tmp_path):
    """Appends past the indexed frontier (grown segment AND brand-new
    segment) are NOT staleness — the lazy open scans only those bytes."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    for seq in range(3):
        store.append(_rec(seq), fingerprint=FP)
    store.close()
    TuningRecordStore(path, lazy=True)         # index frontier: 3 records
    store = TuningRecordStore(path)            # new segment
    store.append(_rec(3), fingerprint=FP)
    store.close()
    lazy = TuningRecordStore(path, lazy=True)
    assert len(lazy.records(fp=FP.digest)) == 4
    assert _store_view(lazy) == _store_view(TuningRecordStore(path))


# ---------------------------------------------------------------------------
# randomized interleavings (hypothesis) — guarded import, NOT importorskip:
# the deterministic edge-case tests above must run even without hypothesis
# ---------------------------------------------------------------------------
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(st.sampled_from(["write", "poll", "rollover"]),
                        min_size=1, max_size=40),
           layout=st.sampled_from(["dir", "single"]))
    def test_any_interleaving_delivers_every_record_once_in_order(ops,
                                                                  layout):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d,
                                "store" if layout == "dir" else "store.jsonl")
            store = TuningRecordStore(path)
            watcher = StoreWatcher(path)
            written, seen = 0, []
            for op in ops:
                if op == "write":
                    store.append(_rec(written), fingerprint=FP)
                    written += 1
                elif op == "poll":
                    seen += _drain(watcher)
                else:                    # rollover: writer restarts
                    store.close()
                    if layout == "dir":  # a single file IS one segment
                        store = TuningRecordStore(path)
            seen += _drain(watcher)
            assert seen == list(range(written))
            assert _drain(watcher) == []

    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(st.sampled_from(["write", "poll", "rollover",
                                         "compact"]),
                        min_size=1, max_size=40))
    def test_any_schedule_with_compaction_is_exactly_once_in_order(ops):
        """ISSUE 5 acceptance property: however compaction interleaves with
        appends, rollovers, and polls, the tail delivers every record
        exactly once in write order, and a fresh full load agrees."""
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "store")
            store = TuningRecordStore(path)
            watcher = StoreWatcher(path)
            written, seen = 0, []
            for op in ops:
                if op == "write":
                    store.append(_rec(written), fingerprint=FP)
                    written += 1
                elif op == "poll":
                    seen += _drain(watcher)
                elif op == "rollover":
                    store.close()
                    store = TuningRecordStore(path)
                else:
                    compact_store(path)      # retention off: pure folding
            seen += _drain(watcher)
            assert seen == list(range(written))
            assert _drain(watcher) == []
            assert [int(r.key)
                    for r in TuningRecordStore(path).records()] \
                == list(range(written))
            lazy = TuningRecordStore(path, lazy=True)
            assert [int(r.key) for r in lazy.records(fp=FP.digest)] \
                == list(range(written))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_any_interleaving_delivers_every_record_once_in_order():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_any_schedule_with_compaction_is_exactly_once_in_order():
        pass
