"""Concurrent store access: a tail-following reader vs a per-record-flushing
writer — now with compaction rewriting segments underneath both, and a
FLEET of daemons racing over the fenced tuning-job queue (ISSUE 9).

The contracts under test:

  * (ISSUE 4/5) however polls interleave with appends, ``StoreWatcher``
    delivers every record EXACTLY ONCE, IN WRITE ORDER — across torn final
    lines, segment rollover, and ``compact_store`` rewrite-and-swaps; the
    sidecar index survives the same traffic;
  * (ISSUE 9) however N daemons' {submit, claim, service, die, compact}
    schedules interleave, ``TuningJobQueue`` grants each job's lease to at
    most one live claimant at a time (fencing tokens), a superseded
    claimant's ``done`` is refused at the API (``FencedClaimError``) AND at
    the fold, lease expiry is judged on each reader's own clock (immune to
    cross-machine skew in the writer stamps), and the compactor lock admits
    one compactor at a time.

The deterministic cases pin the edges; the hypothesis properties drive
randomized interleavings, plus a 600-schedule seeded sweep of the fleet
property (the ISSUE 9 bar).
"""
import json
import os
import random
import tempfile

import pytest

from repro.core.searchspace import Param, SearchSpace
from repro.store import (JOB_TYPES, CompactionLocked, FencedClaimError,
                         FenceRegistry, SpaceFingerprint, StoreWatcher,
                         TuningJobQueue, TuningRecord, TuningRecordStore,
                         compact_store, index_path, load_index)
from repro.store.compact import COMPACT_LOCK_KEY

SPACE = SearchSpace([Param("a", (0, 1, 2, 3)), Param("b", (0, 1, 2))],
                    name="cc")
FP = SpaceFingerprint.of(SPACE, objective="cc@sim")


def _rec(seq: int) -> TuningRecord:
    idx = seq % SPACE.size
    return TuningRecord(fp=FP.digest, run="w", seq=seq, key=str(seq),
                        idx=idx, value=1.0 + 0.01 * seq,
                        config=SPACE.config(idx))


def _drain(watcher: StoreWatcher):
    return [int(r.key) for r in watcher.poll()]


@pytest.mark.parametrize("layout", ["dir", "single"])
def test_reader_sees_interleaved_appends_once_in_order(tmp_path, layout):
    path = str(tmp_path / ("store" if layout == "dir" else "store.jsonl"))
    watcher = StoreWatcher(path)        # watching before the store exists
    assert watcher.poll() == []
    store = TuningRecordStore(path)
    seen = []
    n = 0
    for burst in (1, 3, 1, 5, 2):
        for _ in range(burst):
            store.append(_rec(n), fingerprint=FP)
            n += 1
        seen += _drain(watcher)
    assert seen == list(range(n))
    assert _drain(watcher) == []        # nothing re-delivered


@pytest.mark.parametrize("layout", ["dir", "single"])
def test_torn_final_line_held_until_completed(tmp_path, layout):
    path = str(tmp_path / ("store" if layout == "dir" else "store.jsonl"))
    store = TuningRecordStore(path)
    store.append(_rec(0), fingerprint=FP)
    store.close()
    seg = path if layout == "single" else os.path.join(
        path, os.listdir(path)[0])

    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0]
    line = json.dumps(_rec(1).to_json()) + "\n"
    with open(seg, "ab") as f:          # a mid-flush / killed writer
        f.write(line[:len(line) // 2].encode())
        f.flush()
        assert _drain(watcher) == [], "torn line must not be delivered"
        f.write(line[len(line) // 2:].encode())
    assert _drain(watcher) == [1], "completed line delivered exactly once"
    assert _drain(watcher) == []


def test_rollover_preserves_order_past_ten_segments(tmp_path):
    """Lexicographic segment order breaks at rollover #10 (``-10`` sorts
    before ``-2``); the watcher must follow numeric rollover order."""
    path = str(tmp_path / "store")
    watcher = StoreWatcher(path)
    store = TuningRecordStore(path)
    for seq in range(12):               # 12 segments: one record each
        store.append(_rec(seq), fingerprint=FP)
        store.close()
    assert len(os.listdir(path)) == 12
    assert _drain(watcher) == list(range(12))


def test_torn_line_across_rollover(tmp_path):
    """A killed writer's torn tail in an old segment never blocks delivery
    from the successor segment — and never resurfaces."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    store.append(_rec(0), fingerprint=FP)
    store.close()
    seg0 = os.path.join(path, os.listdir(path)[0])
    with open(seg0, "ab") as f:
        f.write(b'{"kind": "obs", "fp": "dead')    # killed mid-record
    store = TuningRecordStore(path)                # new writer, new segment
    store.append(_rec(1), fingerprint=FP)
    store.close()

    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1]
    assert _drain(watcher) == []


# ---------------------------------------------------------------------------
# compaction vs a live tail (ISSUE 5)
# ---------------------------------------------------------------------------
def test_compaction_mid_tail_delivers_unconsumed_exactly_once(tmp_path):
    """The core swap contract: a watcher that consumed some sealed segments
    and never touched others must, after compaction folds them all into one
    ``segment-0-*`` file, receive exactly the records it had NOT yet seen —
    in write order, nothing twice."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    for seq in range(3):
        store.append(_rec(seq), fingerprint=FP)
    store.close()
    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1, 2]       # segment 0: fully consumed
    store = TuningRecordStore(path)
    for seq in range(3, 6):
        store.append(_rec(seq), fingerprint=FP)
    store.close()                              # segment 1: never polled
    store = TuningRecordStore(path)
    store.append(_rec(6), fingerprint=FP)      # segment 2: active writer

    stats = compact_store(path)
    assert stats.folded and len(stats.sources) == 2
    assert _drain(watcher) == [3, 4, 5, 6], \
        "exactly the unconsumed records, oldest first"
    assert _drain(watcher) == []
    store.append(_rec(7), fingerprint=FP)      # the live tail keeps working
    assert _drain(watcher) == [7]
    # a fresh reader sees one copy of everything, in order
    assert _drain(StoreWatcher(path)) == list(range(8))
    assert [int(r.key) for r in TuningRecordStore(path).records()] \
        == list(range(8))


def test_compaction_mid_segment_consumption(tmp_path):
    """Partial consumption WITHIN one sealed segment: the watcher polled
    half its records before the writer rolled over and compaction folded
    it — the compacted copy must resume at the exact line the tail left."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    for seq in range(2):
        store.append(_rec(seq), fingerprint=FP)
    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1]           # mid-segment tail position
    for seq in range(2, 5):
        store.append(_rec(seq), fingerprint=FP)
    store.close()
    store = TuningRecordStore(path)
    store.append(_rec(5), fingerprint=FP)      # seals segment 0
    compact_store(path)
    assert _drain(watcher) == [2, 3, 4, 5]
    assert _drain(watcher) == []


def test_compaction_racing_appender_loses_nothing(tmp_path):
    """An appender holding its segment open across a compaction keeps
    appending into the same (untouched) file: compaction only folds sealed
    segments, and the appender's numbering never reuses a folded name."""
    path = str(tmp_path / "store")
    old = TuningRecordStore(path)
    for seq in range(3):
        old.append(_rec(seq), fingerprint=FP)
    old.close()
    live = TuningRecordStore(path)
    live.append(_rec(3), fingerprint=FP)       # live handle, active segment
    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1, 2, 3]
    compact_store(path)
    live.append(_rec(4), fingerprint=FP)       # racing append, same handle
    live.append(_rec(5), fingerprint=FP)
    assert _drain(watcher) == [4, 5]
    live.close()
    # rollover after compaction: the new segment's name must sort after the
    # folded ones (numbering restarts past the compaction high water)
    relay = TuningRecordStore(path)
    relay.append(_rec(6), fingerprint=FP)
    relay.close()
    assert _drain(watcher) == [6]
    assert _drain(StoreWatcher(path)) == list(range(7))


def test_from_start_false_watcher_across_compaction(tmp_path):
    """An opened-at-end watcher must treat pre-open history as consumed and
    post-open appends as deliverable — including when compaction folds the
    segment before the watcher's next poll (byte-offset provenance: the
    open-time size IS the consumed frontier)."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    for seq in range(3):
        store.append(_rec(seq), fingerprint=FP)      # pre-open history
    watcher = StoreWatcher(path, from_start=False)
    for seq in range(3, 5):
        store.append(_rec(seq), fingerprint=FP)      # post-open, unpolled
    store.close()
    store = TuningRecordStore(path)
    store.append(_rec(5), fingerprint=FP)            # seals segment 0
    compact_store(path)
    assert _drain(watcher) == [3, 4, 5], \
        "history skipped, post-open appends survive the fold"
    assert _drain(watcher) == []


def test_double_compaction_chains_provenance(tmp_path):
    """Folding a compacted segment again re-stamps provenance one level at
    a time; a tail that consumed generation 1 must not see its records
    resurface from generation 2."""
    path = str(tmp_path / "store")
    for seq in range(2):
        store = TuningRecordStore(path)
        store.append(_rec(seq), fingerprint=FP)
        store.close()
    watcher = StoreWatcher(path)
    assert _drain(watcher) == [0, 1]
    compact_store(path)                        # gen 1 folds both
    assert _drain(watcher) == []
    store = TuningRecordStore(path)
    store.append(_rec(2), fingerprint=FP)
    store.close()
    assert _drain(watcher) == [2]
    compact_store(path)                        # gen 2 folds gen 1 + segment
    assert _drain(watcher) == []
    assert _drain(StoreWatcher(path)) == [0, 1, 2]


# ---------------------------------------------------------------------------
# sidecar index under concurrent traffic (ISSUE 5)
# ---------------------------------------------------------------------------
def _store_view(store: TuningRecordStore):
    return ([r.to_json() for r in store.records(fp=FP.digest)],
            None if store.best(FP.digest) is None
            else store.best(FP.digest).to_json())


def test_stale_index_rebuilt_when_segments_rewritten(tmp_path):
    """An index referencing a segment that shrank or vanished (a rewrite it
    never saw) is discarded and rebuilt — results match a full load."""
    path = str(tmp_path / "store")
    for seq in range(4):
        store = TuningRecordStore(path)
        store.append(_rec(seq), fingerprint=FP)
        store.close()
    TuningRecordStore(path, lazy=True)         # writes the sidecar
    doomed = [f for f in sorted(os.listdir(path)) if f.endswith(".jsonl")][0]
    os.remove(os.path.join(path, doomed))      # rewrite the index missed
    lazy = TuningRecordStore(path, lazy=True)
    assert _store_view(lazy) == _store_view(TuningRecordStore(path))
    fresh = load_index(path)                   # sidecar was repaired too
    assert fresh is not None and doomed not in fresh.segments


def test_torn_index_write_treated_as_missing(tmp_path):
    """A torn (partially written) sidecar must never poison an open: it
    reads as missing, the index rebuilds, results match a full load."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    for seq in range(5):
        store.append(_rec(seq), fingerprint=FP)
    store.close()
    TuningRecordStore(path, lazy=True)
    idx_file = index_path(path)
    blob = open(idx_file, "rb").read()
    with open(idx_file, "wb") as f:            # killed mid-write
        f.write(blob[:len(blob) // 2])
    assert load_index(path) is None
    lazy = TuningRecordStore(path, lazy=True)
    assert _store_view(lazy) == _store_view(TuningRecordStore(path))
    assert load_index(path) is not None


def test_outdated_index_tail_scan_picks_up_appends(tmp_path):
    """Appends past the indexed frontier (grown segment AND brand-new
    segment) are NOT staleness — the lazy open scans only those bytes."""
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    for seq in range(3):
        store.append(_rec(seq), fingerprint=FP)
    store.close()
    TuningRecordStore(path, lazy=True)         # index frontier: 3 records
    store = TuningRecordStore(path)            # new segment
    store.append(_rec(3), fingerprint=FP)
    store.close()
    lazy = TuningRecordStore(path, lazy=True)
    assert len(lazy.records(fp=FP.digest)) == 4
    assert _store_view(lazy) == _store_view(TuningRecordStore(path))


# ---------------------------------------------------------------------------
# fenced tuning-job queue under a fleet of daemons (ISSUE 9)
# ---------------------------------------------------------------------------
class _Req:
    """Anything with the RetuneRequest fields is submittable."""

    def __init__(self, key: str, t: float = 1.0):
        self.key = key
        self.objective = f"{key}@sim"
        self.observed = 2.0
        self.predicted = 1.0
        self.reason = "drift"
        self.t = t


def _queue(path, worker, clock, appender, ttl=10.0):
    return TuningJobQueue(path, worker=worker, claim_ttl=ttl, clock=clock,
                          appender=appender)


@pytest.mark.parametrize("skew", [-1e6, 1e6])
def test_lease_expiry_judged_on_reader_clock_not_writer_stamps(tmp_path,
                                                               skew):
    """Cross-machine clock skew: the claimant's host clock is ±11 days off.
    Under writer-stamp arbitration a -skew claim would look ancient (peers
    steal the live lease instantly) and a +skew claim far-future (the queue
    wedges for 11 days). Reader-clock expiry makes both irrelevant: each
    reader counts the TTL from when IT first folded the claim."""
    path = str(tmp_path / "store")
    t = [100.0]
    store = TuningRecordStore(path, load=False)
    a = _queue(path, "a", lambda: t[0] + skew, store)   # skewed claimant
    b = _queue(path, "b", lambda: t[0], store)          # honest reader
    assert a.submit(_Req("cell-k", t=1.0))
    ticket = a.claim()
    assert ticket is not None and ticket.token == 1
    assert b.claim() is None, \
        "live lease must hold regardless of the writer's clock"
    t[0] += 5.0
    assert b.claim() is None, "still inside the TTL on b's own clock"
    t[0] += 6.0                     # 11s since b first folded the claim
    took = b.claim()
    assert took is not None and took.token == 2, \
        "a genuinely expired lease re-arms with a higher fencing token"
    b.done(took)
    assert len(_queue(path, "c", lambda: t[0], store)) == 0


def test_zombie_done_raises_and_fold_rejects_the_record(tmp_path):
    """The tentpole bug: a claimant pauses past its TTL, a peer re-claims
    and services, then the zombie wakes. Its ``done()`` must raise
    ``FencedClaimError`` — and even a done RECORD that slipped onto disk
    (zombie died between the fence check and the flush landing) must be
    refused by every fold, so the job is not closed under the live
    claimant."""
    path = str(tmp_path / "store")
    t = [100.0]
    clk = lambda: t[0]                                       # noqa: E731
    store = TuningRecordStore(path, load=False)
    a = _queue(path, "a", clk, store)
    b = _queue(path, "b", clk, store)
    assert a.submit(_Req("cell-k", t=1.0))
    za = a.claim()
    assert za is not None and za.token == 1
    assert b.claim() is None        # b folds the claim: its TTL clock starts
    t[0] += 11.0                    # a pauses past claim_ttl
    zb = b.claim()
    assert zb is not None and zb.token == 2, "expired lease re-claimed"
    with pytest.raises(FencedClaimError):
        a.done(za)                  # the zombie wakes mid-service
    # the slipped-write variant: force the zombie's done onto disk anyway
    store.append_control({"kind": "job", "state": "done", "id": za.id,
                          "key": za.key, "by": "a", "t": clk(),
                          "token": za.token})
    fresh = _queue(path, "c", clk, store)
    assert len(fresh) == 1, "the fenced done must not close the job"
    assert fresh.rejected_writes == 1
    b.done(zb)                      # the live claimant closes it
    assert len(_queue(path, "d", clk, store)) == 0


def test_racing_claimant_with_stale_snapshot_backs_off(tmp_path):
    """The claim-race window: b folded the queue BEFORE a's claim landed,
    so b's pre-append token snapshot misses it. b's post-append re-read
    must spot the unseen live lower-token claim, release its own token,
    and back off — and the loser's released token must NOT fence the
    winner's ``done`` (released claims are transparent to arbitration)."""
    path = str(tmp_path / "store")
    t = [100.0]
    clk = lambda: t[0]                                       # noqa: E731
    store = TuningRecordStore(path, load=False)
    a = _queue(path, "a", clk, store)
    b = _queue(path, "b", clk, store)
    assert a.submit(_Req("cell-k", t=1.0))
    b._refresh()                    # b's snapshot predates a's claim
    canon = b._canonical("cell-k")
    ta = a.claim()
    assert ta is not None and ta.token == 1
    assert b._try_claim(canon, clk()) is None, \
        "the post-append check must detect the stolen claim and back off"
    assert b.claim() is None, "a still holds the live lease"
    a.done(ta)                      # the winner's done is NOT fenced by the
    assert a.rejected_writes == 0   # loser's released higher token
    assert len(_queue(path, "c", clk, store)) == 0


def test_released_racer_token_survives_compaction_fold(tmp_path):
    """compact_store's GC replays the same fencing fold: a claim+release
    pair (an aborted racer) must be transparent there too, or compaction
    would resurrect a job whose winner's done it mis-judged as fenced."""
    path = str(tmp_path / "store")
    t = [100.0]
    clk = lambda: t[0]                                       # noqa: E731
    store = TuningRecordStore(path, load=False)
    a = _queue(path, "a", clk, store)
    b = _queue(path, "b", clk, store)
    assert a.submit(_Req("cell-k", t=1.0))
    b._refresh()
    canon = b._canonical("cell-k")
    ta = a.claim()
    assert b._try_claim(canon, clk()) is None   # release(token 2) on disk
    a.done(ta)                                  # done carries token 1
    store.close()
    store2 = TuningRecordStore(path, load=False)
    store2.append(_rec(0), fingerprint=FP)      # seals the control segment
    stats = compact_store(path, retention_s=0.0, now=t[0] + 1.0)
    assert stats.folded and stats.dropped_retune > 0, \
        "the completed group must GC despite the released racer token"
    assert len(_queue(path, "c", clk, store2)) == 0
    store2.close()


def test_quarantine_after_k_dead_claimants(tmp_path):
    """A poison job that kills every claimant must not re-arm forever:
    after K consecutive leases expire unreleased, the next would-be
    claimant quarantines the group (fresh-token terminal close) instead
    of claiming it. A NEW submit for the key re-arms fresh."""
    path = str(tmp_path / "store")
    t = [100.0]
    clk = lambda: t[0]                                       # noqa: E731
    store = TuningRecordStore(path, load=False)
    a = _queue(path, "a", clk, store)
    assert a.submit(_Req("cell-k", t=1.0))
    assert a.claim().token == 1     # claimant 1 dies (never done/release)
    b = TuningJobQueue(path, worker="b", claim_ttl=10.0, clock=clk,
                       appender=store, quarantine_after=2)
    assert b.claim() is None        # live lease holds; b's TTL clock starts
    t[0] += 11.0
    assert b.claim().token == 2, \
        "one burned lease is below the threshold: re-arm normally"
    t[0] += 11.0                    # claimant 2 dies too
    assert b.claim() is None, "threshold reached: quarantined, not claimed"
    assert b.quarantined == 1
    assert len(b) == 0, "quarantine is terminal — job no longer offered"
    fresh = _queue(path, "c", clk, store)
    assert len(fresh) == 0 and fresh.quarantined == 1, \
        "a fresh fold sees the quarantine records and the counter"
    # the key re-arms for NEW submissions with a strictly higher fence
    assert fresh.submit(_Req("cell-k", t=t[0]))
    took = fresh.claim()
    assert took is not None and took.token == 4, \
        "quarantine burned token 3; the re-armed claim must be above it"
    fresh.done(took)


def test_voluntary_releases_never_count_toward_quarantine(tmp_path):
    """Graceful give-backs (service failed, shutdown) and aborted racers
    release their tokens — they are NOT dead claimants and must not push
    a healthy job into quarantine."""
    path = str(tmp_path / "store")
    t = [100.0]
    clk = lambda: t[0]                                       # noqa: E731
    store = TuningRecordStore(path, load=False)
    q = TuningJobQueue(path, worker="a", claim_ttl=10.0, clock=clk,
                       appender=store, quarantine_after=2)
    assert q.submit(_Req("cell-k", t=1.0))
    for _ in range(4):              # 4 voluntary give-backs, 0 deaths
        tk = q.claim()
        assert tk is not None
        q.release(tk)
    tk = q.claim()
    assert tk is not None and q.quarantined == 0, \
        "released leases are transparent to the quarantine count"
    q.done(tk)


def test_quarantined_group_gcs_under_compaction(tmp_path):
    """compact_store's job fold must treat ``quarantine`` as a token-fenced
    terminal close — the group folds away under retention like a done
    group, instead of being resurrected as open forever."""
    path = str(tmp_path / "store")
    t = [100.0]
    clk = lambda: t[0]                                       # noqa: E731
    store = TuningRecordStore(path, load=False)
    q = TuningJobQueue(path, worker="a", claim_ttl=10.0, clock=clk,
                       appender=store, quarantine_after=1)
    assert q.submit(_Req("cell-k", t=1.0))
    assert q.claim() is not None    # the one claimant dies
    t[0] += 11.0
    assert q.claim() is None and q.quarantined == 1
    store.close()
    store2 = TuningRecordStore(path, load=False)
    store2.append(_rec(0), fingerprint=FP)      # seals the control segment
    stats = compact_store(path, retention_s=0.0, now=t[0] + 1.0)
    assert stats.folded and stats.dropped_retune > 0, \
        "the quarantined group must GC like a completed one"
    assert len(_queue(path, "c", clk, store2)) == 0
    store2.close()


def test_stale_quarantine_write_is_fence_rejected(tmp_path):
    """A quarantine record whose token is below the group's live claim is
    a superseded daemon's late write: every fold must refuse it, exactly
    as it refuses a fenced done."""
    path = str(tmp_path / "store")
    t = [100.0]
    clk = lambda: t[0]                                       # noqa: E731
    store = TuningRecordStore(path, load=False)
    q = _queue(path, "a", clk, store)
    assert q.submit(_Req("cell-k", t=1.0))
    tk = q.claim()
    assert tk is not None and tk.token == 1
    store.append_control({"kind": "job", "state": "quarantine", "id": tk.id,
                          "key": tk.key, "by": "zombie", "t": clk(),
                          "token": 0})
    fresh = _queue(path, "c", clk, store)
    assert len(fresh) == 1 and fresh.quarantined == 0
    assert fresh.rejected_writes == 1
    q.done(tk)
    assert len(_queue(path, "d", clk, store)) == 0


def test_retune_daemon_surfaces_quarantined_counter(tmp_path):
    """RetuneDaemon's fleet stats delegate to its queue's fold."""
    from repro.launch.retune import RetuneDaemon
    path = str(tmp_path / "store")
    t = [100.0]
    clk = lambda: t[0]                                       # noqa: E731
    store = TuningRecordStore(path, load=False)
    q = TuningJobQueue(path, worker="a", claim_ttl=10.0, clock=clk,
                       appender=store, quarantine_after=1)
    assert q.submit(_Req("cell-k", t=1.0))
    assert q.claim() is not None
    t[0] += 11.0
    assert q.claim() is None and q.quarantined == 1
    daemon = RetuneDaemon(path, store=store, clock=clk,
                          quarantine_after=1, worker="d")
    assert daemon.quarantined == 1
    assert daemon.step() is None, "nothing claimable on a quarantined key"


def test_second_compactor_raises_while_lock_is_fresh(tmp_path):
    path = str(tmp_path / "store")
    store = TuningRecordStore(path)
    store.append(_rec(0), fingerprint=FP)
    store.close()
    store = TuningRecordStore(path)
    store.append(_rec(1), fingerprint=FP)       # seals segment 0
    reg = FenceRegistry(path, clock=lambda: 100.0)
    held = reg.issue(COMPACT_LOCK_KEY, by="compactor-peer")
    assert held == 1
    with pytest.raises(CompactionLocked):
        compact_store(path, now=100.5)          # peer's lock is fresh
    # a lock whose holder stamp aged past lock_ttl is taken over — with the
    # NEXT token, never by deleting the marker
    stats = compact_store(path, now=100.0 + 3600.0 + 1.0)
    assert stats.folded
    assert reg.highest(COMPACT_LOCK_KEY) == 2
    assert reg.released(COMPACT_LOCK_KEY, 2), "lock released after the swap"
    # an explicitly released lock is claimable immediately, no TTL wait
    store.close()
    store = TuningRecordStore(path)
    store.append(_rec(2), fingerprint=FP)
    assert compact_store(path, now=100.0 + 3600.0 + 2.0).folded
    store.close()


# ---------------------------------------------------------------------------
# randomized interleavings (hypothesis) — guarded import, NOT importorskip:
# the deterministic edge-case tests above must run even without hypothesis
# ---------------------------------------------------------------------------
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(st.sampled_from(["write", "poll", "rollover"]),
                        min_size=1, max_size=40),
           layout=st.sampled_from(["dir", "single"]))
    def test_any_interleaving_delivers_every_record_once_in_order(ops,
                                                                  layout):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d,
                                "store" if layout == "dir" else "store.jsonl")
            store = TuningRecordStore(path)
            watcher = StoreWatcher(path)
            written, seen = 0, []
            for op in ops:
                if op == "write":
                    store.append(_rec(written), fingerprint=FP)
                    written += 1
                elif op == "poll":
                    seen += _drain(watcher)
                else:                    # rollover: writer restarts
                    store.close()
                    if layout == "dir":  # a single file IS one segment
                        store = TuningRecordStore(path)
            seen += _drain(watcher)
            assert seen == list(range(written))
            assert _drain(watcher) == []

    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(st.sampled_from(["write", "poll", "rollover",
                                         "compact"]),
                        min_size=1, max_size=40))
    def test_any_schedule_with_compaction_is_exactly_once_in_order(ops):
        """ISSUE 5 acceptance property: however compaction interleaves with
        appends, rollovers, and polls, the tail delivers every record
        exactly once in write order, and a fresh full load agrees."""
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "store")
            store = TuningRecordStore(path)
            watcher = StoreWatcher(path)
            written, seen = 0, []
            for op in ops:
                if op == "write":
                    store.append(_rec(written), fingerprint=FP)
                    written += 1
                elif op == "poll":
                    seen += _drain(watcher)
                elif op == "rollover":
                    store.close()
                    store = TuningRecordStore(path)
                else:
                    compact_store(path)      # retention off: pure folding
            seen += _drain(watcher)
            assert seen == list(range(written))
            assert _drain(watcher) == []
            assert [int(r.key)
                    for r in TuningRecordStore(path).records()] \
                == list(range(written))
            lazy = TuningRecordStore(path, lazy=True)
            assert [int(r.key) for r in lazy.records(fp=FP.digest)] \
                == list(range(written))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_any_interleaving_delivers_every_record_once_in_order():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_any_schedule_with_compaction_is_exactly_once_in_order():
        pass


# ---------------------------------------------------------------------------
# fleet schedules: {submit, claim, service, die, compact, tick} × N daemons
# (ISSUE 9 acceptance property) — the harness executes any op schedule and
# checks the lease-exclusivity invariants against a model ledger after every
# single step, then drains the queue and reconciles a cold fold.
# ---------------------------------------------------------------------------
class _FleetFuzz:
    """N in-process daemons sharing ONE appender store (one pid = one live
    segment, the sealed-per-pid rule) racing over a handful of job keys.

    Invariants checked:
      * a claim is granted only when no other claim on the key is live on
        the shared clock (exactly-once leases);
      * fencing tokens per key are strictly increasing;
      * an accepted ``done`` comes from the ledger's current owner (or is
        a benign no-op on an already-closed group);
      * a superseded claimant's ``done`` raises ``FencedClaimError``;
      * after draining, every accepted generation of every key was
        serviced exactly once, and a cold fold agrees the queue is empty.
    """

    KEYS = ("cell-a", "cell-b", "cell-c")
    TTL = 10.0

    def __init__(self, path: str, n_daemons: int = 3):
        self.path = path
        self.t = [100.0]
        self.clock = lambda: self.t[0]
        self.store = TuningRecordStore(path, load=False)
        self.daemons = [_queue(path, f"d{i}", self.clock, self.store,
                               ttl=self.TTL) for i in range(n_daemons)]
        self.held = [None] * n_daemons
        self.open = {k: False for k in self.KEYS}
        self.lease = {k: None for k in self.KEYS}   # (daemon, token, t)
        self.last_token = {k: 0 for k in self.KEYS}
        self.generations = {k: 0 for k in self.KEYS}
        self.services = {k: 0 for k in self.KEYS}
        self.fenced = 0
        self.compactions = 0

    def _expired_lease(self, key: str) -> bool:
        lease = self.lease[key]
        return lease is None or self.t[0] - lease[2] > self.TTL

    def run_op(self, op, i: int, key: str) -> None:
        self.t[0] += 0.001              # unique submit ids per op
        if op == "submit":
            accepted = self.daemons[i].submit(
                _Req(key, t=self.t[0]),
                job_type=JOB_TYPES[self.generations[key] % len(JOB_TYPES)])
            assert accepted == (not self.open[key]), \
                "submit must accept iff the key has no open job group"
            if accepted:
                self.open[key] = True
                self.generations[key] += 1
        elif op == "claim":
            if self.held[i] is not None:
                return                   # one job at a time per daemon
            tk = self.daemons[i].claim()
            if tk is None:
                return
            assert self.open[tk.key], "claimed a key with no open job"
            assert self._expired_lease(tk.key), \
                "claim granted while another claim was live: double lease"
            assert tk.token > self.last_token[tk.key], \
                "fencing tokens must be strictly increasing per key"
            self.last_token[tk.key] = tk.token
            self.lease[tk.key] = (i, tk.token, self.t[0])
            self.held[i] = tk
        elif op == "service":
            tk, self.held[i] = self.held[i], None
            if tk is None:
                return
            try:
                self.daemons[i].done(tk)
            except FencedClaimError:
                self.fenced += 1
                lease = self.lease[tk.key]
                assert lease is not None and lease[0] != i, \
                    "done fenced although this daemon still held the lease"
                return
            lease = self.lease[tk.key]
            if lease is not None and lease[0] == i and lease[1] == tk.token:
                self.open[tk.key] = False
                self.lease[tk.key] = None
                self.services[tk.key] += 1
                return
            # stale ticket: its generation already closed (idempotent
            # no-op) — it must NOT have closed a re-armed generation
            if self.open[tk.key]:
                assert self.daemons[i]._canonical(tk.key) is not None, \
                    "a stale ticket's done closed the next generation"
        elif op == "die":
            # the daemon restarts: its held ticket is forgotten (the claim
            # stays on disk until the TTL fences it out) and its successor
            # cold-folds the whole store
            self.held[i] = None
            self.daemons[i] = _queue(self.path, f"d{i}", self.clock,
                                     self.store, ttl=self.TTL)
        elif op == "compact":
            self.store.close()           # seal this pid's live segment
            stats = compact_store(self.path, retention_s=0.0,
                                  now=self.t[0], clock=self.clock)
            self.compactions += int(stats.folded)
        elif op == "tick":
            self.t[0] += self.TTL / 2 + 0.1
        else:                            # pragma: no cover
            raise AssertionError(op)

    def drain(self, max_rounds: int = 60) -> None:
        for _ in range(max_rounds):
            if not any(self.open.values()) \
                    and all(h is None for h in self.held):
                break
            progressed = False
            for i in range(len(self.daemons)):
                if self.held[i] is not None:
                    self.run_op("service", i, "")
                    progressed = True
                else:
                    before = self.held[i]
                    self.run_op("claim", i, "")
                    progressed = progressed or self.held[i] is not before
            if not progressed:
                self.run_op("tick", 0, "")  # expire zombie leases
        assert not any(self.open.values()), \
            f"queue failed to drain: {self.open}"

    def check_final(self) -> None:
        for k in self.KEYS:
            assert self.services[k] == self.generations[k], \
                (f"{k}: {self.generations[k]} accepted generations but "
                 f"{self.services[k]} accepted services — not exactly-once")
        cold = _queue(self.path, "auditor", self.clock, self.store)
        assert len(cold) == 0, "a cold fold disagrees: jobs still open"


_FLEET_OPS = ("submit", "claim", "service", "die", "compact", "tick")


def _run_fleet_schedule(schedule, n_daemons: int = 3) -> _FleetFuzz:
    """One schedule: a list of (op, daemon_index, key_index) triples."""
    with tempfile.TemporaryDirectory() as d:
        fuzz = _FleetFuzz(os.path.join(d, "store"), n_daemons=n_daemons)
        for op, i, ki in schedule:
            fuzz.run_op(op, i % len(fuzz.daemons),
                        fuzz.KEYS[ki % len(fuzz.KEYS)])
        fuzz.drain()
        fuzz.check_final()
        fuzz.store.close()
        return fuzz


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(schedule=st.lists(
        st.tuples(st.sampled_from(_FLEET_OPS),
                  st.integers(min_value=0, max_value=2),
                  st.integers(min_value=0, max_value=2)),
        min_size=1, max_size=25))
    def test_fleet_schedule_is_exactly_once_under_fencing(schedule):
        """ISSUE 9 acceptance property: any interleaving of {submit, claim,
        service, die, compact, tick} across 3 daemons grants each job's
        lease exactly once at a time, fences superseded writers, and drains
        to every accepted job serviced exactly once."""
        _run_fleet_schedule(schedule)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fleet_schedule_is_exactly_once_under_fencing():
        pass


def test_600_seeded_fleet_schedules_exactly_once():
    """The ISSUE 9 bar, hypothesis-independent: 600 seeded random schedules
    (ops weighted toward the contended paths) across 3 daemons, every one
    asserting the full lease/fencing invariant set after every op."""
    weights = {"submit": 5, "claim": 6, "service": 5, "die": 2,
               "compact": 1, "tick": 3}
    bag = [op for op, w in weights.items() for _ in range(w)]
    fenced = serviced = 0
    for seed in range(600):
        rng = random.Random(seed)
        schedule = [(rng.choice(bag), rng.randrange(3), rng.randrange(3))
                    for _ in range(rng.randint(4, 14))]
        fuzz = _run_fleet_schedule(schedule)
        fenced += fuzz.fenced
        serviced += sum(fuzz.services.values())
    assert serviced >= 600, "the sweep barely exercised the queue"
    assert fenced > 0, \
        "600 schedules never produced a fenced zombie done — the sweep " \
        "lost its teeth"
