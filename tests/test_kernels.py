"""Per-kernel allclose vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.gp_fast import IncrementalGP
from repro.kernels import ops, ref


# -- GEMM --------------------------------------------------------------------

@pytest.mark.parametrize("M,N,K", [(128, 128, 128), (256, 384, 512),
                                   (512, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_shapes_dtypes(M, N, K, dtype):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(M, K)), dtype)
    b = jnp.asarray(rng.normal(size=(K, N)), dtype)
    out = ops.gemm(a, b, block_m=128, block_n=128, block_k=128)
    want = ref.gemm(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 64, 256)])
def test_gemm_block_configs(bm, bn, bk):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    out = ops.gemm(a, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gemm(a, b)),
                               rtol=1e-4, atol=1e-3)


def test_gemm_rejects_indivisible():
    a = jnp.zeros((100, 128), jnp.float32)
    b = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(AssertionError):
        ops.gemm(a, b, block_m=64, block_n=64, block_k=64)


# -- flash attention -----------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hd", [(1, 128, 2, 64), (2, 256, 4, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_shapes_dtypes(B, S, H, hd, dtype):
    rng = np.random.default_rng(2)
    q, k, v = (jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
               for _ in range(3))
    out = ops.flash_attention(q, k, v, block_q=64, block_kv=64)
    want = ref.attention(q, k, v, causal=True)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("bq,bkv", [(32, 128), (128, 32), (64, 64)])
def test_flash_block_configs(bq, bkv):
    rng = np.random.default_rng(3)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
               for _ in range(3))
    out = ops.flash_attention(q, k, v, block_q=bq, block_kv=bkv)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.attention(q, k, v)),
                               rtol=2e-4, atol=2e-4)


def test_flash_noncausal():
    rng = np.random.default_rng(4)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
               for _ in range(3))
    out = ops.flash_attention(q, k, v, block_q=64, block_kv=64, causal=False)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.attention(q, k, v, causal=False)),
                               rtol=2e-4, atol=2e-4)


# -- Matérn GP posterior ---------------------------------------------------------

@pytest.mark.parametrize("t,N,d", [(13, 512, 6), (37, 1024, 15)])
@pytest.mark.parametrize("nu", ["matern32", "matern52"])
def test_gp_kernel_vs_oracle_and_engine(t, N, d, nu):
    rng = np.random.default_rng(5)
    Xc = rng.random((N, d)).astype(np.float32)
    g = IncrementalGP(Xc, max_obs=64, kernel=nu, ell=2.0)
    for _ in range(t):
        g.add(Xc[rng.integers(N)], float(rng.normal(10, 3)))
    x_obs, vinv, w, mask, y_mean, y_std = ops.gp_inputs_from_incremental(g)
    mean_k, var_k = ops.gp_posterior(
        jnp.asarray(Xc), jnp.asarray(x_obs), jnp.asarray(vinv),
        jnp.asarray(w), jnp.asarray(mask), ell=2.0, nu=nu, block_n=256)
    # kernel vs same-precision jnp oracle. The VARIANCE path is well
    # conditioned -> tight. The MEAN is amplified by ||L^-1||*||w|| (GP
    # kernel matrices are ill-conditioned), so even two fp32 codings differ
    # by ~kappa*eps: bound by a fraction of the mean's range instead.
    m_r, v_r = ref.gp_posterior(jnp.asarray(Xc), jnp.asarray(x_obs),
                                jnp.asarray(vinv), jnp.asarray(w), 2.0, nu)
    np.testing.assert_allclose(np.asarray(var_k), np.asarray(v_r),
                               rtol=3e-3, atol=1e-4)
    m_r = np.asarray(m_r)
    rng_m = m_r.max() - m_r.min() + 1e-9
    assert np.abs(np.asarray(mean_k) - m_r).max() < 0.03 * rng_m
    # behavioral: fp32 kernel vs float64 incremental engine. GP systems are
    # ill-conditioned, so pointwise fp32 error can reach ~2% of the y-range —
    # what matters for acquisition is the RANKING, which must agree.
    mu_k = y_mean + y_std * np.asarray(mean_k)
    mu_i, _ = g.predict()
    y_range = mu_i.max() - mu_i.min()
    assert np.abs(mu_k - mu_i).max() < 0.05 * y_range
    top_k = set(np.argsort(mu_k)[:20])
    top_i = set(np.argsort(mu_i)[:20])
    assert len(top_k & top_i) >= 18


def test_vmem_models_monotone():
    from repro.kernels.flash_attention import flash_vmem_bytes
    from repro.kernels.gemm import gemm_vmem_bytes
    assert gemm_vmem_bytes(256, 256, 256) < gemm_vmem_bytes(512, 512, 512)
    assert flash_vmem_bytes(256, 256, 128) < flash_vmem_bytes(1024, 1024, 128)


# -- GQA-expanded flash dispatch vs the models/layers reference -------------

@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (4, 1)])   # MHA, GQA, MQA
@pytest.mark.parametrize("bq,bkv", [(64, 64), (128, 64), (64, 128)])
def test_flash_gqa_expanded_vs_layers_reference(H, KV, bq, bkv):
    """The serve dispatch path (_pallas_flash_attention) expands KV heads
    and calls the MHA-core Pallas kernel; it must match the grouped-head
    pure-JAX attention in models/layers.py on causal prefill shapes."""
    from repro.models.layers import _direct_attention
    rng = np.random.default_rng(11)
    B, S, hd = 1, 256, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    G = H // KV
    ke = jnp.repeat(k, G, axis=2) if G > 1 else k
    ve = jnp.repeat(v, G, axis=2) if G > 1 else v
    out = ops.flash_attention(q, ke, ve, block_q=bq, block_kv=bkv,
                              causal=True)
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    want = _direct_attention(q, k, v, q_pos=pos, k_pos=pos, window=None,
                             scale=1.0 / np.sqrt(hd))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_kernel_config_dispatch_matches_pure_jax():
    """End-to-end: a prefill step with KernelConfig set must match the
    pure-JAX step within kernel tolerance (and fall back silently when the
    blocks don't tile the sequence)."""
    from repro.configs.registry import smoke_config
    from repro.models.params import init_params
    from repro.models.stepfn import make_prefill_step
    from repro.parallel.sharding import (KernelConfig, ParallelConfig,
                                         ShardCtx)
    cfg = smoke_config("qwen3-moe-30b-a3b")       # GQA arch
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 256
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    pcfg0 = ParallelConfig(flash_threshold=1 << 30, logits_chunk=0)
    pcfg1 = pcfg0.replace(kernel=KernelConfig(
        use_flash=True, flash_block_q=128, flash_block_kv=128))
    step0 = jax.jit(make_prefill_step(cfg, ShardCtx(None, pcfg0),
                                      cache_cap=S + 4))
    step1 = jax.jit(make_prefill_step(cfg, ShardCtx(None, pcfg1),
                                      cache_cap=S + 4))
    out0, _ = step0(params, batch)
    out1, _ = step1(params, batch)
    denom = float(jnp.abs(out0).max())
    assert float(jnp.abs(out0 - out1).max()) < 5e-3 * max(denom, 1.0)
    # blocks that don't tile S: dispatch precondition fails -> pure-JAX path
    pcfg2 = pcfg0.replace(kernel=KernelConfig(
        use_flash=True, flash_block_q=512, flash_block_kv=512))
    out2, _ = jax.jit(make_prefill_step(cfg, ShardCtx(None, pcfg2),
                                        cache_cap=S + 4))(params, batch)
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(out2))


# -- gp_inputs_from_incremental packaging ------------------------------------

def test_gp_inputs_triangular_solve_parity():
    """The O(t²)-per-column triangular-solve packaging must match the old
    O(T³) dense-inverse formulation exactly (same math, fp64 then cast)."""
    rng = np.random.default_rng(12)
    Xc = rng.random((64, 5)).astype(np.float32)
    g = IncrementalGP(Xc, max_obs=32, kernel="matern32", ell=2.0)
    for _ in range(17):
        g.add(Xc[rng.integers(64)], float(rng.normal(3, 1)))
    x_obs, vinv, w, mask, y_mean, y_std = ops.gp_inputs_from_incremental(g)
    T, t = len(mask), g.t
    # oracle: dense inverse of the padded factor (identity on pad rows),
    # zeroed outside the live t x t block — the pre-fix formulation
    Lp = np.eye(T)
    Lp[:t, :t] = g.L[:t, :t]
    vinv_ref = np.linalg.inv(Lp)
    vinv_ref[t:, :] = 0.0
    vinv_ref[:, t:] = 0.0
    np.testing.assert_allclose(vinv, vinv_ref.astype(np.float32),
                               rtol=1e-5, atol=1e-6)
    yv = g.y[:t]
    w_ref = np.zeros(T)
    w_ref[:t] = np.linalg.solve(g.L[:t, :t], (yv - yv.mean()) / yv.std())
    np.testing.assert_allclose(w, w_ref.astype(np.float32),
                               rtol=1e-5, atol=1e-6)
    assert mask[:t].all() and not mask[t:].any()


# -- self-hosted GP backend (DESIGN.md §14) ----------------------------------

@pytest.mark.parametrize("block_n", [128, 256])
def test_incremental_gp_pallas_backend_vs_numpy(block_n):
    """backend="pallas" routes predict/predict_at through the fused
    matern_gp kernel; it must track the numpy oracle within the kernel's
    established fp32 tolerance (fraction of the posterior-mean range) and
    agree on acquisition RANKING."""
    rng = np.random.default_rng(13)
    N, d = 300, 6                     # non-multiple of block_n: pads
    Xc = rng.random((N, d)).astype(np.float64)
    g_np = IncrementalGP(Xc, max_obs=32)
    g_pl = IncrementalGP(Xc, max_obs=32, backend="pallas", block_n=block_n)
    for _ in range(14):
        i = rng.integers(N)
        y = float(rng.normal(5, 2))
        g_np.add(Xc[i], y)
        g_pl.add(Xc[i], y)
    mu0, sd0 = g_np.predict()
    mu1, sd1 = g_pl.predict()
    assert mu1.shape == (N,) and sd1.shape == (N,)
    y_range = mu0.max() - mu0.min()
    assert np.abs(mu0 - mu1).max() < 0.05 * y_range
    assert np.abs(sd0 - sd1).max() < 5e-3 * max(sd0.max(), 1e-9) + 1e-4
    top0 = set(np.argsort(mu0)[:20])
    top1 = set(np.argsort(mu1)[:20])
    assert len(top0 & top1) >= 18
    # pool-mode scoring at arbitrary points goes through the same kernel
    Xq = rng.random((75, d))
    mu0a, _ = g_np.predict_at(Xq)
    mu1a, _ = g_pl.predict_at(Xq)
    assert np.abs(mu0a - mu1a).max() < 0.05 * y_range


def test_bo_strategy_runs_on_pallas_gp_backend():
    """Full BO loop with the self-hosted posterior: same engine, kernel
    scoring — must converge on a smooth synthetic surface."""
    from repro.core.runner import run_strategy
    from repro.core.searchspace import Param, SearchSpace
    from repro.core.strategies.bo import BOConfig, BOStrategy
    from repro.core.objectives import SimulatedObjective
    vals = tuple(range(8))
    space = SearchSpace([Param("a", vals), Param("b", vals)], name="syn")
    rng = np.random.default_rng(14)
    times = np.array([(c["a"] - 5) ** 2 + (c["b"] - 2) ** 2 + 1.0
                      for c in (space.config(i) for i in range(space.size))])
    obj = SimulatedObjective(space, times, name="syn")
    strat = BOStrategy(BOConfig(initial_samples=6, gp_backend="pallas",
                                gp_block_n=128))
    res = run_strategy(strat, obj, budget=20, seed=0)
    assert res.best_value <= times.min() + 4.0   # found the basin


# -- flash decode (single-token cache attention, ISSUE 8) --------------------

def _decode_case(B, S, H, KV, hd, cur, *, window=None, rolling=False, seed=0):
    """A cache state the way a live server produces it: contiguous fill to
    ``cur`` (later slots empty, ``cache_pos == -1``), or a rolling window's
    wrapped layout (slot s holds the latest position congruent to s)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    if rolling:
        slots = np.arange(S)
        pos = cur - ((cur - slots) % S)
        pos = np.where(pos >= 0, pos, -1)
    else:
        pos = np.where(np.arange(S) <= cur, np.arange(S), -1)
    cache_pos = jnp.asarray(np.broadcast_to(pos, (B, S)).copy(), jnp.int32)
    cur_pos = jnp.full((B,), cur, jnp.int32)
    return q, k, v, cache_pos, cur_pos


@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (4, 1)])
@pytest.mark.parametrize("num_splits,block_kv,combine",
                         [(1, 64, "jax"), (2, 32, "jax"), (4, 16, "kernel")])
def test_decode_parity_gqa_and_splits(H, KV, num_splits, block_kv, combine):
    """Kernel output must match the layers.py pure-JAX decode reference
    across GQA head ratios and split/combine configurations."""
    from repro.models.layers import _decode_attention
    q, k, v, cp, cu = _decode_case(2, 128, H, KV, 16, cur=97)
    ref_out = _decode_attention(q, k, v, cache_pos=cp, cur_pos=cu,
                                window=None, scale=1.0 / np.sqrt(16))
    out = ops.decode_attention(q, k, v, cp, cu, block_kv=block_kv,
                               num_splits=num_splits, combine=combine,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("case", [
    dict(B=2, S=128, H=4, KV=2, hd=16, cur=5),              # mostly empty
    dict(B=1, S=100, H=4, KV=2, hd=16, cur=99),             # S % block != 0
    dict(B=2, S=64, H=4, KV=2, hd=16, cur=150, window=24,
         rolling=True),                                     # rolling window
    dict(B=2, S=96, H=4, KV=1, hd=16, cur=40, window=16),   # window, no wrap
])
def test_decode_parity_occupancy_window_capacity(case):
    """Validity-mask edges: partially-empty caches, capacities that don't
    tile into block_kv (padded with masked slots), and windowed/rolling
    caches — including splits that land entirely in masked territory."""
    from repro.models.layers import _decode_attention
    case = dict(case)
    window = case.pop("window", None)
    rolling = case.pop("rolling", False)
    hd = case["hd"]
    q, k, v, cp, cu = _decode_case(**case, window=window, rolling=rolling)
    ref_out = _decode_attention(q, k, v, cache_pos=cp, cur_pos=cu,
                                window=window, scale=1.0 / np.sqrt(hd))
    for num_splits, block_kv, combine in [(1, 64, "jax"), (4, 16, "jax"),
                                          (2, 32, "kernel"),
                                          (8, 32, "kernel")]:
        out = ops.decode_attention(q, k, v, cp, cu, window=window,
                                   block_kv=block_kv, num_splits=num_splits,
                                   combine=combine, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   rtol=2e-4, atol=2e-4)


def _golden_decode_run(arch, kernel=None):
    """The exact run tests/golden/decode_logits.json was captured with
    (pre-PR code, ParallelConfig(kernel=None)); returns final-step logits."""
    from repro.configs.registry import smoke_config
    from repro.models.params import init_params
    from repro.models.stepfn import make_decode_step, make_prefill_step
    from repro.parallel.sharding import ParallelConfig, ShardCtx
    cfg = smoke_config(arch)
    px = ShardCtx(None, ParallelConfig(flash_threshold=1 << 30,
                                       logits_chunk=0, kernel=kernel))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, STEPS = 2, 8, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    prefill = jax.jit(make_prefill_step(cfg, px, cache_cap=S + STEPS))
    decode = jax.jit(make_decode_step(cfg, px))
    logits, cache = prefill(params, {"tokens": tokens})
    toks = jnp.argmax(logits, -1)
    for i in range(STEPS):
        logits, cache = decode(params, cache, {"tokens": toks[:, None]},
                               jnp.asarray(S + i, jnp.int32))
        toks = jnp.argmax(logits, -1)
    return np.asarray(logits, np.float32)


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "gemma-2b",
                                  "recurrentgemma-9b"])
def test_decode_kernel_none_byte_identical_to_golden(arch):
    """Acceptance pin (ISSUE 8): with ``ParallelConfig.kernel=None`` the
    decode path is BYTE-identical to the pre-PR capture — adding the Pallas
    dispatch changed nothing for servers that don't opt in."""
    import json, os
    path = os.path.join(os.path.dirname(__file__), "golden",
                        "decode_logits.json")
    with open(path) as f:
        golden = json.load(f)
    got = _golden_decode_run(arch, kernel=None)
    np.testing.assert_array_equal(got,
                                  np.asarray(golden[arch], np.float32))


def test_decode_kernel_dispatch_matches_pure_jax_end_to_end():
    """The golden run re-executed WITH flash-decode dispatch must track the
    pure-JAX decode within kernel tolerance (bf16 model dtype — same band
    as the prefill dispatch test), across a GQA arch and the windowed
    rolling-cache arch; and a config whose gate is closed (use_decode=False)
    stays bitwise on the pure-JAX path."""
    from repro.parallel.sharding import KernelConfig
    for arch in ("qwen3-moe-30b-a3b", "recurrentgemma-9b"):
        base = _golden_decode_run(arch, kernel=None)
        kc = KernelConfig(use_decode=True, decode_block_kv=8,
                          decode_num_splits=2, decode_combine="kernel")
        got = _golden_decode_run(arch, kernel=kc)
        denom = max(float(np.abs(base).max()), 1.0)
        assert float(np.abs(got - base).max()) < 5e-3 * denom
    closed = _golden_decode_run("gemma-2b",
                               kernel=KernelConfig(use_decode=False))
    np.testing.assert_array_equal(closed,
                                  _golden_decode_run("gemma-2b", kernel=None))
