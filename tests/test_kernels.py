"""Per-kernel allclose vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.gp_fast import IncrementalGP
from repro.kernels import ops, ref


# -- GEMM --------------------------------------------------------------------

@pytest.mark.parametrize("M,N,K", [(128, 128, 128), (256, 384, 512),
                                   (512, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_shapes_dtypes(M, N, K, dtype):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(M, K)), dtype)
    b = jnp.asarray(rng.normal(size=(K, N)), dtype)
    out = ops.gemm(a, b, block_m=128, block_n=128, block_k=128)
    want = ref.gemm(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 64, 256)])
def test_gemm_block_configs(bm, bn, bk):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    out = ops.gemm(a, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gemm(a, b)),
                               rtol=1e-4, atol=1e-3)


def test_gemm_rejects_indivisible():
    a = jnp.zeros((100, 128), jnp.float32)
    b = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(AssertionError):
        ops.gemm(a, b, block_m=64, block_n=64, block_k=64)


# -- flash attention -----------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hd", [(1, 128, 2, 64), (2, 256, 4, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_shapes_dtypes(B, S, H, hd, dtype):
    rng = np.random.default_rng(2)
    q, k, v = (jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
               for _ in range(3))
    out = ops.flash_attention(q, k, v, block_q=64, block_kv=64)
    want = ref.attention(q, k, v, causal=True)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("bq,bkv", [(32, 128), (128, 32), (64, 64)])
def test_flash_block_configs(bq, bkv):
    rng = np.random.default_rng(3)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
               for _ in range(3))
    out = ops.flash_attention(q, k, v, block_q=bq, block_kv=bkv)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.attention(q, k, v)),
                               rtol=2e-4, atol=2e-4)


def test_flash_noncausal():
    rng = np.random.default_rng(4)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
               for _ in range(3))
    out = ops.flash_attention(q, k, v, block_q=64, block_kv=64, causal=False)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.attention(q, k, v, causal=False)),
                               rtol=2e-4, atol=2e-4)


# -- Matérn GP posterior ---------------------------------------------------------

@pytest.mark.parametrize("t,N,d", [(13, 512, 6), (37, 1024, 15)])
@pytest.mark.parametrize("nu", ["matern32", "matern52"])
def test_gp_kernel_vs_oracle_and_engine(t, N, d, nu):
    rng = np.random.default_rng(5)
    Xc = rng.random((N, d)).astype(np.float32)
    g = IncrementalGP(Xc, max_obs=64, kernel=nu, ell=2.0)
    for _ in range(t):
        g.add(Xc[rng.integers(N)], float(rng.normal(10, 3)))
    x_obs, vinv, w, mask, y_mean, y_std = ops.gp_inputs_from_incremental(g)
    mean_k, var_k = ops.gp_posterior(
        jnp.asarray(Xc), jnp.asarray(x_obs), jnp.asarray(vinv),
        jnp.asarray(w), jnp.asarray(mask), ell=2.0, nu=nu, block_n=256)
    # kernel vs same-precision jnp oracle. The VARIANCE path is well
    # conditioned -> tight. The MEAN is amplified by ||L^-1||*||w|| (GP
    # kernel matrices are ill-conditioned), so even two fp32 codings differ
    # by ~kappa*eps: bound by a fraction of the mean's range instead.
    m_r, v_r = ref.gp_posterior(jnp.asarray(Xc), jnp.asarray(x_obs),
                                jnp.asarray(vinv), jnp.asarray(w), 2.0, nu)
    np.testing.assert_allclose(np.asarray(var_k), np.asarray(v_r),
                               rtol=3e-3, atol=1e-4)
    m_r = np.asarray(m_r)
    rng_m = m_r.max() - m_r.min() + 1e-9
    assert np.abs(np.asarray(mean_k) - m_r).max() < 0.03 * rng_m
    # behavioral: fp32 kernel vs float64 incremental engine. GP systems are
    # ill-conditioned, so pointwise fp32 error can reach ~2% of the y-range —
    # what matters for acquisition is the RANKING, which must agree.
    mu_k = y_mean + y_std * np.asarray(mean_k)
    mu_i, _ = g.predict()
    y_range = mu_i.max() - mu_i.min()
    assert np.abs(mu_k - mu_i).max() < 0.05 * y_range
    top_k = set(np.argsort(mu_k)[:20])
    top_i = set(np.argsort(mu_i)[:20])
    assert len(top_k & top_i) >= 18


def test_vmem_models_monotone():
    from repro.kernels.flash_attention import flash_vmem_bytes
    from repro.kernels.gemm import gemm_vmem_bytes
    assert gemm_vmem_bytes(256, 256, 256) < gemm_vmem_bytes(512, 512, 512)
    assert flash_vmem_bytes(256, 256, 128) < flash_vmem_bytes(1024, 1024, 128)
