"""Full-loop online-serving tests over the deterministic simulation harness.

Acceptance pins (ISSUE 4):
  * a mid-serve store append swaps the better config into the running server
    between decode steps, without a restart;
  * measured prod latencies round-trip: written as ``context="prod"``
    records, they come back as cross-fingerprint priors through
    ``transfer.warm_matches``;
  * drift between observed latency and the stored roofline enqueues exactly
    one re-tune request, a warm re-tune seeded purely from prod records
    reaches the cold run's best in >= 30% fewer unique evaluations (the
    benchmarks/warm_start.py bar), and the serving fleet hot-reloads the
    re-tune's result;
  * with a cold store the loop changes nothing: defaults stay deployed and
    the decode stream is identical to a loop-less server.
"""
import math

import numpy as np
import pytest

from loop_sim import (FleetSim, LoopSim, StubDecodeServer, VirtualClock,
                      evals_to_reach, prod_only_store)
from repro.core.engine import RetuneQueue, RetuneRequest, run_retune
from repro.core.runner import run_strategy
from repro.core.strategies import make_strategy
from repro.store import (JOB_TYPES, FencedClaimError, TuningRecord,
                         TuningRecordStore, warm_matches)

TARGET_REDUCTION = 0.30          # same bar as results/bench/warm_start.json


def test_mid_serve_append_hot_swaps_better_config(tmp_path):
    sim = LoopSim(str(tmp_path / "store"))
    ranked = sim.ranked_indices()
    mediocre, better = int(ranked[40]), int(ranked[2])

    sim.append_tuning_record(mediocre)
    stats = sim.serve(4)                      # initial resolution, then serve
    assert len(stats.swaps) == 1 and stats.swaps[0][0] == 0
    assert sim.server.config == sim.space.config(mediocre)

    sim.append_tuning_record(better)          # lands MID-SERVE
    stats = sim.serve(4)
    assert len(stats.swaps) == 1, "better record must swap in exactly once"
    step, cfg, value = stats.swaps[0]
    assert cfg == sim.space.config(better)
    assert value == pytest.approx(float(sim.times[better]))
    assert sim.server.config == sim.space.config(better)
    assert sim.server.restarts == 0, "hot reload must not restart the server"
    # the swap took effect between decode steps: later latencies are the
    # better config's, earlier ones (previous serve call) the mediocre one's
    assert max(stats.latencies) <= float(sim.times[mediocre])


def test_worse_or_equal_records_never_swap(tmp_path):
    sim = LoopSim(str(tmp_path / "store"))
    ranked = sim.ranked_indices()
    good, worse = int(ranked[5]), int(ranked[100])
    sim.append_tuning_record(good)
    sim.serve(2)
    sim.append_tuning_record(worse)
    sim.append_tuning_record(good)            # duplicate of the deployed one
    stats = sim.serve(4)
    assert stats.swaps == []
    assert sim.server.config == sim.space.config(good)


def test_prod_records_round_trip_through_warm_matches(tmp_path):
    sim = LoopSim(str(tmp_path / "store"))
    ranked = sim.ranked_indices()
    served = [int(ranked[30]), int(ranked[4])]
    sim.append_tuning_record(served[0])
    sim.serve(3)
    sim.append_tuning_record(served[1])
    sim.serve(3)

    store = TuningRecordStore(sim.store_path)
    prod = [d for d, desc in store.fingerprints().items()
            if desc.context == "prod"]
    assert len(prod) == 1
    recs = store.records(fp=prod[0])
    # the first step after each swap is jit warmup: measured but NOT
    # journaled as telemetry — 2 of each 3-step serve survive
    assert len(recs) == 4 and all(r.meta.get("phase") == "decode"
                                  for r in recs)
    assert [r.idx for r in recs] == [served[0]] * 2 + [served[1]] * 2
    # timestamps come from the virtual clock, strictly increasing
    ts = [r.t for r in recs]
    assert ts == sorted(ts) and len(set(ts)) == len(ts)

    # cross-fingerprint priors into a fresh tuning run of the SAME cell:
    # same grids -> exact nearest-neighbor match, discount = base cross noise.
    # (The prod-only view: in the full store the scripted tuning records sit
    # at the same indices and win the per-site dedupe, as they should.)
    prod_store = prod_only_store(sim.store_path, str(tmp_path / "prod.jsonl"))
    warm = warm_matches(prod_store, sim.fp, sim.space)
    assert warm and all(not w.exact for w in warm)
    by_idx = {w.idx: w for w in warm}
    full = {w.idx: w for w in warm_matches(store, sim.fp, sim.space)}
    assert all(full[idx].exact for idx in served), \
        "exact tuning records must outrank prod priors at the same site"
    for idx in served:
        w = by_idx[idx]
        assert w.config == sim.space.config(idx)
        measured = [r.value for r in recs if r.idx == idx]
        assert w.value == pytest.approx(min(measured))
        assert 0 < w.noise == pytest.approx(0.05, abs=1e-6)


def test_default_config_telemetry_never_transfers(tmp_path):
    """Cold store: serving runs on built-in defaults; telemetry is journaled
    but carries no on-grid config, so warm_matches must ignore it."""
    sim = LoopSim(str(tmp_path / "store"))
    stats = sim.serve(3)
    assert stats.swaps == [] and sim.server.config is None
    store = TuningRecordStore(sim.store_path)
    assert len(store.records()) == 3
    assert all(r.config is None and r.idx is None for r in store.records())
    assert warm_matches(store, sim.fp, sim.space) == []


def test_cold_store_serving_is_identical_to_loopless(tmp_path):
    """The online control plane around a cold store is a no-op: the decode
    latency stream is byte-identical to a bare server with no loop at all."""
    sim = LoopSim(str(tmp_path / "store"))
    online = sim.serve(6).latencies

    clock = VirtualClock()
    bare = StubDecodeServer(sim._latency_of, clock,
                            default_latency=sim.server.default_latency)
    offline = [bare.decode_step() for _ in range(6)]
    assert online == offline


def test_drift_enqueues_one_retune_request(tmp_path):
    sim = LoopSim(str(tmp_path / "store"), drift_factor=1.5, drift_window=4)
    best = int(sim.ranked_indices()[0])
    sim.append_tuning_record(best)
    stats = sim.serve(6)
    assert stats.retunes_requested == 0       # on-prediction: no drift

    sim.server.drift_scale = 2.0              # hardware/load regime change
    stats = sim.serve(12)
    assert stats.retunes_requested == 1, \
        "one drifted regime must yield one request, not one per step"
    req = sim.queue.pop()
    assert req is not None and req.key == sim.objective_id
    assert req.observed / req.predicted > 1.5
    assert sim.queue.pop() is None


def test_re_ranked_deployed_config_rebases_drift_prediction(tmp_path):
    """A better record for the ALREADY-DEPLOYED config must not swap (no
    re-jit for an identical config) but must rebase the drift monitor, or
    it would keep judging observed latency against a stale roofline."""
    sim = LoopSim(str(tmp_path / "store"), drift_window=4)
    best = int(sim.ranked_indices()[0])
    sim.append_tuning_record(best)
    sim.serve(2)
    assert sim.monitor.predicted == pytest.approx(float(sim.times[best]))
    sim.store.append(TuningRecord(
        fp=sim.fp.digest, run="re-measure", seq=99, key=str(best), idx=best,
        value=float(sim.times[best]) * 0.5, config=sim.space.config(best)),
        fingerprint=sim.fp)
    stats = sim.serve(2)
    assert stats.swaps == []
    assert sim.monitor.predicted == pytest.approx(
        float(sim.times[best]) * 0.5)


def test_retune_queue_dedupes_per_cell():
    q = RetuneQueue()
    assert q.submit(RetuneRequest(key="cell-a"))
    assert not q.submit(RetuneRequest(key="cell-a"))   # fleet stampede
    assert q.submit(RetuneRequest(key="cell-b"))
    assert len(q) == 2
    assert q.pop().key == "cell-a"
    assert q.submit(RetuneRequest(key="cell-a"))       # re-armed after pop


def test_full_cycle_warm_retune_from_prod_beats_cold(tmp_path):
    """The headline: store -> serve -> prod writeback -> drift -> warm
    re-tune -> hot reload of the re-tuned best, with the warm-start saving
    measured against a cold run on the same cell (>= 30% fewer uniques)."""
    sim = LoopSim(str(tmp_path / "store"), drift_window=4)
    obj = sim.objective()

    # cold reference: no store, no priors
    cold = run_strategy(make_strategy("ei"), obj, budget=40, seed=3)
    cold_evals = evals_to_reach(cold.trace, cold.best_value)
    assert cold_evals is not None and cold_evals >= 2

    # a fleet's history lands record by record; the server rides the
    # improvements, writing prod telemetry for every config it serves
    ranked = sim.ranked_indices()
    for idx in (int(ranked[40]), int(ranked[12]), int(ranked[3]),
                int(ranked[0])):
        sim.append_tuning_record(idx)
        sim.serve(4)
    assert sim.server.config == sim.space.config(int(ranked[0]))

    # drift: observed latency leaves the stored roofline -> re-tune request
    sim.server.drift_scale = 2.2
    sim.serve(8)
    req = sim.queue.pop()
    assert req is not None

    # warm re-tune seeded PURELY from prod telemetry (the scripted tuning
    # records are filtered out): must reach the cold best >= 30% faster
    prod_store = prod_only_store(sim.store_path, str(tmp_path / "prod.jsonl"))
    assert all(d.context == "prod" for d in
               prod_store.fingerprints().values())
    warm = run_strategy(make_strategy("ei"), obj, budget=40, seed=3,
                        store=prod_store, run_id="warm-retune")
    warm_evals = evals_to_reach(warm.trace, cold.best_value)
    assert warm_evals is not None
    assert warm_evals <= (1 - TARGET_REDUCTION) * cold_evals, \
        f"warm {warm_evals} vs cold {cold_evals} unique evals"

    # the drift request itself is serviced through the shared store; the
    # serving fleet tails the same store and hot-reloads the result — the
    # loop is closed when the re-tuned best is deployed without a restart
    res = run_retune(req, obj, make_strategy("ei"), store=sim.store_path,
                     budget=40, seed=7)
    assert math.isfinite(res.best_value)
    retuned = TuningRecordStore(sim.store_path)
    assert any(r.run.startswith("retune[") for r in retuned.records())
    sim.server.drift_scale = 1.0
    stats = sim.serve(2)
    deployed_value = sim.source.current[1]
    assert deployed_value <= float(sim.times[int(ranked[0])])
    assert sim.server.restarts == 0
    if stats.swaps:      # re-tune found a strictly better config: deployed
        assert stats.swaps[0][2] == pytest.approx(deployed_value)


def test_durable_retune_survives_server_death_and_daemon_services(tmp_path):
    """ISSUE 5 acceptance pin: a drift request submitted by one (simulated)
    serving process survives that process's death as a durable store record,
    is claimed EXACTLY ONCE by a separate ``launch/retune.py`` daemon, and
    the serviced result lands back in the store for the fleet."""
    path = str(tmp_path / "store")
    sim = LoopSim(path, drift_window=4, durable_queue=True)
    best = int(sim.ranked_indices()[0])
    sim.append_tuning_record(best)
    sim.serve(6)
    sim.server.drift_scale = 2.0
    stats = sim.serve(12)
    assert stats.retunes_requested == 1
    obj = sim.objective()                  # the cell's surface, kept aside
    sim.store.close()
    del sim                                # the serving process dies

    from repro.launch.retune import RetuneDaemon
    daemon = RetuneDaemon(path, objective_for=lambda key: obj,
                          budget=20, worker="retune-daemon-1")
    rival = RetuneDaemon(path, objective_for=lambda key: obj,
                         budget=20, worker="retune-daemon-2")
    res = daemon.step()
    assert res is not None and math.isfinite(res.best_value)
    assert daemon.serviced == 1
    assert rival.step() is None, "the request is claimed exactly once"
    assert daemon.step() is None, "done: nothing left to claim"

    store = TuningRecordStore(path)
    retune_runs = {r.run for r in store.records()
                   if r.run.startswith("retune[")}
    assert len(retune_runs) == 1, "the serviced run is journaled once"
    # a resurrected server resolves through the same store and sees a
    # config at least as good as what drifted
    sim2 = LoopSim(path, durable_queue=True)
    sim2.serve(1)
    assert sim2.source.current is not None
    assert sim2.source.current[1] <= float(sim2.times[best])
    assert len(sim2.queue) == 0, "no open requests remain"


def test_compaction_mid_serve_is_invisible_to_the_loop(tmp_path):
    """ISSUE 5 acceptance pin: compaction racing a live serve loop loses no
    records, re-delivers none (no spurious swap), and leaves resolution —
    for the running server AND a fresh one — identical."""
    path = str(tmp_path / "store")
    sim = LoopSim(path)
    ranked = sim.ranked_indices()
    sim.append_tuning_record(int(ranked[40]))
    sim.serve(3)
    sim.seal_segment()                     # rollover: old segment foldable
    sim.append_tuning_record(int(ranked[5]))
    sim.serve(3)
    assert sim.server.config == sim.space.config(int(ranked[5]))
    before = sim.source.current
    sim.seal_segment()
    stats = sim.compact()
    assert stats.folded and stats.records_kept == stats.records_in

    serve_stats = sim.serve(4)             # the loop keeps running over it
    assert serve_stats.swaps == [], \
        "compacted copies of consumed records must not re-trigger a swap"
    assert sim.source.current == before
    # a restarting server resolves the compacted store identically
    fresh = LoopSim(path)
    fresh.serve(1)
    assert fresh.source.current == before
    # and nothing was lost: both tuning records are still on disk
    store = TuningRecordStore(path)
    assert {r.idx for r in store.records(fp=sim.fp.digest)} \
        == {int(ranked[40]), int(ranked[5])}


def test_sub_margin_improvement_does_not_trigger_rejit(tmp_path):
    """Swap hysteresis (ROADMAP follow-up): a strictly better record whose
    roofline delta is below ``swap_margin`` must NOT swap (no re-jit); a
    beyond-margin improvement still must."""
    sim_probe = LoopSim(str(tmp_path / "probe"))
    ranked = sim_probe.ranked_indices()
    v = sim_probe.times
    deployed, nearby, big = int(ranked[10]), int(ranked[5]), int(ranked[0])
    margin = float(v[deployed] - v[nearby]) + 1e-9
    assert float(v[deployed] - v[big]) > margin, "surface sanity"

    sim = LoopSim(str(tmp_path / "store"), swap_margin=margin)
    sim.append_tuning_record(deployed)
    stats = sim.serve(2)
    assert len(stats.swaps) == 1           # initial deploy
    sim.append_tuning_record(nearby)       # better, but sub-margin
    stats = sim.serve(3)
    assert stats.swaps == [] and len(sim.server.applied) == 1, \
        "sub-margin improvement must not pay a re-jit"
    assert sim.server.config == sim.space.config(deployed)
    sim.append_tuning_record(big)          # beyond margin: worth it
    stats = sim.serve(3)
    assert len(stats.swaps) == 1
    assert sim.server.config == sim.space.config(big)


def test_margin_zero_preserves_always_swap(tmp_path):
    sim = LoopSim(str(tmp_path / "store"))   # default swap_margin=0.0
    ranked = sim.ranked_indices()
    sim.append_tuning_record(int(ranked[10]))
    sim.serve(2)
    sim.append_tuning_record(int(ranked[9]))  # any strict improvement
    stats = sim.serve(2)
    assert len(stats.swaps) == 1


def test_loop_sim_smoke():
    """CI smoke entry: the harness itself builds and one poll cycle runs."""
    clock = VirtualClock()
    assert clock() == 0.0
    clock.advance(1.5)
    assert clock() == 1.5


def test_mid_serve_kernel_hot_swap_no_restart_no_spurious_rejit(tmp_path):
    """DESIGN.md §14 acceptance: a kernel tuner landing a block config
    mid-serve hot-swaps the running server's kernels between decode steps —
    no restart — and swap-margin hysteresis keeps a sub-margin improvement
    from triggering a spurious re-jit."""
    sim = LoopSim(str(tmp_path / "store"), kernel_cell=True)
    ranked = np.argsort(sim.kernel_times, kind="stable")
    best, second, third = int(ranked[0]), int(ranked[1]), int(ranked[2])
    t = sim.kernel_times
    # margin swallows third->second but not third->best
    margin = float(t[third] - t[second]) + 1e-9
    assert t[third] - t[best] > margin
    sim.kernel_source.swap_margin = margin

    # cold store: no kernel swap, pure-default kernels
    stats = sim.serve(3)
    assert sim.server.kernel_applied == [] and stats.kernel_swaps == []

    # a kernel record lands mid-serve: swap at the next poll, no restart,
    # params/cache survive (the stub counts restarts; must stay 0)
    sim.append_kernel_record(third)
    stats = sim.serve(4)
    assert len(stats.kernel_swaps) == 1
    assert sim.server.kernel_applied == [sim.kernel_space.config(third)]
    assert sim.server.restarts == 0
    assert sim.server.kernel_config == sim.kernel_space.config(third)
    derives_after_swap = sim.server.derives

    # sub-margin improvement: no swap, no re-derive (no spurious re-jit)
    sim.append_kernel_record(second)
    stats = sim.serve(4)
    assert stats.kernel_swaps == []
    assert len(sim.server.kernel_applied) == 1
    assert sim.server.derives == derives_after_swap

    # past-margin improvement: swaps, still restart-free
    sim.append_kernel_record(best)
    stats = sim.serve(4)
    assert len(stats.kernel_swaps) == 1
    assert sim.server.kernel_config == sim.kernel_space.config(best)
    assert sim.server.restarts == 0
    assert sim.server.derives == derives_after_swap + 1


def test_kernel_swap_does_not_disturb_sharding_loop(tmp_path):
    """Kernel and sharding sources share the store but are independent
    cells: a kernel record never wins the sharding resolution (different
    objective id), a kernel swap doesn't rebase the drift monitor, and the
    post-swap warmup step is excluded from telemetry exactly once."""
    sim = LoopSim(str(tmp_path / "store"), kernel_cell=True)
    sharding_idx = int(sim.ranked_indices()[3])
    sim.append_tuning_record(sharding_idx)
    sim.append_kernel_record(int(np.argmin(sim.kernel_times)))
    stats = sim.serve(6)
    assert len(stats.swaps) == 1 and len(stats.kernel_swaps) == 1
    assert sim.server.config == sim.space.config(sharding_idx)
    # drift monitor judges the SHARDING roofline, untouched by kernel swaps
    assert sim.monitor.predicted == pytest.approx(
        float(sim.times[sharding_idx]))
    # both swaps happened at step 0's poll -> one warmup step total was
    # withheld from prod telemetry
    assert sim.recorder.count == stats.steps - 1


def test_stale_kernel_cell_auto_enqueues_retune_and_daemon_closes_loop(tmp_path):
    """Serve-side kernel staleness closes the loop without a human: a cell
    serving fallback kernels (no exact-fingerprint record has EVER landed)
    enqueues exactly one durable retune request; a daemon services it with
    the cell's own objective; the serving fleet hot-reloads the result and
    the cell stops being a retune candidate."""
    path = str(tmp_path / "store")
    sim = LoopSim(path, kernel_cell=True, durable_queue=True)
    assert sim.kernel_source.stale
    stats = sim.serve(6)
    assert stats.kernel_retunes_requested == 1, \
        "stale cell enqueues once; per-cell dedupe absorbs later polls"
    tickets = sim.queue.open_tickets()
    assert [tk.key for tk in tickets] == [sim.kernel_source.objective_id]
    assert tickets[0].reason == "stale"

    from repro.core.objectives import SimulatedObjective
    from repro.launch.retune import RetuneDaemon
    kobj = SimulatedObjective(sim.kernel_space, sim.kernel_times,
                              name=sim.kernel_source.objective_id)
    daemon = RetuneDaemon(path, objective_for=lambda key: kobj,
                          budget=8, worker="ktune-daemon",
                          clock=sim.clock)
    assert daemon.step() is not None and daemon.step() is None

    stats = sim.serve(6)
    assert len(stats.kernel_swaps) == 1, "fleet hot-reloads the retune"
    assert not sim.kernel_source.stale
    assert stats.kernel_retunes_requested == 0, \
        "an exact record landed: the cell is no longer a retune candidate"
    assert len(sim.queue) == 0


def test_fresh_kernel_cell_never_enqueues(tmp_path):
    """A kernel cell already tuned under its exact fingerprint must not
    request a retune — staleness means 'never tuned', not 'tunable'."""
    sim = LoopSim(str(tmp_path / "store"), kernel_cell=True,
                  durable_queue=True)
    sim.append_kernel_record(int(np.argmin(sim.kernel_times)))
    stats = sim.serve(6)
    assert len(stats.kernel_swaps) == 1
    assert stats.kernel_retunes_requested == 0
    assert len(sim.queue) == 0


def test_stale_decode_cell_retune_hot_swap_and_no_rejit_on_swap_back(tmp_path):
    """The decode kernel cell rides the same control plane (ISSUE 8): a
    serving cell whose flash-decode blocks were never tuned under its exact
    fingerprint enqueues one durable retune; a daemon services it with the
    decode cell's own objective; the result hot-swaps in mid-serve between
    decode steps; and re-applying a previously-deployed decode config is a
    compiled-kernel-cache hit — no spurious re-jit on swap-back."""
    path = str(tmp_path / "store")
    sim = LoopSim(path, decode_kernel_cell=True, durable_queue=True)
    assert sim.decode_kernel_source.stale
    assert sim.server.decode_dispatch == "jax"

    stats = sim.serve(6)
    assert stats.kernel_retunes_requested == 1, \
        "stale decode cell enqueues once; per-cell dedupe absorbs later polls"
    tickets = sim.queue.open_tickets()
    assert [tk.key for tk in tickets] == [sim.decode_kernel_source.objective_id]
    assert tickets[0].reason == "stale"
    assert stats.decode_steps_jax == stats.steps, \
        "every step so far served by the pure-JAX fallback"

    from repro.core.objectives import SimulatedObjective
    from repro.launch.retune import RetuneDaemon
    dobj = SimulatedObjective(sim.decode_kernel_space,
                              sim.decode_kernel_times,
                              name=sim.decode_kernel_source.objective_id)
    daemon = RetuneDaemon(path, objective_for=lambda key: dobj,
                          budget=8, worker="dtune-daemon", clock=sim.clock)
    assert daemon.step() is not None and daemon.step() is None

    stats = sim.serve(6)
    assert len(stats.kernel_swaps) == 1, "fleet hot-reloads the retune"
    assert not sim.decode_kernel_source.stale
    assert stats.kernel_retunes_requested == 0
    assert len(sim.queue) == 0
    assert sim.server.restarts == 0
    tuned_cfg = dict(sim.server.kernel_config)
    assert "num_splits" in tuned_cfg, "a decode-cell config was deployed"
    assert sim.server.decode_dispatch == "pallas"
    assert stats.decode_steps_pallas == stats.steps, \
        "swap landed at the first poll, before any step: all Pallas"

    # swap-back cycle: deploy a different decode config, then return to the
    # tuned one — both are compiled-cache hits the second time around
    other = int(np.argmax(sim.decode_kernel_times))
    derives = sim.server.derives
    sim.server.apply_kernel_config(sim.decode_kernel_space.config(other))
    assert sim.server.derives == derives + 1      # first visit: one re-jit
    sim.server.apply_kernel_config(tuned_cfg)
    sim.server.apply_kernel_config(sim.decode_kernel_space.config(other))
    assert sim.server.derives == derives + 1, \
        "swap-back to either previously-derived config must not re-jit"


def test_flash_and_decode_cells_coexist_independently(tmp_path):
    """One loop watches both kernel cells: each hot-swaps from its own
    objective id, both stale cells enqueue their own retune tickets, and a
    record landing for one cell neither swaps nor un-stales the other."""
    path = str(tmp_path / "store")
    sim = LoopSim(path, kernel_cell=True, decode_kernel_cell=True,
                  durable_queue=True)
    stats = sim.serve(4)
    assert stats.kernel_retunes_requested == 2, \
        "both stale kernel cells enqueue their own durable ticket"
    keys = sorted(tk.key for tk in sim.queue.open_tickets())
    assert keys == sorted([sim.kernel_source.objective_id,
                           sim.decode_kernel_source.objective_id])

    # a flash record lands: only the flash source swaps / un-stales
    sim.append_kernel_record(int(np.argmin(sim.kernel_times)))
    stats = sim.serve(4)
    assert len(stats.kernel_swaps) == 1
    assert not sim.kernel_source.stale
    assert sim.decode_kernel_source.stale
    assert "block_q" in sim.server.kernel_config

    # now a decode record: the decode source swaps without disturbing flash
    sim.append_decode_kernel_record(int(np.argmin(sim.decode_kernel_times)))
    stats = sim.serve(4)
    assert len(stats.kernel_swaps) == 1
    assert not sim.decode_kernel_source.stale
    assert "num_splits" in sim.server.kernel_config


# ---------------------------------------------------------------------------
# the tuning fleet (ISSUE 9 acceptance): N daemons + a racing compactor
# ---------------------------------------------------------------------------
def test_fleet_drains_50_mixed_jobs_exactly_once_with_racing_compactor(
        tmp_path):
    """The ISSUE 9 acceptance scenario end to end: 3 daemons round-robin a
    50-job queue cycling all four job types while a compactor races them
    every few rounds under the real lock. Every job is serviced exactly
    once across the fleet, every daemon participates, every serviced run is
    journaled under its job type, and the store's resolution content is
    byte-identical across a final compaction."""
    sim = FleetSim(str(tmp_path / "store"), n_daemons=3, budget=2)
    sim.submit_jobs(50)
    assert len(sim.submitter) == 50
    rounds = sim.drain(compact_every=3, retention_s=0.0)
    assert sim.open_keys() == [], f"queue not drained after {rounds} rounds"

    per_key = sim.services_per_key()
    assert sorted(per_key) == sorted(sim.submitted), \
        "every submitted job serviced, no phantom keys"
    assert set(per_key.values()) == {1}, \
        f"duplicate service: {[k for k, n in per_key.items() if n != 1]}"
    assert {w for _, w in sim.service_log} == \
        {f"daemon-{i}" for i in range(3)}, "every daemon participated"
    assert all(d.fenced == 0 for d in sim.daemons), \
        "no daemon was fenced out in an uncontended round-robin"
    assert sim.compactions >= 1, "the compactor never actually raced"

    store = TuningRecordStore(sim.store_path)
    prefixes = {run.split("[")[0] for run in store.runs() if "[" in run}
    assert set(JOB_TYPES) <= prefixes, \
        f"missing job-type runs: {set(JOB_TYPES) - prefixes}"
    # every journaled service carries its claim's fencing token
    fenced_meta = [r for r in store.records()
                   if (r.meta or {}).get("fence", {}).get("token", 0) >= 1]
    assert fenced_meta, "serviced runs must stamp meta['fence']"

    before = sim.resolution_view()
    assert sim.compact_racing(retention_s=0.0) is not None
    assert sim.resolution_view() == before, \
        "compaction changed the store's resolution content"


def test_fleet_fenced_out_claimant_wakes_and_is_refused(tmp_path):
    """A daemon claims, stalls past the claim TTL mid-service, a peer
    re-claims (higher fencing token) and services the job — when the
    stalled daemon revives, its ``done`` raises ``FencedClaimError`` and
    the job is NOT double-closed or double-counted."""
    sim = FleetSim(str(tmp_path / "store"), n_daemons=2, claim_ttl=5.0,
                   budget=2)
    sim.submit_jobs(1)
    zombie = sim.daemons[0].queue.claim()
    assert zombie is not None and zombie.token == 1
    # daemon-1 folds the claim now: its TTL countdown starts on ITS clock
    assert sim.daemons[1].queue.claim() is None
    sim.clock.advance(6.0)               # daemon-0 stalls past the TTL
    takeover = sim.daemons[1].queue.claim()
    assert takeover is not None and takeover.token == 2, \
        "the expired lease re-arms for the peer under a higher token"
    with pytest.raises(FencedClaimError):
        sim.daemons[0].queue.done(zombie)    # revives mid-takeover: refused
    assert sim.open_keys() == ["cell-000"], \
        "the zombie's refused done must not close the re-claimed job"
    # the peer hands its lease back (shutdown) and services via the real
    # daemon step instead — claim token 3, run, done
    sim.daemons[1].queue.release(takeover)
    assert sim.step_daemon(1) is not None
    assert sim.open_keys() == []             # serviced once, closed once
    assert sim.services_per_key() == {"cell-000": 1}
    # after closure the zombie's done is an idempotent no-op — it neither
    # raises nor re-closes a later generation of the key
    sim.daemons[0].queue.done(zombie)
    assert sim.services_per_key() == {"cell-000": 1}
