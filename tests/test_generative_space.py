"""GenerativeSpace (DESIGN.md §15): constraint-native backend parity + scale.

Small-space parity is exact: the generative backend must agree with the
enumerated one on every validity verdict and on every neighbor SET (indices
differ by design — generative indices are mixed-radix codes in the full
Cartesian grid, enumerated indices are dense kept-positions — so parity is
checked through codes, never through raw index values). Scale tests assert
the whole point of the backend: 10^9-cartesian spaces construct in
milliseconds with O(1) residency and tune end-to-end through the standard
pool-mode BO engine with records journaled under a stable fingerprint.
"""
import itertools
import math

import numpy as np
import pytest

from repro.core.objectives import CallableObjective
from repro.core.runner import run_strategy
from repro.core.searchspace import (DEFAULT_MAX_ENUMERATION, GenerativeSpace,
                                    Param, SearchSpace, VectorConstraint)
from repro.store.records import SpaceFingerprint, TuningRecordStore

from tests.test_searchspace import (random_constrained_case,
                                    reference_enumeration)


def twin_spaces(params, constraints, name="twin"):
    """The same problem through both backends."""
    enum = SearchSpace(params, constraints, name=name)
    gen = GenerativeSpace(params, constraints, name=name)
    return enum, gen


def enum_codes(enum: SearchSpace) -> np.ndarray:
    """Mixed-radix code of every kept config — the shared identity the two
    backends are compared through."""
    return enum.value_indices.astype(np.int64) @ enum._strides


# -- automatic fallback ------------------------------------------------------

def test_auto_fallback_above_max_enumeration():
    params = [Param(f"p{j}", tuple(range(10))) for j in range(4)]
    s = SearchSpace(params, max_enumeration=1000)   # cart 10^4 > 1000
    assert isinstance(s, GenerativeSpace)
    assert s.generative and s.size == 10_000


def test_small_spaces_stay_enumerated():
    s = SearchSpace([Param("a", (1, 2, 4, 8)), Param("b", (1, 2, 4))])
    assert type(s) is SearchSpace
    assert not s.generative


def test_above_default_cap_no_longer_raises():
    # cart = 6^10 ≈ 6.05e7 > DEFAULT_MAX_ENUMERATION (2e7): pre-§15 this was
    # a ValueError, now it silently becomes the generative backend
    params = [Param(f"p{j}", tuple(range(6))) for j in range(10)]
    assert 6 ** 10 > DEFAULT_MAX_ENUMERATION
    s = SearchSpace(params, [VectorConstraint(
        lambda c: (c["p0"] + c["p1"]) % 3 != 0)], name="big")
    assert isinstance(s, GenerativeSpace)
    assert s.cartesian_size == 6 ** 10
    rng = np.random.default_rng(0)
    assert s._feasible_mask(s.sample_feasible(rng, 64)).all()


def test_explicit_generative_on_small_space_allowed():
    # direct construction below the cap is legal (it is how parity tests
    # compare backends on spaces small enough to enumerate)
    gen = GenerativeSpace([Param("a", (1, 2, 4, 8)), Param("b", (1, 2, 4))],
                          [lambda c: c["a"] * c["b"] <= 8])
    assert gen.generative and gen.size == 12


def test_int64_overflow_guard():
    params = [Param(f"p{j}", tuple(range(1 << 8))) for j in range(8)]
    with pytest.raises(ValueError, match="overflows int64"):
        GenerativeSpace(params)


# -- small-space parity vs the enumerated backend ----------------------------

@pytest.mark.parametrize("seed", range(12))
def test_validity_verdict_parity_over_full_grid(seed):
    params, cons = random_constrained_case(seed)
    ref = reference_enumeration(params, cons)
    if len(ref) == 0:
        pytest.skip("all configs filtered")
    enum, gen = twin_spaces(params, cons, name=f"par{seed}")
    feasible_codes = set(int(c) for c in enum_codes(enum))
    assert gen.cartesian_size == enum.cartesian_size
    for g, ords in enumerate(itertools.product(
            *[range(len(p.values)) for p in params])):
        cfg = {p.name: p.values[o] for p, o in zip(params, ords)}
        want = g in feasible_codes
        assert (gen.index_of(cfg) is not None) == want
        assert (gen._find_code(g) is not None) == want
        # and the generative index IS the code
        if want:
            assert gen.index_of(cfg) == g
            assert gen.config(g) == cfg


@pytest.mark.parametrize("seed", range(12))
def test_neighbor_sets_parity(seed):
    params, cons = random_constrained_case(seed)
    ref = reference_enumeration(params, cons)
    if len(ref) == 0:
        pytest.skip("all configs filtered")
    enum, gen = twin_spaces(params, cons, name=f"nbr{seed}")
    codes = enum_codes(enum)
    for i, g in enumerate(codes):
        want_h = {int(codes[j]) for j in enum.hamming_neighbors(i)}
        want_a = {int(codes[j]) for j in enum.adjacent_neighbors(i)}
        assert set(gen.hamming_neighbors(int(g))) == want_h
        assert set(gen.adjacent_neighbors(int(g))) == want_a


def test_neighbor_walk_is_memoized():
    enum, gen = twin_spaces(
        [Param(f"p{j}", tuple(range(5))) for j in range(3)],
        [lambda c: (c["p0"] + c["p2"]) % 2 == 0])
    g = int(enum_codes(enum)[0])
    first = gen.hamming_neighbors(g)
    calls = {"n": 0}
    orig = gen._feasible_mask

    def counting(codes):
        calls["n"] += 1
        return orig(codes)

    gen._feasible_mask = counting
    assert gen.hamming_neighbors(g) == first     # memo hit: no re-walk
    assert calls["n"] == 0


@pytest.mark.parametrize("seed", range(6))
def test_x_norm_rows_match_enumerated(seed):
    params, cons = random_constrained_case(seed)
    ref = reference_enumeration(params, cons)
    if len(ref) == 0:
        pytest.skip("all configs filtered")
    enum, gen = twin_spaces(params, cons, name=f"xn{seed}")
    codes = enum_codes(enum)
    np.testing.assert_array_equal(gen.X_norm[codes], enum.X_norm)
    for i in (0, len(codes) - 1):
        np.testing.assert_array_equal(gen.X_norm[int(codes[i])],
                                      enum.X_norm[i])


# -- feasible sampling -------------------------------------------------------

def tight_space():
    """~3% acceptance: exercises the rejection loop's adaptive batching."""
    params = [Param(f"p{j}", tuple(range(8))) for j in range(4)]
    cons = [VectorConstraint(lambda c: (c["p0"] * c["p1"]) % 11 == 1)]
    return params, cons


def test_sample_feasible_all_feasible_and_deterministic():
    params, cons = tight_space()
    gen = GenerativeSpace(params, cons, name="tight")
    got = gen.sample_feasible(np.random.default_rng(7), 200)
    assert len(got) == 200
    assert gen._feasible_mask(got).all()
    again = gen.sample_feasible(np.random.default_rng(7), 200)
    np.testing.assert_array_equal(got, again)    # fixed seed → fixed draw


def test_stratified_feasible_spans_code_range():
    params = [Param(f"p{j}", tuple(range(9))) for j in range(6)]
    gen = GenerativeSpace(params, [VectorConstraint(
        lambda c: (c["p0"] + c["p5"]) % 3 != 0)], name="strat")
    got = gen.stratified_feasible(np.random.default_rng(3), 64)
    assert len(got) == 64
    assert gen._feasible_mask(got).all()
    # stratification: draws land across the full code range, not one corner
    assert got.min() < gen.cartesian_size // 4
    assert got.max() > 3 * (gen.cartesian_size // 4)


def test_random_index_is_feasible():
    params, cons = tight_space()
    gen = GenerativeSpace(params, cons, name="rand")
    rng = np.random.default_rng(0)
    draws = np.array([gen.random_index(rng) for _ in range(32)], np.int64)
    assert gen._feasible_mask(draws).all()


def test_infeasible_space_sampling_raises():
    gen = GenerativeSpace([Param("a", (1, 2, 3)), Param("b", (1, 2, 3))],
                          [lambda c: c["a"] > 100], name="empty")
    with pytest.raises(ValueError, match="feasible"):
        gen.sample_feasible(np.random.default_rng(0), 4)


# -- nearest snapping --------------------------------------------------------

def test_nearest_index_roundtrips_feasible_rows():
    params, cons = random_constrained_case(3)
    ref = reference_enumeration(params, cons)
    if len(ref) == 0:
        pytest.skip("all configs filtered")
    enum, gen = twin_spaces(params, cons, name="near")
    codes = enum_codes(enum)
    for g in codes[:: max(1, len(codes) // 16)]:
        assert gen.nearest_index(gen.X_norm[int(g)]) == int(g)
    excl = {int(codes[0])}
    alt = gen.nearest_index(gen.X_norm[int(codes[0])], exclude=excl)
    assert alt not in excl and gen._find_code(alt) is not None


def test_nearest_indices_batch_matches_single_and_feasible():
    params, cons = tight_space()
    gen = GenerativeSpace(params, cons, name="nearb")
    rng = np.random.default_rng(5)
    pts = rng.random((24, gen.dim), dtype=np.float32)
    batch = gen.nearest_indices(pts, chunk=7)
    assert gen._feasible_mask(batch).all()
    for k, row in enumerate(pts):
        assert int(batch[k]) == gen.nearest_index(row)


# -- interface boundaries ----------------------------------------------------

def test_unsupported_dense_surface_raises():
    gen = GenerativeSpace([Param("a", (1, 2)), Param("b", (1, 2, 3))])
    with pytest.raises(AttributeError):
        gen.value_indices
    with pytest.raises(NotImplementedError):
        gen.take(np.array([0]))
    with pytest.raises(TypeError):
        gen.X_norm[0:5]
    assert gen.x_norm_lazy
    assert len(gen.X_norm) == gen.cartesian_size


def test_resident_bytes_is_o1_at_1e9():
    params = [Param(f"p{j}", tuple(range(32))) for j in range(6)]
    s = SearchSpace(params, [VectorConstraint(
        lambda c: (c["p0"] + c["p1"]) % 2 == 0)], name="huge")
    assert isinstance(s, GenerativeSpace)
    assert s.cartesian_size == 32 ** 6            # ≈ 1.07e9
    assert s.resident_bytes < 64 * 1024           # vs ~4 GB enumerated X_norm
    assert s._feasible_mask(
        s.sample_feasible(np.random.default_rng(1), 128)).all()


# -- out-of-grid short-circuit (regression) ----------------------------------

def test_index_of_value_indices_out_of_grid_ordinal_is_none():
    # pre-fix, an out-of-range ordinal radix-folded into a code that can
    # alias a DIFFERENT valid config — both backends must reject it
    params = [Param("a", (1, 2, 4)), Param("b", (1, 2))]
    enum = SearchSpace(params, name="oog")
    gen = GenerativeSpace(params, name="oog")
    bad = np.array([0, 2])            # b ordinal 2 out of grid (n=2)
    assert enum.index_of_value_indices(bad) is None
    assert gen.index_of_value_indices(bad) is None
    assert enum.index_of_value_indices(np.array([3, 0])) is None
    assert gen.index_of_value_indices(np.array([3, 0])) is None
    assert enum.index_of_value_indices(np.array([-1, 0])) is None
    assert gen.index_of_value_indices(np.array([-1, 0])) is None


def test_find_code_out_of_grid_is_none():
    enum = SearchSpace([Param("a", (1, 2, 4)), Param("b", (1, 2))])
    assert enum._find_code(-1) is None
    assert enum._find_code(enum.cartesian_size) is None
    gen = GenerativeSpace([Param("a", (1, 2, 4)), Param("b", (1, 2))])
    assert gen._find_code(-1) is None
    assert gen._find_code(gen.cartesian_size) is None


# -- fingerprint stability ---------------------------------------------------

def test_fingerprint_stable_across_constructions_and_backends():
    params, cons = tight_space()
    a = GenerativeSpace(params, cons, name="fp")
    b = GenerativeSpace(params, cons, name="fp")
    fa = SpaceFingerprint.of(a, objective="obj")
    fb = SpaceFingerprint.of(b, objective="obj")
    assert fa.digest == fb.digest                 # deterministic identity
    enum = SearchSpace(params, cons, name="fp")
    fe = SpaceFingerprint.of(enum, objective="obj")
    # backends disagree on `size` (kept vs cartesian) so digests differ,
    # but cross-size transfer still links them — same rule that links a
    # narrow space's records to a wide lookup (store/resolve.py)
    assert fa.compatible(fe) and fe.compatible(fa)


# -- end-to-end: pool-mode BO on a 10^9 grid, journaled ----------------------

def _bowl(cfg):
    vals = np.array([cfg[f"p{j}"] for j in range(6)], np.float64)
    return float(0.01 + np.sum((vals / 31.0 - 0.4) ** 2))


def test_pool_bo_end_to_end_on_generative_space(tmp_path):
    params = [Param(f"p{j}", tuple(range(32))) for j in range(6)]
    space = SearchSpace(params, [VectorConstraint(
        lambda c: (c["p0"] + c["p1"]) % 2 == 0)], name="e2e")
    assert isinstance(space, GenerativeSpace)
    obj = CallableObjective(space, _bowl, name="gen_e2e")
    store = TuningRecordStore(str(tmp_path / "store"))
    from repro.core.strategies import make_strategy
    res = run_strategy(make_strategy("ei"), obj, budget=30, seed=0,
                       store=store, run_id="gen-run")
    assert res.unique_evals == 30
    journaled_idx = np.array([o.idx for o in res.journal], np.int64)
    assert space._feasible_mask(journaled_idx).all()
    # records landed in the store under the run's (stable) fingerprint
    fp = SpaceFingerprint.of(space, objective=obj.name)
    recs = store.records(fp=fp.digest)
    assert len(recs) == len(res.journal)
    assert all(r.config is not None for r in recs)
    best_cfg, best_val = store.best_config(fp)
    assert math.isclose(best_val, res.best_value, rel_tol=1e-12)
    assert space.index_of(best_cfg) == res.best_idx
    # the run actually optimized: beat the feasible-sample median handily
    sample = space.sample_feasible(np.random.default_rng(9), 256)
    med = float(np.median([_bowl(space.config(int(g))) for g in sample]))
    assert res.best_value < med


# -- production wide spaces --------------------------------------------------

def test_deepseek_wide_space_is_generative_and_samples():
    from repro.core.tuning_targets import sharding_space
    s = sharding_space("deepseek-v3-671b", "train_4k", wide=True)
    assert isinstance(s, GenerativeSpace)
    assert s.cartesian_size > 10 ** 9
    got = s.stratified_feasible(np.random.default_rng(0), 32)
    assert s._feasible_mask(got).all()
    cfg = s.config(int(got[0]))
    assert s.index_of(cfg) == int(got[0])
    # fingerprint identity is construction-stable
    fa = SpaceFingerprint.of(s, objective="cell")
    fb = SpaceFingerprint.of(
        sharding_space("deepseek-v3-671b", "train_4k", wide=True),
        objective="cell")
    assert fa.digest == fb.digest


def test_deepseek_wide_pool_bo_end_to_end_through_engine(tmp_path):
    """The acceptance pin: the previously-unconstructible deepseek wide cell
    constructs generatively and completes a pool-mode BO run through
    ``ParallelTuningEngine``, records journaled under its stable fingerprint
    (the real objective is a minutes-per-eval dry-run compile; the surface
    here is synthetic, resolved through the fingerprint's own grids)."""
    from repro.core.strategies.bo import BOConfig, BOStrategy
    from repro.core.tuning_targets import sharding_space
    from repro.store.resolve import cell_objective
    space = sharding_space("deepseek-v3-671b", "train_4k", wide=True)
    assert isinstance(space, GenerativeSpace)
    oid = cell_objective("deepseek-v3-671b", "train_4k")
    fp = SpaceFingerprint.of(space, objective=oid)

    def latency(cfg):
        x = fp.x_norm(cfg)          # fingerprint-grid renormalization
        return (float(0.01 + np.sum((x - 0.37) ** 2))
                if x is not None else float("nan"))

    obj = CallableObjective(space, latency, name=oid)
    store = TuningRecordStore(str(tmp_path / "store"))
    res = run_strategy(BOStrategy(BOConfig(initial_samples=8)), obj,
                       budget=16, seed=0, store=store, run_id="ds-wide")
    assert res.unique_evals == 16 and res.best_idx is not None
    recs = store.records(fp=fp.digest)
    assert len(recs) == 16
    assert all(space.index_of(r.config) == r.idx for r in recs), \
        "journaled configs round-trip through the code-keyed identity"
    best_cfg, best_val = store.best_config(fp)
    assert math.isclose(best_val, res.best_value, rel_tol=1e-12)
    assert space.index_of(best_cfg) == res.best_idx


def test_hard_sharding_grid_is_tight_coupled_and_samplable():
    """The hard-constrained scenario grids the propagating sampler unlocks
    (ISSUE 10): VMEM-coresidency + occupancy + tile-divisibility coupled
    constraints on a 10^9 cartesian, published under a NEW fingerprint
    family so hard-grid journals never mix with wide ones."""
    from repro.core.tuning_targets import sharding_space
    s = sharding_space("deepseek-v3-671b", "train_4k", hard=True)
    assert isinstance(s, GenerativeSpace)
    assert s.name.startswith("sharding_hard[")
    assert s.cartesian_size > 10 ** 9
    assert {"vmem_coresidency", "occupancy_floor",
            "q_tiles_divide_seq"} <= {
        getattr(c, "name", "") for c in s.constraints}
    got = s.sample_feasible(np.random.default_rng(0), 64)
    assert s._feasible_mask(got).all()
    strat = s.stratified_feasible(np.random.default_rng(1), 64)
    assert s._feasible_mask(strat).all()
    est = s.feasible_fraction_interval()
    assert est["hi"] < 0.05, "hard grid must be far tighter than wide"
    # distinct fingerprint family: never collides with the wide grid
    fa = SpaceFingerprint.of(s, objective="cell")
    fb = SpaceFingerprint.of(
        sharding_space("deepseek-v3-671b", "train_4k", wide=True),
        objective="cell")
    assert fa.digest != fb.digest
    # identity is construction-stable within the family
    fa2 = SpaceFingerprint.of(
        sharding_space("deepseek-v3-671b", "train_4k", hard=True),
        objective="cell")
    assert fa.digest == fa2.digest
    # every sampled config honours the no-ragged-tiles rule end-to-end
    cfg = s.config(int(got[0]))
    assert 4096 % (cfg["attn_q_chunks"] * cfg["attn_block_q"]) == 0
    assert 4096 % cfg["attn_block_kv"] == 0


def test_narrow_and_non_moe_wide_spaces_stay_enumerated():
    from repro.core.tuning_targets import sharding_space
    narrow = sharding_space("deepseek-v3-671b", "train_4k")
    assert type(narrow) is SearchSpace
    wide_dense = sharding_space("internlm2-1.8b", "train_4k", wide=True)
    assert type(wide_dense) is SearchSpace   # small grid: vectorized path


def test_describe_reports_estimated_feasible_fraction():
    """describe() surfaces a loudly-labeled feasible-fraction estimate:
    a propagation-derived bracket before any draws exist (Knuth probe
    descents — works without sampling), a Jeffreys interval over the
    rejection sampler's accepted/attempted counts once draws exist."""
    gen = GenerativeSpace([Param("a", tuple(range(16))),
                           Param("b", tuple(range(16)))],
                          [lambda c: c["a"] > c["b"]], name="halfspace")
    before = gen.describe()
    assert "PROPAGATION" in before and "Jeffreys" not in before
    est = gen.feasible_fraction_interval()
    assert est["method"] == "propagation"
    assert est["lo"] <= est["point"] <= est["hi"]
    # a > b over a 16x16 grid keeps 120/256 ~ 0.47; unbiased probe
    # descents must at least bracket a plausible nonzero mass
    assert est["hi"] > 0.0

    rng = np.random.default_rng(0)
    gen.sample_feasible(rng, 64)
    after = gen.describe()
    assert "Jeffreys" in after and "draws" in after
    est = gen.feasible_fraction_interval()
    assert est["method"] == "jeffreys"
    # the true fraction is 120/256 ~ 0.47 and the interval has real
    # counts behind it — it must cover the truth
    assert est["lo"] < 120 / 256 < est["hi"]
    assert f"{est['point']:.3g}" in after


def test_feasible_fraction_interval_unconstrained_exact():
    gen = GenerativeSpace([Param("a", tuple(range(8))),
                           Param("b", tuple(range(8)))], name="freegrid")
    est = gen.feasible_fraction_interval()
    assert est == {"method": "exact", "point": 1.0, "lo": 1.0, "hi": 1.0}
    assert "unconstrained" in gen.describe()


# -- constraint-propagating sampler (DESIGN.md §15) --------------------------

def force_propagation(gen):
    """Sink the acceptance EWMA below the routing threshold so every draw
    goes through the propagating sampler."""
    gen._accept_ewma = 0.0
    return gen


@pytest.mark.parametrize("seed", range(8))
def test_propagating_draws_match_rejection_verdicts(seed):
    """Every propagated code must be feasible by the rejection sampler's
    exact verdict (_feasible_mask == _constrain over the full grid)."""
    params, cons = random_constrained_case(seed)
    ref = reference_enumeration(params, cons)
    if len(ref) == 0:
        pytest.skip("all configs filtered")
    enum, gen = twin_spaces(params, cons, name=f"prop{seed}")
    force_propagation(gen)
    feasible = set(int(c) for c in enum_codes(enum))
    draws = gen.sample_feasible(np.random.default_rng(seed), 64)
    assert gen._prop_draws > 0                     # propagation actually ran
    assert all(int(c) in feasible for c in draws)


def test_propagating_membership_parity_covers_full_feasible_set():
    # small space: enough propagated draws must reach EVERY feasible config
    # (propagation explores the same feasible set rejection does — no
    # region is unreachable through the pruned per-dimension grids)
    params = [Param("a", tuple(range(4))), Param("b", tuple(range(4))),
              Param("c", tuple(range(3)))]
    cons = [VectorConstraint(lambda c: (c["a"] + c["b"]) % 3 == 0, "ab"),
            VectorConstraint(lambda c: c["c"] != 1, "c")]
    enum, gen = twin_spaces(params, cons, name="cover")
    force_propagation(gen)
    feasible = set(int(c) for c in enum_codes(enum))
    got = set(int(c) for c in
              gen.sample_feasible(np.random.default_rng(0), 600))
    assert got == feasible


def test_propagating_fixed_seed_deterministic_on_fresh_spaces():
    params, cons = tight_space()
    a = force_propagation(GenerativeSpace(params, cons, name="da"))
    b = force_propagation(GenerativeSpace(params, cons, name="db"))
    d1 = a.sample_feasible(np.random.default_rng(11), 100)
    d2 = b.sample_feasible(np.random.default_rng(11), 100)
    np.testing.assert_array_equal(d1, d2)


def test_loose_space_draws_byte_identical_to_legacy_rejection():
    """The routing tentpole must not perturb loosely-constrained spaces:
    the EWMA starts at 1.0 and never sinks below PROPAGATE_BELOW, so the
    draw stream is byte-identical to the pre-propagation rejection loop
    (re-implemented here verbatim as the pin)."""
    params, cons = tight_space()          # ~3% acceptance: still "loose"

    def legacy_rejection(space, rng, m):
        out, got, attempts = [], 0, 0
        ewma = 1.0
        budget = max(64 * m, 1 << 20)
        while got < m and attempts < budget:
            rate = max(ewma, 1e-3)
            batch = int(min(max(int((m - got) / rate) + 16, 256), 1 << 17))
            codes = rng.integers(0, space.cartesian_size, size=batch,
                                 dtype=np.int64)
            kept = codes[space._feasible_mask(codes)]
            ewma = 0.7 * ewma + 0.3 * (len(kept) / batch)
            attempts += batch
            if kept.size:
                out.append(kept)
                got += len(kept)
        codes = np.concatenate(out)[:m]
        if len(codes) < m:
            fill = codes[rng.integers(0, len(codes), size=m - len(codes))]
            codes = np.concatenate([codes, fill])
        return codes

    gen = GenerativeSpace(params, cons, name="loose")
    ref = GenerativeSpace(params, cons, name="ref")
    got = gen.sample_feasible(np.random.default_rng(13), 300)
    want = legacy_rejection(ref, np.random.default_rng(13), 300)
    np.testing.assert_array_equal(got, want)
    assert gen._prop_draws == 0            # propagation never engaged


def test_tight_1e9_space_first_sample_fast_where_rejection_raises():
    """The acceptance criterion: at ~1e-6 feasible fraction over a 1e9
    cartesian grid, pure rejection exhausts its budget and raises while
    the auto-routed sampler falls back to propagation and succeeds."""
    import time

    def build(name):
        # (2/1024)^3 ~ 7e-9 feasible: far beyond any rejection budget
        params = [Param(f"p{k}", tuple(range(1, 33))) for k in range(6)]
        cons = [VectorConstraint(
                    lambda c: (c["p0"] * 33 + c["p1"]) % 1024 < 2, "t01"),
                VectorConstraint(
                    lambda c: (c["p2"] * 33 + c["p3"]) % 1024 < 2, "t23"),
                VectorConstraint(
                    lambda c: (c["p4"] * 33 + c["p5"]) % 1024 < 2, "t45")]
        return GenerativeSpace(params, cons, name=name)

    legacy = build("hard-legacy")
    legacy.PROPAGATE_BELOW = -1.0          # pin pure rejection
    with pytest.raises(ValueError, match="feasible"):
        legacy.sample_feasible(np.random.default_rng(0), 4)

    sp = build("hard-auto")
    t0 = time.perf_counter()
    draws = sp.sample_feasible(np.random.default_rng(0), 4)
    dt = time.perf_counter() - t0
    assert sp._feasible_mask(draws).all()
    assert sp._prop_draws >= 4
    assert dt < 2.0                        # ms-scale in practice; CI slack


def test_stratified_propagation_stays_in_stratum():
    # constraints on TRAILING params only: every top-digit stratum is
    # feasible, so in-stratum propagation must fill all of them in place
    params = [Param(f"p{k}", tuple(range(8))) for k in range(6)]
    cons = [VectorConstraint(lambda c: (c["p4"] * 9 + c["p5"]) % 16 == 0)]
    gen = force_propagation(GenerativeSpace(params, cons, name="strat-p"))
    m = 64
    got = gen.stratified_feasible(np.random.default_rng(2), m)
    assert gen._feasible_mask(got).all()
    cart = gen.cartesian_size
    for i, code in enumerate(got):
        assert i * cart // m <= int(code) < (i + 1) * cart // m


def test_dead_end_memoization_populates_and_amortizes():
    # (p0, p1) pairs mostly dead: backtracking records dead prefixes and
    # later draws skip them without re-pruning
    params = [Param(f"p{k}", tuple(range(8))) for k in range(4)]
    cons = [VectorConstraint(lambda c: (c["p0"] * 9 + c["p1"]) % 31 == 0),
            VectorConstraint(lambda c: (c["p2"] + c["p3"]) % 4 == 0)]
    gen = force_propagation(GenerativeSpace(params, cons, name="dead"))
    gen.sample_feasible(np.random.default_rng(1), 64)
    assert len(gen._dead_prefixes) > 0
    before = len(gen._dead_prefixes)
    calls = {"n": 0}
    orig = gen._prune_axis

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    gen._prune_axis = counting
    gen.sample_feasible(np.random.default_rng(2), 64)
    warm = calls["n"]
    # a fully cold re-run of the same draws pays strictly more prunes
    cold = force_propagation(GenerativeSpace(params, cons, name="dead2"))
    calls2 = {"n": 0}
    orig2 = cold._prune_axis

    def counting2(*a, **k):
        calls2["n"] += 1
        return orig2(*a, **k)

    cold._prune_axis = counting2
    cold.sample_feasible(np.random.default_rng(1), 64)
    cold.sample_feasible(np.random.default_rng(2), 64)
    assert warm < calls2["n"]
    assert len(gen._dead_prefixes) >= before


@pytest.mark.parametrize("seed", range(6))
def test_axis_exchange_parity_with_enumerated(seed):
    params, cons = random_constrained_case(seed)
    ref = reference_enumeration(params, cons)
    if len(ref) == 0:
        pytest.skip("all configs filtered")
    enum, gen = twin_spaces(params, cons, name=f"ax{seed}")
    codes = enum_codes(enum)
    for i, g in enumerate(codes[:: max(1, len(codes) // 12)]):
        pos = int(np.searchsorted(codes, g))
        for j in range(enum.dim):
            want = {int(codes[k]) for k in enum.axis_exchange(pos, j)}
            assert set(gen.axis_exchange(int(g), j)) == want


def test_axis_exchange_never_returns_infeasible_or_self():
    params, cons = tight_space()
    gen = GenerativeSpace(params, cons, name="axf")
    rng = np.random.default_rng(4)
    for code in gen.sample_feasible(rng, 16):
        for j in range(gen.dim):
            ex = gen.axis_exchange(int(code), j)
            assert int(code) not in ex
            if ex:
                assert gen._feasible_mask(np.asarray(ex, np.int64)).all()


def test_plain_callable_constraints_propagate_too():
    # non-vector constraints go through the per-candidate pruning fallback
    params = [Param("a", tuple(range(6))), Param("b", tuple(range(6)))]
    cons = [lambda c: (c["a"] * c["b"]) % 5 == 1]
    enum, gen = twin_spaces(params, cons, name="plain")
    force_propagation(gen)
    feasible = set(int(c) for c in enum_codes(enum))
    draws = gen.sample_feasible(np.random.default_rng(0), 80)
    assert set(int(c) for c in draws) <= feasible
    assert gen._prop_draws > 0


def test_conditional_constraint_reads_grow_deps_safely():
    # a constraint that only reads "b" when a > 2: the probe may or may
    # not see the read, but KeyError growth + the leaf check keep every
    # drawn code feasible either way
    params = [Param("a", tuple(range(6))), Param("b", tuple(range(6)))]

    def tricky(c):
        if c["a"] > 2:
            return c["b"] % 2 == 0
        return True

    enum, gen = twin_spaces(params, [tricky], name="cond")
    force_propagation(gen)
    feasible = set(int(c) for c in enum_codes(enum))
    draws = gen.sample_feasible(np.random.default_rng(3), 200)
    assert set(int(c) for c in draws) == feasible


# -- sticky adaptive state regression (satellite fix) ------------------------

def test_failed_sample_restores_accept_ewma():
    """A raising sample_feasible call must not leave the acceptance EWMA
    crushed at its floor — pre-fix, the NEXT call on the same space opened
    with a pathologically large first batch sized by the stale estimate."""
    gen = GenerativeSpace([Param("a", (1, 2, 3)), Param("b", (1, 2, 3))],
                          [lambda c: c["a"] > 100], name="sticky")
    assert gen._accept_ewma == 1.0
    with pytest.raises(ValueError, match="feasible"):
        gen.sample_feasible(np.random.default_rng(0), 4)
    assert gen._accept_ewma == 1.0        # restored, not floor-stuck
    draws_first = gen._accept_draws
    with pytest.raises(ValueError, match="feasible"):
        gen.sample_feasible(np.random.default_rng(1), 4)
    # identical adaptive state -> identical batch schedule on the retry
    assert gen._accept_draws == 2 * draws_first
    assert gen._accept_ewma == 1.0


def test_failed_sample_restores_ewma_on_pure_rejection_path_too():
    gen = GenerativeSpace([Param("a", (1, 2, 3)), Param("b", (1, 2, 3))],
                          [lambda c: c["a"] > 100], name="sticky2")
    gen.PROPAGATE_BELOW = -1.0
    with pytest.raises(ValueError, match="feasible"):
        gen.sample_feasible(np.random.default_rng(0), 4)
    assert gen._accept_ewma == 1.0
