"""Sharding rules (pure) + multi-device integration via subprocess.

The subprocess tests force 8 host devices (the main test process must stay
at 1 device) and run a real sharded train step + gradient compression under
shard_map — the miniature of the production mesh.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.parallel.sharding import (DEFAULT_ACT_RULES, DEFAULT_PARAM_RULES,
                                     ParallelConfig, resolve_spec)


class FakeMesh:
    """Duck-typed mesh: resolve_spec only needs axis_names + devices.shape."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.devices = np.empty(tuple(axes.values()))


def test_resolve_drops_indivisible():
    mesh = FakeMesh(data=16, model=16)
    # kv_heads=1 cannot shard over model=16 -> replicated
    spec = resolve_spec((1024, 1, 128), ("embed", "kv_heads", "head_dim"),
                        DEFAULT_PARAM_RULES, mesh)
    assert spec[1] is None if len(spec) > 1 else True
    assert spec[0] == "data"


def test_resolve_no_axis_reuse():
    mesh = FakeMesh(data=16, model=16)
    # both dims want "model": only the first gets it
    spec = resolve_spec((256, 4096), ("vocab", "mlp"),
                        {"vocab": "model", "mlp": "model"}, mesh)
    assert spec[0] == "model"
    assert len(spec) == 1 or spec[1] is None


def test_resolve_tuple_axes_partial():
    mesh = FakeMesh(pod=2, data=16, model=16)
    spec = resolve_spec((256, 128), ("act_batch", None),
                        DEFAULT_ACT_RULES, mesh)
    assert spec[0] == ("pod", "data")


def test_resolve_tuple_axes_drops_nondividing():
    mesh = FakeMesh(pod=2, data=16, model=16)
    # batch 8: divisible by pod(2) and by pod*data=32? no -> only pod
    spec = resolve_spec((8, 128), ("act_batch", None), DEFAULT_ACT_RULES, mesh)
    assert spec[0] == ("pod", "data") or spec[0] == "pod"


def test_resolve_missing_mesh_axis_ignored():
    mesh = FakeMesh(data=4, model=2)   # no "pod" axis (single-pod)
    spec = resolve_spec((256, 128), ("act_batch", None), DEFAULT_ACT_RULES, mesh)
    assert spec[0] == "data"


_SUBPROCESS_SHARDED_TRAIN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.configs.registry import smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.params import init_params, model_specs
    from repro.models.stepfn import make_train_step
    from repro.optim.optimizers import AdamW, constant_lr
    from repro.parallel.sharding import ParallelConfig, ShardCtx, param_shardings, act_sharding

    mesh = make_host_mesh(data=4, model=2)
    pcfg = ParallelConfig(flash_threshold=1 << 30, logits_chunk=0)
    px = ShardCtx(mesh=mesh, pcfg=pcfg)
    cfg = smoke_config("qwen3-moe-30b-a3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    sh = param_shardings(model_specs(cfg), mesh, pcfg)
    params = jax.tree.map(jax.device_put, params, sh)
    opt = AdamW(schedule=constant_lr(1e-3))
    opt_state = opt.init(params)
    tokens = jax.device_put(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 32)).astype(np.int32),
        act_sharding((8, 32), ("act_batch", "act_seq"), mesh, pcfg))
    step = jax.jit(make_train_step(cfg, px, opt), donate_argnums=(0, 1))
    params, opt_state, m = step(params, opt_state, {"tokens": tokens}, 0)
    l1 = float(m["loss"])
    params, opt_state, m = step(params, opt_state, {"tokens": tokens}, 1)
    print(json.dumps({"loss1": l1, "loss2": float(m["loss"]),
                      "n_dev": jax.device_count()}))
""")

_SUBPROCESS_COMPRESSION = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np, json, functools
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map            # jax >= 0.6
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from repro.parallel.compression import compress_tree_psum
    mesh = jax.make_mesh((8,), ("pod",))
    g_global = np.random.default_rng(0).normal(size=(8, 64, 32)).astype(np.float32)

    def worker(method):
        def f(g, key):
            grads = {"w": g}
            res = {"w": jnp.zeros_like(g)} if method == "topk" else None
            red, _ = compress_tree_psum(grads, res, "pod", method, key, 0.25)
            return red["w"]
        return f

    out = {}
    for method in ("none", "int8", "topk"):
        fn = jax.jit(shard_map(worker(method), mesh=mesh,
                               in_specs=(P("pod"), P()), out_specs=P("pod")))
        keys = jax.random.PRNGKey(0)
        red = np.asarray(fn(g_global, keys))
        true_mean = g_global.mean(axis=0)
        err = float(np.abs(red[0] - true_mean).max())
        out[method] = err
    print(json.dumps(out))
""")


def _run_sub(code: str) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_moe_train_step_8dev():
    out = _run_sub(_SUBPROCESS_SHARDED_TRAIN)
    assert out["n_dev"] == 8
    assert np.isfinite(out["loss1"]) and np.isfinite(out["loss2"])
    assert out["loss2"] <= out["loss1"] + 0.5


@pytest.mark.slow
def test_grad_compression_8dev():
    out = _run_sub(_SUBPROCESS_COMPRESSION)
    assert out["none"] < 1e-6                       # exact mean
    assert out["int8"] < 0.02                       # quantization error bound
    assert out["topk"] < 1.0                        # sparse first step, coarse
