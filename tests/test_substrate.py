"""Data pipeline, optimizers, checkpointing, runtime fault tolerance."""
import math
import os
import time

import numpy as np
import pytest

# training-substrate tests compile jax train steps and run restart drills:
# the nightly tier. PR CI deselects them (-m "not slow"); the tier-1 verify
# command runs everything.
pytestmark = pytest.mark.slow

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, DataIterator, make_source
from repro.optim.optimizers import AdamW, Adafactor, clip_by_global_norm, \
    constant_lr, warmup_cosine
from repro.runtime.train import (LoopConfig, SimulatedFailure, TrainLoop,
                                 run_with_restarts)
from repro.configs.registry import smoke_config


# -- data ---------------------------------------------------------------

def _dc(**kw):
    base = dict(vocab_size=97, seq_len=32, global_batch=8, seed=5)
    base.update(kw)
    return DataConfig(**base)


def test_data_deterministic_in_step():
    src = make_source(_dc())
    a = src.batch(7)["tokens"]
    b = src.batch(7)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = src.batch(8)["tokens"]
    assert not np.array_equal(a, c)


def test_data_host_sharding_partitions_batch():
    src = make_source(_dc())
    full = src.batch(3, (0, 1))["tokens"]
    h0 = src.batch(3, (0, 2))["tokens"]
    h1 = src.batch(3, (1, 2))["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), full)


def test_data_iterator_restore():
    it = DataIterator(make_source(_dc()))
    next(it); next(it)
    st = it.state()
    a = next(it)["tokens"]
    it2 = DataIterator(make_source(_dc()))
    it2.restore(st)
    np.testing.assert_array_equal(next(it2)["tokens"], a)


def test_data_tokens_in_vocab():
    b = make_source(_dc()).batch(0)["tokens"]
    assert b.min() >= 0 and b.max() < 97


# -- optimizers -----------------------------------------------------------

def _quadratic_losses(opt, steps=60):
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 1.0, 1.0])
    losses = []
    for _ in range(steps):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = opt.update(g, state, params)
        losses.append(float(jnp.sum((params["w"] - target) ** 2)))
    return losses


def test_adamw_converges_quadratic():
    losses = _quadratic_losses(AdamW(schedule=constant_lr(0.1), weight_decay=0.0))
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_converges_quadratic():
    losses = _quadratic_losses(Adafactor(schedule=constant_lr(0.3)))
    assert losses[-1] < 0.2 * losses[0]


def test_adafactor_state_is_factored():
    opt = Adafactor(schedule=constant_lr(0.1))
    p = {"w": jnp.zeros((64, 32))}
    st = opt.init(p)
    assert st["v"]["w"]["vr"].shape == (64,)
    assert st["v"]["w"]["vc"].shape == (32,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    cn = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert cn == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, warmup=10, total=100)
    assert float(s(0)) < float(s(9))
    assert float(s(10)) == pytest.approx(1.0, rel=0.1)
    assert float(s(99)) < float(s(50))


# -- checkpointing ----------------------------------------------------------

def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.asarray([1, 2, 3], jnp.int32),
            "b": {"w": jnp.asarray([[1.5, -2.25]], jnp.bfloat16)},
            "c": jnp.asarray(0.5, jnp.float32)}
    path = ckpt.save(str(tmp_path), 12, tree, extras={"step": 12})
    got, extras = ckpt.restore(path, tree)
    assert extras["step"] == 12
    np.testing.assert_array_equal(np.asarray(got["a"]), [1, 2, 3])
    assert got["b"]["w"].dtype.name == "bfloat16"
    np.testing.assert_allclose(np.asarray(got["b"]["w"], np.float32),
                               [[1.5, -2.25]])


def test_checkpoint_latest_and_atomic(tmp_path):
    t = {"x": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 5, t)
    os.makedirs(tmp_path / "step_00000009.tmp")   # simulated crash mid-write
    assert ckpt.latest(str(tmp_path)).endswith("step_00000005")


def test_async_checkpointer_gc(tmp_path):
    ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ac.save(s, {"x": jnp.full((2,), s)})
    ac.wait()
    ac._gc()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_")
                  and not d.endswith(".tmp"))
    assert dirs == ["step_00000003", "step_00000004"]


# -- runtime fault tolerance ---------------------------------------------------

def _loop(tmp_path, attempt, fail_at=None, steps=14):
    cfg = smoke_config("internlm2-1.8b")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    lc = LoopConfig(steps=steps, ckpt_every=5, ckpt_dir=str(tmp_path),
                    log_every=0, fail_at_step=fail_at if attempt == 0 else None)
    return TrainLoop(cfg, dc, lc)


def test_train_restart_resumes_from_checkpoint(tmp_path):
    metrics = run_with_restarts(
        lambda attempt: _loop(tmp_path, attempt, fail_at=8), max_restarts=2)
    # second attempt restored from step 5 and ran 14-5=9 steps
    assert metrics.restored_from is not None
    assert metrics.start_step == 5
    assert metrics.start_step + len(metrics.losses) == 14


def test_train_loss_decreases(tmp_path):
    loop = _loop(tmp_path / "fresh", 0, steps=30)
    metrics = loop.run()
    assert np.mean(metrics.losses[-5:]) < np.mean(metrics.losses[:5])


def test_straggler_detection(tmp_path, monkeypatch):
    loop = _loop(tmp_path / "s", 0, steps=12)
    orig = loop._step_fn
    calls = {"n": 0}

    def slow_step(*a, **k):
        calls["n"] += 1
        if calls["n"] == 9:
            time.sleep(0.75)
        return orig(*a, **k)

    loop._step_fn = slow_step
    metrics = loop.run()
    assert 8 in metrics.straggler_events
